"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (device count is locked at first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """A 1x1 mesh over the real local device (CPU smoke/serving paths)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_edge_mesh(n_devices: int = 1):
    """Edge fleet sub-mesh: pure data-parallel SLM replicas (PICE's p-way
    semantic parallelism maps onto the data axis)."""
    return jax.make_mesh((n_devices, 1), ("data", "model"))
