import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""HLO inspector: compile one (arch x shape) on the production mesh and print
the top ops by bytes/FLOPs — the dry-run 'profiler' used by §Perf to find
what dominates a roofline term.

  PYTHONPATH=src python -m repro.launch.inspect_hlo --arch granite-3-8b \
      --shape train_4k --top 25
"""
import argparse
import re
from collections import defaultdict

import jax

from repro.configs import registry
from repro.configs.registry import SHAPES
from repro.launch.dryrun import _dryrun_config, build_step
from repro.launch.mesh import make_production_mesh

_DT = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
       "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8, "u64": 8}
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def shape_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(sig):
        if dt not in _DT:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DT[dt]
    return total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--moe-sort", action="store_true")
    ap.add_argument("--chunked-ce", type=int, default=0)
    ap.add_argument("--collectives", action="store_true",
                    help="print unique collective ops with source metadata")
    args = ap.parse_args()

    shape = SHAPES[args.shape]
    cfg = _dryrun_config(registry.get_config(args.arch), shape)
    if args.moe_sort:
        cfg = cfg.with_(moe_sort_dispatch=True)
    if args.chunked_ce:
        from repro.training import losses
        losses.CHUNKED_CE_BLOCK = args.chunked_ce
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    fn, a, in_sh, out_sh, donate = build_step(cfg, shape, mesh)
    with mesh:
        compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                           donate_argnums=donate).lower(*a).compile()
    text = compiled.as_text()

    if args.collectives:
        import collections
        seen = collections.Counter()
        samples = {}
        for line in text.splitlines():
            for op in ("all-gather(", "all-reduce(", "reduce-scatter(",
                       "all-to-all(", "collective-permute("):
                if op in line and "=" in line:
                    sig = line.split("=", 1)[1].strip()[:110]
                    meta = ""
                    if "op_name=" in line:
                        meta = line.split("op_name=")[1].split('"')[1][:90]
                    key = (op[:-1], sig.split(")")[0][:70], meta)
                    seen[key] += 1
                    samples[key] = line.strip()[:240]
        for (op, sig, meta), n in seen.most_common(20):
            print(f"x{n:4d} {op:18s} {sig}\n      op_name={meta}")
        return

    # group per-op output bytes by (opcode, shape signature)
    agg_bytes = defaultdict(lambda: [0, 0])
    line_re = re.compile(r"=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\])(?:\{[^}]*\})?)\s+([a-z0-9_\-]+)\(")
    for m in line_re.finditer(text):
        sig, op = m.group(1), m.group(2)
        b = shape_bytes(sig)
        key = f"{op} {sig[:60]}"
        agg_bytes[key][0] += b
        agg_bytes[key][1] += 1
    print(f"== top {args.top} op groups by total output bytes "
          f"({args.arch} x {args.shape}) ==")
    for key, (b, n) in sorted(agg_bytes.items(), key=lambda kv: -kv[1][0])[
            : args.top]:
        print(f"{b/1e9:10.2f} GB  x{n:5d}  {key}")
    cost = compiled.cost_analysis()
    print(f"\ncost: flops={cost.get('flops'):.3e} "
          f"bytes={cost.get('bytes accessed'):.3e}")


if __name__ == "__main__":
    main()
