"""Parameter / input / cache sharding rules for the production mesh.

Baseline policy (paper-faithful Megatron-style TP over `model`, DP over
`pod`+`data`):
  - attention q/o shard heads over `model`; k/v shard kv-heads when they
    divide (else replicated — standard GQA TP);
  - MLP + expert FFN shard d_ff over `model` (experts stay whole per shard:
    robust for 8 or 128 experts);
  - embedding shards vocab over `model`;
  - decode caches shard batch over `pod`+`data` when it divides, else the
    cache length dim (sequence-parallel cache for batch-1 long-context);
  - optimizer state mirrors the param tree.

`fsdp=True` additionally shards the largest param dim over `data`
(ZeRO-3-style; a beyond-paper §Perf option).
"""
from __future__ import annotations


import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models.config import ModelConfig

# Per-param-name PartitionSpec templates for UNSTACKED leaves
# (a leading layer-stack dim gets None prepended automatically).
_BY_NAME = {
    "tok": ("model", None),
    "unembed": (None, "model"),
    "wq": (None, "model", None),
    "wk": (None, "model", None),
    "wv": (None, "model", None),
    "wo": ("model", None, None),
    "bq": ("model", None),
    "bk": ("model", None),
    "bv": ("model", None),
    "w_gate": {2: (None, "model"), 3: (None, None, "model")},
    "w_up": {2: (None, "model"), 3: (None, None, "model")},
    "w_down": {2: ("model", None), 3: (None, "model", None)},
    "b_up": ("model",),
    "b_down": (None,),
    "router": (None, None),
    "w_in": (None, "model"),
    "w_out": ("model", None),
    "w_ff_gate": (None, "model"),
    "w_ff_up": (None, "model"),
    "w_ff_down": ("model", None),
    # replicated small/recurrent tensors
    "w_if": (None, None),
    "w_gates": (None, None),
    "r_gates": (None, None),
}

_FSDP_SKIP = {"tok", "unembed"}  # keep embeddings TP-only


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return entry.key
    return ""


def _base_ndim(name: str, template) -> int:
    if isinstance(template, dict):
        return -1  # resolved by ndim lookup
    return len(template)


def param_pspec(cfg: ModelConfig, mesh: Mesh, path, leaf,
                fsdp: bool = False, kv_hd_shard: bool = False,
                moe_ep: bool = False) -> P:
    name = _leaf_name(path)
    template = _BY_NAME.get(name)
    shape = leaf.shape
    in_moe = any(isinstance(e, jax.tree_util.DictKey) and e.key == "moe"
                 for e in path)
    if moe_ep and in_moe and name in ("w_gate", "w_up", "w_down") \
            and len(shape) >= 3:
        # expert-parallel: experts over `model` (dim -3), all-to-all dispatch
        t = [None] * len(shape)
        t[-3] = "model"
        spec = [shd.shardable(mesh, d, a) for d, a in zip(shape, t)]
        if spec[-3] is not None:
            return P(*spec)
    if kv_hd_shard and name in ("wk", "wv"):
        # GQA with n_kv < model-axis: shard the head_dim instead, matching
        # the decode cache layout (kills the cache-update reshard — §Perf).
        nkv = shape[-2]
        if shd.shardable(mesh, nkv, "model") is None:
            t = [None] * (len(shape) - 1) + ["model"]
            spec = [shd.shardable(mesh, d, a) for d, a in zip(shape, t)]
            return P(*spec)
    if template is None:
        spec = [None] * len(shape)
    else:
        if isinstance(template, dict):
            t = template.get(len(shape)) or template.get(len(shape) - 1)
            if t is None:
                spec = [None] * len(shape)
            else:
                t = list(t)
                if len(t) == len(shape) - 1:
                    t = [None] + t
                spec = t
        else:
            t = list(template)
            if len(t) == len(shape) - 1:      # stacked on a layer axis
                t = [None] + t
            elif len(t) != len(shape):
                t = [None] * len(shape)
            spec = t
    # drop non-divisible axes
    spec = [shd.shardable(mesh, d, a) for d, a in zip(shape, spec)]
    if fsdp and name not in _FSDP_SKIP:
        # shard the largest still-unsharded dim over data
        free = [i for i, a in enumerate(spec) if a is None]
        if free:
            i = max(free, key=lambda j: shape[j])
            if shape[i] % shd.axis_size(mesh, "data") == 0:
                spec[i] = "data"
    return P(*spec)


def param_shardings(cfg: ModelConfig, mesh: Mesh, params_shape,
                    fsdp: bool = False, kv_hd_shard: bool = False,
                    moe_ep: bool = False):
    return shd.tree_shardings(
        mesh, params_shape,
        lambda path, leaf: param_pspec(cfg, mesh, path, leaf, fsdp=fsdp,
                                       kv_hd_shard=kv_hd_shard, moe_ep=moe_ep))


# ---------------------------------------------------------------------------
# Batch / cache shardings
# ---------------------------------------------------------------------------

def batch_pspec(mesh: Mesh, shape) -> P:
    b = shd.batch_axes(mesh)
    spec = [b] + [None] * (len(shape) - 1)
    return shd.pspec(mesh, shape, spec)


def _cache_leaf_pspec(mesh: Mesh, name: str, shape,
                      kv_policy: str = "hd_model") -> P:
    b = shd.batch_axes(mesh)
    if name == "lengths":
        return shd.pspec(mesh, shape, [b])
    if name in ("k", "v", "cross_k", "cross_v"):
        # (L, B, S, n_kv, hd)
        L, B, S, nkv, hd = shape
        batch_ok = shd.shardable(mesh, B, b) is not None
        seq_axis = None if batch_ok else b
        kv_axis = "model" if shd.shardable(mesh, nkv, "model") else None
        if kv_policy == "replicate":
            # cache replicates over `model` when n_kv doesn't divide
            hd_axis = None
        elif kv_policy == "seq_model":
            # §Perf winner for GQA decode: shard the cache LENGTH over
            # `model` — QK contracts hd (local), PV partial-sums are tiny
            # (B,1,Nq,hd) all-reduces, and the position-`length` scatter
            # lands on one shard (proven collective-free by the batch-1
            # long_500k rows, which shard S over `data` the same way).
            return shd.pspec(mesh, shape, [None, b if batch_ok else None,
                                           "model" if batch_ok else b,
                                           None, None])
        else:
            hd_axis = None if kv_axis else "model"
        return shd.pspec(mesh, shape, [None, b if batch_ok else None,
                                       seq_axis, kv_axis, hd_axis])
    if name == "conv":      # (L, B, K-1, inner)
        return shd.pspec(mesh, shape, [None, b, None, "model"])
    if name == "ssd":       # (L, B, H, P, N)
        return shd.pspec(mesh, shape, [None, b, "model", None, None])
    if name == "C":         # mlstm (L, B, H, hd, hd)
        return shd.pspec(mesh, shape, [None, b, "model", None, None])
    if name in ("n",):      # (L, B, H, hd) or slstm (L, B, d)
        if len(shape) == 4:
            return shd.pspec(mesh, shape, [None, b, "model", None])
        return shd.pspec(mesh, shape, [None, b, "model"])
    if name in ("m", "h", "c"):
        spec = [None, b] + [None] * (len(shape) - 2)
        if len(shape) == 3:
            spec[2] = "model"
        return shd.pspec(mesh, shape, spec)
    return P(*([None] * len(shape)))


def cache_shardings(mesh: Mesh, cache_tree, kv_policy: str = "hd_model"):
    def spec_fn(path, leaf):
        name = _leaf_name(path)
        if not hasattr(leaf, "shape"):
            return P()
        return _cache_leaf_pspec(mesh, name, leaf.shape, kv_policy=kv_policy)
    return shd.tree_shardings(mesh, cache_tree, spec_fn)


def input_shardings(mesh: Mesh, specs: dict, kv_policy: str = "hd_model"):
    """NamedShardings for the input_specs() dict of a step function."""
    out = {}
    for k, v in specs.items():
        if k == "cache":
            out[k] = cache_shardings(mesh, v, kv_policy=kv_policy)
        elif hasattr(v, "shape"):
            out[k] = NamedSharding(mesh, batch_pspec(mesh, v.shape))
        else:
            out[k] = v
    return out
