"""Step-function builders shared by the trainer, server, and dry-run."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.training import losses as losses_lib
from repro.training import optimizer as opt_lib


def make_train_step(cfg: ModelConfig, opt_cfg: opt_lib.AdamWConfig, mesh=None):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    batch: {tokens, targets[, enc_frames][, prefix_embeds]}.
    """
    prefix_len = cfg.n_prefix_tokens if cfg.family == "vlm" else 0
    cdt = jnp.dtype(cfg.dtype)

    def _cast_once(params):
        # norm scales and small vectors stay f32 (layers upcast internally)
        return jax.tree.map(
            lambda p: p.astype(cdt)
            if (p.dtype == jnp.float32 and p.ndim >= 2) else p, params)

    def loss_fn(params, batch):
        if cfg.cast_params_once:
            params = _cast_once(params)
        logits, aux = transformer.forward(
            cfg, params, batch["tokens"],
            prefix_embeds=batch.get("prefix_embeds"),
            enc_frames=batch.get("enc_frames"),
            mesh=mesh)
        total, metrics = losses_lib.lm_loss(cfg, logits, batch["targets"], aux,
                                            prefix_len=prefix_len)
        return total, metrics

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        params, opt_state, opt_metrics = opt_lib.adamw_update(
            opt_cfg, params, grads, opt_state)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, mesh=None):
    def prefill_step(params, tokens, cache, prompt_lengths=None,
                     enc_frames=None, prefix_embeds=None):
        return transformer.prefill(cfg, params, tokens, cache,
                                   prefix_embeds=prefix_embeds,
                                   enc_frames=enc_frames,
                                   prompt_lengths=prompt_lengths, mesh=mesh)
    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh=None):
    def decode_step(params, tokens, cache):
        return transformer.decode_step(cfg, params, tokens, cache, mesh=mesh)
    return decode_step
