"""PICE serving launcher: build the cloud engine + edge fleet and run the
progressive pipeline on a stream of requests (real-compute, tiny models).

  PYTHONPATH=src python -m repro.launch.serve --requests 8 [--train-steps 150]

With --train-steps > 0 the tiny cloud/edge models are first trained on the
synthetic corpus so sketches/expansions are meaningful (quality metrics are
reported against the corpus ground truth).
"""
from __future__ import annotations

import argparse
import time


from repro.configs.pice_cloud_edge import (TINY_CLOUD, TINY_EDGE_CONFIGS)
from repro.core import metrics as metrics_lib
from repro.core.profiler import cost_coefficient, profile_engine
from repro.core.progressive import PICEConfig, PICEPipeline
from repro.core.scheduler import EdgeModelInfo
from repro.data import corpus as corpus_lib
from repro.data.pipeline import PackedDataset
from repro.serving.engine import InferenceEngine
from repro.serving.requests import Request
from repro.training import optimizer as opt_lib
from repro.training.train_loop import init_train_state, train


def build_engines(train_steps: int = 0, seed: int = 0, log_fn=print,
                  names=None, kv_backend: str = "paged"):
    engines = {}
    text = corpus_lib.lm_text(2000, seed)
    caps = {"tiny-cloud": 0.9, "tiny-edge-a": 0.7, "tiny-edge-b": 0.55,
            "tiny-edge-c": 0.6}
    pool = [("tiny-cloud", TINY_CLOUD)] + list(TINY_EDGE_CONFIGS.items())
    if names:
        pool = [(n, c) for n, c in pool if n in names]
    for name, cfg in pool:
        state = init_train_state(cfg, seed)
        if train_steps:
            ds = PackedDataset(text, 192, 8, seed)
            opt_cfg = opt_lib.AdamWConfig(lr=2e-3, warmup_steps=20,
                                          total_steps=train_steps)
            log_fn(f"-- training {name} for {train_steps} steps")
            state = train(cfg, state, iter(ds), opt_cfg, train_steps,
                          log_every=max(train_steps // 2, 1), log_fn=log_fn)
        engines[name] = InferenceEngine(cfg, state.params, max_batch=8,
                                        max_len=1024, name=name,
                                        kv_backend=kv_backend)
    return engines, caps


def build_pipeline(engines, caps, log_fn=print,
                   profile_lengths=(8, 16, 32)) -> PICEPipeline:
    cloud = engines["tiny-cloud"]
    lm_cloud = profile_engine(cloud, lengths=profile_lengths, name="tiny-cloud")
    infos = []
    for name, eng in engines.items():
        if name == "tiny-cloud":
            continue
        lm = profile_engine(eng, lengths=profile_lengths, name=name)
        c = cost_coefficient(lm_cloud, lm)
        log_fn(f"profiled {name}: rate={lm.rate:.1f} tok/s, c={c:.2f}")
        infos.append(EdgeModelInfo(name=name, latency=lm,
                                   capability=caps.get(name, 0.5)))
    edge_engines = {k: v for k, v in engines.items() if k != "tiny-cloud"}
    return PICEPipeline(cloud, edge_engines, lm_cloud, infos,
                        cfg=PICEConfig(ensemble_size=2))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kv-backend", choices=("dense", "paged"),
                    default="paged",
                    help="KV cache backend (paged = on-demand page pool)")
    args = ap.parse_args()

    engines, caps = build_engines(args.train_steps, args.seed,
                                  kv_backend=args.kv_backend)
    pipe = build_pipeline(engines, caps)
    examples = corpus_lib.corpus(args.requests, seed=args.seed + 7)
    t0 = time.time()
    quality = []
    for ex in examples:
        resp = pipe.handle(Request(query=ex.query, category=ex.category))
        q = metrics_lib.rouge_1(ex.answer, resp.text)[2]
        quality.append(q)
        print(f"[{resp.mode:12s}] lat={resp.latency_s:5.2f}s "
              f"cloud={resp.cloud_tokens:4d}t edge={resp.edge_tokens:4d}t "
              f"rouge1-f1={q:.3f} | {resp.text[:60]!r}")
    dt = time.time() - t0
    print(f"\n{args.requests} requests in {dt:.1f}s "
          f"({60*args.requests/dt:.1f} req/min); "
          f"mean quality={sum(quality)/len(quality):.3f}; stats={pipe.stats}")


if __name__ == "__main__":
    main()
