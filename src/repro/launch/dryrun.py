import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape), lower + compile the step function on
the production mesh (single-pod 16x16 = 256 chips, and multi-pod 2x16x16 =
512 chips), print memory_analysis() (fits?) and cost_analysis() (FLOPs/bytes
for the roofline), and parse collective traffic out of the optimized HLO.

NOTE: the 512-placeholder-device XLA flag above MUST precede every other
import (jax locks the device count at first init). Smoke tests and benches
run in separate processes and see 1 device.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/dryrun]
"""
import argparse
import gc
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.registry import SHAPES, adapt_for_shape, input_specs, shape_supported
from repro.distributed import hlo_analysis
from repro.launch import shardings as sh_lib
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh
from repro.models import transformer
from repro.training import optimizer as opt_lib


def _dryrun_config(cfg, shape):
    """Dry-run adaptations (documented in DESIGN.md / EXPERIMENTS.md):
    - unroll the layer stack so XLA cost analysis counts every layer;
    - chunk=64 for big-head SSDs keeps the intra-chunk decay tensor bounded.
    """
    cfg = adapt_for_shape(cfg, shape)
    over = {"scan_layers": False, "use_pallas": False}
    if cfg.family in ("ssm", "hybrid") and shape.seq_len >= 4096:
        over["ssm_chunk"] = 64
    return cfg.with_(**over)


def build_step(cfg, shape, mesh, fsdp: bool = False, kv_hd_shard: bool = False,
               kv_policy: str = "hd_model"):
    moe_ep = cfg.moe_ep
    """Returns (fn, arg_specs tuple, in_shardings, out_shardings, donate)."""
    specs = input_specs(cfg, SHAPES[shape.name] if isinstance(shape, str) else shape)
    params_shape = jax.eval_shape(lambda k: transformer.init_params(cfg, k),
                                  jax.ShapeDtypeStruct((2,), jnp.uint32))
    psh = sh_lib.param_shardings(cfg, mesh, params_shape, fsdp=fsdp,
                                 kv_hd_shard=kv_hd_shard, moe_ep=moe_ep)

    if shape.kind == "train":
        opt_cfg = opt_lib.AdamWConfig()
        opt_shape = jax.eval_shape(opt_lib.init_opt_state, params_shape)
        osh = jax.tree.map(
            lambda s: s, opt_lib.OptState(
                step=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
                mu=sh_lib.param_shardings(cfg, mesh, opt_shape.mu, fsdp=fsdp,
                                          kv_hd_shard=kv_hd_shard,
                                          moe_ep=moe_ep),
                nu=sh_lib.param_shardings(cfg, mesh, opt_shape.nu, fsdp=fsdp,
                                          kv_hd_shard=kv_hd_shard,
                                          moe_ep=moe_ep)))
        batch = {k: v for k, v in specs.items()}
        bsh = sh_lib.input_shardings(mesh, batch)
        fn = steps_lib.make_train_step(cfg, opt_cfg, mesh=mesh)
        return (fn, (params_shape, opt_shape, batch), (psh, osh, bsh),
                (psh, osh, None), (0, 1))

    if shape.kind == "prefill":
        cache = specs["cache"]
        csh = sh_lib.cache_shardings(mesh, cache, kv_policy=kv_policy)
        args = [params_shape, specs["tokens"], cache, specs["prompt_lengths"]]
        ash = [psh, sh_lib.input_shardings(mesh, {"t": specs["tokens"]})["t"],
               csh, sh_lib.input_shardings(mesh, {"l": specs["prompt_lengths"]})["l"]]
        extras, esh = [], []
        for key in ("enc_frames", "prefix_embeds"):
            if key in specs:
                extras.append(specs[key])
                esh.append(sh_lib.input_shardings(mesh, {key: specs[key]})[key])
            else:
                extras.append(None)
                esh.append(None)
        base = steps_lib.make_prefill_step(cfg, mesh=mesh)

        def fn(params, tokens, cache, plens, enc_frames, prefix_embeds):
            return base(params, tokens, cache, prompt_lengths=plens,
                        enc_frames=enc_frames, prefix_embeds=prefix_embeds)

        return (fn, tuple(args + extras), tuple(ash + esh),
                (None, csh), (2,))

    # decode
    cache = specs["cache"]
    csh = sh_lib.cache_shardings(mesh, cache, kv_policy=kv_policy)
    tsh = sh_lib.input_shardings(mesh, {"t": specs["tokens"]})["t"]
    fn = steps_lib.make_decode_step(cfg, mesh=mesh)
    return (fn, (params_shape, specs["tokens"], cache), (psh, tsh, csh),
            (None, csh), (2,))


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
            fsdp: bool = False, act_shard: str = None, tag: str = "",
            moe_sort: bool = False, kv_hd_shard: bool = False,
            chunked_ce: int = 0, kv_policy: str = "hd_model",
            moe_ep: bool = False, cast_once: bool = False) -> dict:
    shape = SHAPES[shape_name]
    cfg = registry.get_config(arch)
    skip = shape_supported(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "fsdp": fsdp, "tag": tag}
    if skip:
        rec.update(status="skipped", reason=skip)
        if out_dir:
            out_dir.mkdir(parents=True, exist_ok=True)
            suffix = f"__{tag}" if tag else ""
            fname = (f"{arch.replace('.', 'p')}__{shape_name}__{mesh_name}"
                     f"{suffix}.json")
            (out_dir / fname).write_text(json.dumps(rec, indent=1))
        return rec
    cfg = _dryrun_config(cfg, shape)
    if act_shard:
        cfg = cfg.with_(act_shard=act_shard)
    if moe_sort:
        cfg = cfg.with_(moe_sort_dispatch=True)
    if moe_ep:
        cfg = cfg.with_(moe_ep=True)
    if cast_once:
        cfg = cfg.with_(cast_params_once=True)
    if chunked_ce:
        from repro.training import losses as losses_lib
        losses_lib.CHUNKED_CE_BLOCK = chunked_ce
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        fn, args, in_sh, out_sh, donate = build_step(cfg, shape, mesh,
                                                     fsdp=fsdp,
                                                     kv_hd_shard=kv_hd_shard,
                                                     kv_policy=kv_policy)
        with mesh:
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        coll = hlo_analysis.collective_bytes(hlo)
        rec.update(
            status="ok",
            lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2),
            flops=float(cost.get("flops", 0.0)),
            bytes_accessed=float(cost.get("bytes accessed", 0.0)),
            collective_bytes={k: int(v) for k, v in coll.items()
                              if k != "counts"},
            collective_counts=coll["counts"],
            memory={
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
            },
            hlo_bytes=len(hlo),
        )
        print(f"[OK] {arch} x {shape_name} on {mesh_name}: "
              f"flops/dev={rec['flops']:.3e} bytes/dev={rec['bytes_accessed']:.3e} "
              f"coll={rec['collective_bytes']['total']:.3e}B "
              f"temp={rec['memory']['temp_bytes']} "
              f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)")
        del compiled, lowered, jitted
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[FAIL] {arch} x {shape_name} on {mesh_name}: {rec['error']}")
    finally:
        gc.collect()
    if out_dir:
        out_dir.mkdir(parents=True, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        fname = f"{arch.replace('.', 'p')}__{shape_name}__{mesh_name}{suffix}.json"
        (out_dir / fname).write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--act-shard", default=None)
    ap.add_argument("--moe-sort", action="store_true")
    ap.add_argument("--moe-ep", action="store_true")
    ap.add_argument("--kv-hd-shard", action="store_true")
    ap.add_argument("--kv-policy", default="hd_model",
                    choices=["hd_model", "replicate", "seq_model"])
    ap.add_argument("--chunked-ce", type=int, default=0)
    ap.add_argument("--cast-once", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    out_dir = Path(args.out)

    pairs = []
    if args.all:
        for arch in registry.ALIASES:
            for sname in SHAPES:
                pairs.append((arch, sname))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        pairs = [(args.arch, args.shape)]

    n_ok = n_fail = 0
    for arch, sname in pairs:
        mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
        suffix = f"__{args.tag}" if args.tag else ""
        fname = f"{arch.replace('.', 'p')}__{sname}__{mesh_name}{suffix}.json"
        if args.skip_existing and (out_dir / fname).exists():
            prev = json.loads((out_dir / fname).read_text())
            if prev.get("status") in ("ok", "skipped"):
                print(f"[SKIP existing] {arch} x {sname}")
                continue
        rec = run_one(arch, sname, args.multi_pod, out_dir, fsdp=args.fsdp,
                      act_shard=args.act_shard, tag=args.tag,
                      moe_sort=args.moe_sort, kv_hd_shard=args.kv_hd_shard,
                      chunked_ce=args.chunked_ce, kv_policy=args.kv_policy,
                      moe_ep=args.moe_ep, cast_once=args.cast_once)
        n_ok += rec["status"] in ("ok", "skipped")
        n_fail += rec["status"] == "error"
    print(f"dry-run sweep done: {n_ok} ok/skipped, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
