"""Training launcher.

Local mode (default): trains a reduced variant of --arch on the synthetic
corpus on this host's devices. Production mode (--dry-run): lowers the
full-size config on the production mesh (see dryrun.py for the full sweep).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --steps 50
"""
from __future__ import annotations

import argparse


from repro.configs import registry
from repro.data import corpus as corpus_lib
from repro.data.pipeline import PackedDataset
from repro.training import optimizer as opt_lib
from repro.training.checkpoint import save
from repro.training.train_loop import init_train_state, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = registry.get_config(args.arch).reduced(remat=False)
    print(f"training reduced {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"family={cfg.family}")
    text = corpus_lib.lm_text(3000, args.seed)
    ds = PackedDataset(text, args.seq_len, args.batch, args.seed)
    state = init_train_state(cfg, args.seed)
    opt_cfg = opt_lib.AdamWConfig(lr=args.lr, warmup_steps=20,
                                  total_steps=args.steps)
    state = train(cfg, state, iter(ds), opt_cfg, args.steps)
    if args.ckpt:
        path = save(args.ckpt, state.step, state.params)
        print(f"saved checkpoint to {path}")


if __name__ == "__main__":
    main()
