"""AdamW + gradient clipping + LR schedules, implemented from scratch.

Optimizer state is a pytree mirroring params, so it inherits the same
NamedSharding tree under pjit.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    schedule: str = "cosine"     # cosine | linear | constant


class OptState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        if cfg.schedule == "linear":
            decay = 1.0 - (1.0 - cfg.min_lr_ratio) * frac
        else:  # cosine
            decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * 0.5 * (
                1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * decay


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState
                 ) -> Tuple[dict, OptState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip \
        else jnp.ones(())
    step = state.step + 1
    lr = lr_at(cfg, state.step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:   # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state.mu)
    flat_nu = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step=step, mu=new_mu, nu=new_nu), metrics
