"""Checkpointing: numpy-archive pytree serialization (no external deps).

Layout: <dir>/<step>/arrays.npz + tree.json (structure with leaf indices).
Works for params, optimizer state, or any array pytree; restores exact
dtypes/shapes and validates against a template when given.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> tuple:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    path = Path(ckpt_dir) / str(step)
    path.mkdir(parents=True, exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays, dtypes = {}, {}
    for i, l in enumerate(leaves):
        a = np.asarray(l)
        dtypes[str(i)] = str(a.dtype)
        if a.dtype.kind not in "biufc":      # ml_dtypes (bfloat16 etc.)
            a = a.view(np.uint16) if a.dtype.itemsize == 2 else a.view(np.uint8)
        arrays[f"leaf_{i}"] = a
    np.savez(path / "arrays.npz", **arrays)
    (path / "tree.json").write_text(json.dumps({
        "treedef": str(treedef), "n_leaves": len(leaves), "step": step,
        "dtypes": dtypes}))
    return str(path)


def restore(ckpt_dir: str, step: Optional[int], template: Any) -> Any:
    base = Path(ckpt_dir)
    if step is None:
        steps = sorted((int(p.name) for p in base.iterdir()
                        if p.name.isdigit()), reverse=True)
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
        step = steps[0]
    path = base / str(step)
    data = np.load(path / "arrays.npz")
    meta = json.loads((path / "tree.json").read_text())
    dtypes = meta.get("dtypes", {})
    leaves, treedef = _flatten(template)
    out = []
    for i, tpl in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        saved_dt = dtypes.get(str(i))
        if saved_dt and str(arr.dtype) != saved_dt:
            import ml_dtypes  # packaged with jax
            arr = arr.view(np.dtype(getattr(ml_dtypes, saved_dt, saved_dt)))
        if hasattr(tpl, "shape") and tuple(arr.shape) != tuple(tpl.shape):
            raise ValueError(f"leaf {i}: checkpoint shape {arr.shape} != "
                             f"template {tpl.shape}")
        out.append(jnp.asarray(arr, dtype=getattr(tpl, "dtype", arr.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    base = Path(ckpt_dir)
    if not base.exists():
        return None
    steps = [int(p.name) for p in base.iterdir() if p.name.isdigit()]
    return max(steps) if steps else None
