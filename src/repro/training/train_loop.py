"""Generic training loop over jitted train steps (single-host or pjit)."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator

import jax
import jax.numpy as jnp

from repro.launch import steps as steps_lib
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.training import optimizer as opt_lib


@dataclasses.dataclass
class TrainState:
    params: dict
    opt_state: opt_lib.OptState
    step: int = 0


def init_train_state(cfg: ModelConfig, seed: int = 0) -> TrainState:
    params = transformer.init_params(cfg, jax.random.PRNGKey(seed))
    return TrainState(params=params, opt_state=opt_lib.init_opt_state(params))


def train(cfg: ModelConfig, state: TrainState, batches: Iterator,
          opt_cfg: opt_lib.AdamWConfig, n_steps: int, mesh=None,
          log_every: int = 20, log_fn: Callable = print,
          masked: bool = False) -> TrainState:
    """batches yields (tokens, targets) or (tokens, targets, mask)."""
    if masked:
        def step_fn(params, opt_state, batch):
            def loss_fn(params):
                logits, aux = transformer.forward(cfg, params, batch["tokens"],
                                                  mesh=mesh)
                from repro.training.losses import cross_entropy
                loss, n = cross_entropy(logits, batch["targets"], batch["mask"])
                return loss + cfg.router_aux_coef * aux, {"nll": loss}
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
            params, opt_state, om = opt_lib.adamw_update(opt_cfg, params, grads,
                                                         opt_state)
            return params, opt_state, dict(metrics, loss=loss, **om)
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
    else:
        jit_step = jax.jit(steps_lib.make_train_step(cfg, opt_cfg, mesh=mesh),
                           donate_argnums=(0, 1))

    t0 = time.time()
    for i in range(n_steps):
        b = next(batches)
        if masked:
            batch = {"tokens": jnp.asarray(b[0]), "targets": jnp.asarray(b[1]),
                     "mask": jnp.asarray(b[2])}
        else:
            batch = {"tokens": jnp.asarray(b[0]), "targets": jnp.asarray(b[1])}
        state.params, state.opt_state, metrics = jit_step(
            state.params, state.opt_state, batch)
        state.step += 1
        if (i + 1) % log_every == 0 or i == n_steps - 1:
            # repro-analysis: disable=RA103 reason=log-interval readback; one transfer per log_every steps instead of one sync per metric
            metrics_h = jax.device_get(metrics)
            m = {k: float(v) for k, v in metrics_h.items()}
            log_fn(f"step {state.step:5d} loss={m['loss']:.4f} "
                   f"nll={m.get('nll', 0):.4f} "
                   f"({(time.time()-t0)/(i+1):.3f}s/step)")
    return state
