"""Training losses."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

# When > 0, cross_entropy processes the sequence in blocks of this many
# positions via lax.map, so the f32-upcast logits tensor is never
# materialized at (B, S, V) — a §Perf memory-term optimization for
# large-vocab training (set via launch/dryrun --chunked-ce).
CHUNKED_CE_BLOCK = 0


def _ce_terms(logits, targets):
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return lse - gold


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  mask: Optional[jax.Array] = None):
    """logits: (B,S,V) -> mean NLL over unmasked positions.

    Returns (loss, n_tokens). Computed in f32 with logsumexp stability.
    """
    S = logits.shape[1]
    blk = CHUNKED_CE_BLOCK
    if blk and S > blk and S % blk == 0:
        nb = S // blk

        def block(i):
            lg = jax.lax.dynamic_slice_in_dim(logits, i * blk, blk, axis=1)
            tg = jax.lax.dynamic_slice_in_dim(targets, i * blk, blk, axis=1)
            return _ce_terms(lg, tg)

        nll = jnp.moveaxis(jax.lax.map(block, jnp.arange(nb)), 0, 1)
        nll = nll.reshape(targets.shape)
    else:
        nll = _ce_terms(logits, targets)
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    n = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll * mask) / n, n


def lm_loss(cfg: ModelConfig, logits: jax.Array, targets: jax.Array,
            aux: jax.Array, mask: Optional[jax.Array] = None,
            prefix_len: int = 0):
    """Causal LM loss; drops `prefix_len` leading positions (VLM patch stub)."""
    if prefix_len:
        logits = logits[:, prefix_len:]
    loss, n = cross_entropy(logits, targets, mask)
    total = loss + cfg.router_aux_coef * aux
    return total, {"nll": loss, "aux": aux, "tokens": n,
                   "perplexity": jnp.exp(loss)}
