"""Pure-jnp oracle for single-token decode attention over a KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """q: (B,1,Hq,hd); k/v_cache: (B,S,Hkv,hd); lengths: (B,) valid entries.

    Attends the single new query against cache positions [0, lengths).
    Returns (B,1,Hq,hd).
    """
    B, _, Hq, hd = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    rep = Hq // Hkv
    k = jnp.repeat(k_cache, rep, axis=2) if rep > 1 else k_cache
    v = jnp.repeat(v_cache, rep, axis=2) if rep > 1 else v_cache
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    logits = jnp.einsum("bqnh,bknh->bnqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale          # (B,Hq,1,S)
    mask = (jnp.arange(S)[None, :] < lengths[:, None])[:, None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bnqk,bknh->bqnh", probs.astype(v.dtype), v)
