"""Jitted public wrapper for flash-decode attention."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.decode_attention import kernel as _kernel
from repro.kernels.runtime import resolve_interpret


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(q, k_cache, v_cache, lengths, block_s: int = 256,
                     interpret: Optional[bool] = None):
    """Single-token GQA attention over a (possibly ragged) KV cache.

    q: (B,1,Hq,hd); k/v_cache: (B,S,Hkv,hd); lengths: (B,) valid cache sizes.
    """
    return _kernel.decode_attention_pallas(
        q, k_cache, v_cache, lengths, block_s=block_s,
        interpret=resolve_interpret(interpret))
