"""Pallas TPU flash-decode kernel: one new token vs a long KV cache.

This is the hot spot PICE's sketch-shortening targets: at 32k context the
paper measures KV-cache reads at >50% of decode latency. On TPU the decode
step is HBM-bandwidth-bound — each generated token must stream the entire
(B, S, Hkv, hd) cache HBM->VMEM. The kernel:

  * processes all `q_per_kv` query heads of one KV head together, so each
    streamed KV block is reused q_per_kv times (GQA arithmetic-intensity win;
    the GPU analogue reuses via shared memory, here it is one VMEM tile);
  * walks the cache in (block_s, hd) VMEM tiles along the sequential minor
    grid axis with a running-softmax scratch (flash-decode);
  * prunes tail blocks past `lengths` with pl.when (ragged batches read only
    ceil(len / block_s) blocks);
  * a final block that overhangs S (S not a multiple of block_s) is masked
    in-kernel, NOT absorbed by shrinking block_s — e.g. S=300 must tile as
    2x256-class blocks, not 75 blocks of 4.

Grid: (B, Hkv, ceil(S / block_s)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _dec_kernel(len_ref,                       # scalar prefetch: (B,) lengths
                q_ref, k_ref, v_ref, o_ref,
                m_scr, l_scr, acc_scr,
                *, ns: int, bs: int, scale: float):
    b = pl.program_id(0)
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    s_start = si * bs

    @pl.when(s_start < length)
    def _body():
        kpos = s_start + jax.lax.broadcasted_iota(jnp.int32, (bs, 1), 0)
        valid = kpos < length                       # (bs, 1)
        q = q_ref[0, 0].astype(jnp.float32)         # (q_per_kv, hd)
        # zero invalid rows BEFORE the matmul: a ragged final block (S not a
        # multiple of bs) overhangs the cache and reads unspecified padding
        # that must not reach the MXU as NaN/inf
        k = jnp.where(valid, k_ref[0, 0].astype(jnp.float32), 0.0)
        v = jnp.where(valid, v_ref[0, 0].astype(jnp.float32), 0.0)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid[:, 0][None, :], s, NEG_INF)

        m_prev = m_scr[...][:, 0]
        l_prev = l_scr[...][:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = (l_prev * alpha + jnp.sum(p, axis=1))[:, None]
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new[:, None]

    @pl.when(si == ns - 1)
    def _finish():
        l = l_scr[...][:, 0]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(q, k_cache, v_cache, lengths, *, block_s: int = 256,
                            interpret: bool = True):
    """q: (B,1,Hq,hd); k/v_cache: (B,S,Hkv,hd); lengths (B,). -> (B,1,Hq,hd)."""
    B, _, Hq, hd = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    rep = Hq // Hkv
    # a non-power-of-two S keeps the full block size; the overhanging final
    # block is masked in-kernel (shrinking bs here degraded S=300 to bs=4)
    bs = min(block_s, S)
    ns = -(-S // bs)

    # (B, Hkv, q_per_kv, hd): group q heads by their kv head
    qg = q[:, 0].reshape(B, Hkv, rep, hd)
    kf = jnp.moveaxis(k_cache, 2, 1)               # (B, Hkv, S, hd)
    vf = jnp.moveaxis(v_cache, 2, 1)

    kernel = functools.partial(_dec_kernel, ns=ns, bs=bs,
                               scale=1.0 / float(hd) ** 0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, ns),
        in_specs=[
            pl.BlockSpec((1, 1, rep, hd), lambda b, h, s, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, h, s, *_: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, h, s, *_: (b, h, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hd), lambda b, h, s, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rep, hd), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, kf, vf)
    return out.reshape(B, 1, Hq, hd)
