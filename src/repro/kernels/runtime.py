"""Shared kernel-runtime policy helpers.

Every Pallas wrapper in repro.kernels takes `interpret: Optional[bool]`;
`None` resolves through `default_interpret()` so the same call sites compile
to real Mosaic kernels on TPU and fall back to interpret mode everywhere
else (CPU tests / CI) without per-caller plumbing.
"""
from __future__ import annotations

from typing import Optional

import jax


def default_interpret() -> bool:
    """True when Pallas must run in interpret mode (no TPU backend)."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Caller override if given, else the backend default."""
    return default_interpret() if interpret is None else bool(interpret)
