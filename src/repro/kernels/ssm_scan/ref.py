"""Pure-jnp oracle for the Mamba2 SSD chunked scan.

Recurrence (per batch b, head h):
    h_t = exp(dt_t * A_h) * h_{t-1} + dt_t * B_t (outer) x_t
    y_t = C_t^T h_t
with h in R^{P x N} (head_dim x state), B/C shared across heads (n_groups=1).

Two references:
  ssd_sequential_ref — literal per-token scan (ground truth for tests)
  ssd_chunked_ref    — chunked parallel form (used by the models; also the
                       oracle for the Pallas kernel)
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


def ssd_sequential_ref(x, dt, A, B, C, initial_state=None):
    """x: (Bb,S,H,P), dt: (Bb,S,H), A: (H,), B/C: (Bb,S,N).

    Returns y (Bb,S,H,P), final_state (Bb,H,P,N). All math in f32.
    """
    Bb, S, H, P = x.shape
    N = B.shape[-1]
    x, dt, B, C = (a.astype(jnp.float32) for a in (x, dt, B, C))
    A = A.astype(jnp.float32)
    h0 = (jnp.zeros((Bb, H, P, N), jnp.float32)
          if initial_state is None else initial_state.astype(jnp.float32))

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp            # (Bb,H,P), (Bb,H), (Bb,N), (Bb,N)
        decay = jnp.exp(dt_t * A[None])      # (Bb,H)
        upd = (dt_t[..., None] * x_t)[..., None] * B_t[:, None, None, :]
        h = h * decay[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", h, C_t)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0))
    hf, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), hf


def ssd_chunked_ref(x, dt, A, B, C, chunk: int = 128, initial_state=None):
    """Chunked-parallel SSD. Same signature/semantics as ssd_sequential_ref."""
    Bb, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q //= 2
    nc = S // Q
    x, dt, B, C = (a.astype(jnp.float32) for a in (x, dt, B, C))
    A = A.astype(jnp.float32)

    xc = x.reshape(Bb, nc, Q, H, P)
    dtc = dt.reshape(Bb, nc, Q, H)
    Bc = B.reshape(Bb, nc, Q, N)
    Cc = C.reshape(Bb, nc, Q, N)

    dA = dtc * A[None, None, None, :]                  # (Bb,nc,Q,H) log-decays
    ca = jnp.cumsum(dA, axis=2)                        # inclusive cumsum
    ca_end = ca[:, :, -1:]                             # (Bb,nc,1,H)

    # intra-chunk: y[t] = sum_{s<=t} exp(ca_t - ca_s) dt_s (C_t.B_s) x_s
    decay = ca[:, :, :, None, :] - ca[:, :, None, :, :]      # (Bb,nc,Q,Q,H) t,s
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(tri[None, None, :, :, None], decay, -jnp.inf)
    L = jnp.exp(decay)
    cb = jnp.einsum("bcqn,bcsn->bcqs", Cc, Bc)               # (Bb,nc,Q,Q)
    w = cb[..., None] * L * dtc[:, :, None, :, :]            # (Bb,nc,Q,Q,H)
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", w, xc)

    # chunk state contributions: G_c = sum_s exp(ca_end - ca_s) dt_s B_s (x) x_s
    kdecay = jnp.exp(ca_end - ca) * dtc                      # (Bb,nc,Q,H)
    G = jnp.einsum("bcqh,bcqn,bcqhp->bchpn", kdecay, Bc, xc)  # (Bb,nc,H,P,N)

    # inter-chunk scan of states
    h0 = (jnp.zeros((Bb, H, P, N), jnp.float32)
          if initial_state is None else initial_state.astype(jnp.float32))
    chunk_decay = jnp.exp(ca_end[:, :, 0])                   # (Bb,nc,H)

    def step(h, inp):
        G_c, dec_c = inp                                     # (Bb,H,P,N), (Bb,H)
        h_new = h * dec_c[..., None, None] + G_c
        return h_new, h                                      # emit state BEFORE chunk

    xs = (jnp.moveaxis(G, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    hf, h_prevs = jax.lax.scan(step, h0, xs)                 # h_prevs (nc,Bb,H,P,N)
    h_prev = jnp.moveaxis(h_prevs, 0, 1)                     # (Bb,nc,H,P,N)

    # state contribution within chunk: y_state[t] = exp(ca_t) C_t . h_prev
    qdecay = jnp.exp(ca)                                     # (Bb,nc,Q,H)
    y_state = jnp.einsum("bcqn,bchpn->bcqhp", Cc, h_prev) * qdecay[..., None]

    y = (y_intra + y_state).reshape(Bb, S, H, P)
    return y, hf
