"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

TPU adaptation: the GPU Mamba2 kernel parallelizes over (batch, head) blocks
with warp-level intra-chunk matmuls. On TPU we map the chunk loop onto the
*sequential* minor grid dimension (TPU grids execute in order), carrying the
(P, N) recurrent state in a VMEM scratch accumulator — the same pattern flash
attention uses for its running softmax. Intra-chunk work is MXU matmuls on
(Q, N) x (N, Q) and (Q, Q) x (Q, P) tiles; Q and N are chosen as multiples of
128 for MXU alignment (P=64 packs two heads per lane tile in practice; we keep
P free and let Mosaic pick the layout).

Grid: (B*H, S // Q) — state scratch persists across the minor (chunk) axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,   # inputs
                y_ref, state_out_ref,                 # outputs
                h_scratch,                            # scratch (P, N) f32
                *, nc: int):
    """One (batch*head, chunk) step.

    x_ref: (Q, P); dt_ref: (Q, 1); a_ref: (1, 1); b_ref/c_ref: (Q, N);
    y_ref: (Q, P); state_out_ref: (P, N); h_scratch: (P, N).
    """
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    x = x_ref[0].astype(jnp.float32)                   # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)                 # (Q, 1)
    A = a_ref[0, 0, 0].astype(jnp.float32)             # scalar
    B = b_ref[0].astype(jnp.float32)                   # (Q, N)
    C = c_ref[0].astype(jnp.float32)                   # (Q, N)
    Q = x.shape[0]

    dA = dt[:, 0] * A                                  # (Q,)
    ca = jnp.cumsum(dA)                                # inclusive
    ca_end = ca[-1]

    # intra-chunk
    decay = ca[:, None] - ca[None, :]                  # (Q, Q) t,s
    tri = jnp.tril(jnp.ones((Q, Q), jnp.bool_))
    L = jnp.where(tri, jnp.exp(decay), 0.0)
    cb = jnp.dot(C, B.T, preferred_element_type=jnp.float32)   # (Q, Q)
    w = cb * L * dt[None, :, 0]                        # weight[t, s]
    y_intra = jnp.dot(w, x, preferred_element_type=jnp.float32)  # (Q, P)

    # state contribution from previous chunks
    h_prev = h_scratch[...]                            # (P, N)
    y_state = jnp.dot(C, h_prev.T, preferred_element_type=jnp.float32)  # (Q, P)
    y_state = y_state * jnp.exp(ca)[:, None]
    y_ref[0] = (y_intra + y_state).astype(y_ref.dtype)

    # update carried state: h = exp(ca_end) h_prev + sum_s exp(ca_end-ca_s) dt_s x_s B_s^T
    kdecay = jnp.exp(ca_end - ca) * dt[:, 0]           # (Q,)
    G = jnp.dot((x * kdecay[:, None]).T, B,
                preferred_element_type=jnp.float32)    # (P, N)
    h_new = h_prev * jnp.exp(ca_end) + G
    h_scratch[...] = h_new

    @pl.when(ci == nc - 1)
    def _emit():
        state_out_ref[0] = h_new.astype(state_out_ref.dtype)


def ssd_pallas(x, dt, A, B, C, chunk: int = 128, interpret: bool = True):
    """x: (Bb,S,H,P), dt: (Bb,S,H), A: (H,), B/C: (Bb,S,N).

    Returns (y (Bb,S,H,P) f32, final_state (Bb,H,P,N) f32).
    Zero initial state (models pass prefill-from-scratch here; decode uses the
    recurrent jnp step).
    """
    Bb, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q //= 2
    nc = S // Q

    # flatten (batch, head) onto the parallel grid axis
    xf = jnp.moveaxis(x, 2, 1).reshape(Bb * H, S, P)
    dtf = jnp.moveaxis(dt, 2, 1).reshape(Bb * H, S, 1)
    af = jnp.tile(A.reshape(1, H, 1, 1), (Bb, 1, 1, 1)).reshape(Bb * H, 1, 1)
    bf = jnp.repeat(B[:, None], H, axis=1).reshape(Bb * H, S, N)
    cf = jnp.repeat(C[:, None], H, axis=1).reshape(Bb * H, S, N)

    kernel = functools.partial(_ssd_kernel, nc=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=(Bb * H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, Q, 1), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, 1, 1), lambda g, c: (g, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, Q, N), lambda g, c: (g, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, P, N), lambda g, c: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb * H, S, P), jnp.float32),
            jax.ShapeDtypeStruct((Bb * H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xf, dtf, af, bf, cf)
    y = jnp.moveaxis(y.reshape(Bb, H, S, P), 1, 2)
    state = state.reshape(Bb, H, P, N)
    return y, state
