"""Jitted public wrapper for the SSD scan kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan import kernel as _kernel
from repro.kernels.runtime import resolve_interpret


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan(x, dt, A, B, C, chunk: int = 128, initial_state=None,
             interpret: Optional[bool] = None):
    """Mamba2 SSD scan. See ref.ssd_sequential_ref for semantics.

    The Pallas kernel computes from a zero initial state; a caller-provided
    initial_state is folded in analytically:
        y_extra[t] = C_t . (prod_{s<=t} decay_s) h0  ,  via the same cumsum.
    """
    y, state = _kernel.ssd_pallas(x, dt, A, B, C, chunk=chunk,
                                  interpret=resolve_interpret(interpret))
    if initial_state is not None:
        dA = dt.astype(jnp.float32) * A.astype(jnp.float32)[None, None, :]
        ca = jnp.cumsum(dA, axis=1)                       # (Bb,S,H)
        h0 = initial_state.astype(jnp.float32)            # (Bb,H,P,N)
        y0 = jnp.einsum("bsn,bhpn->bshp", C.astype(jnp.float32), h0)
        y = y + y0 * jnp.exp(ca)[..., None]
        state = state + h0 * jnp.exp(ca[:, -1])[..., None, None]
    return y, state
