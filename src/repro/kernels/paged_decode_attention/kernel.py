"""Pallas TPU paged flash-decode kernel: one new token vs a paged KV pool.

The serving engine's paged backend (models/paged_cache.py) stores KV in a
per-layer page pool `(n_pages, page_size, n_kv, hd)` addressed through a
block table `(B, P)`. The jnp oracle first *gathers* every slot's full
table width into a contiguous `(B, P * page_size, n_kv, hd)` buffer — per
layer, per token, sized by the table width rather than actual lengths —
and only then attends. At long context that double-pays the PICE decode
hot spot (KV reads are >50% of decode latency); this kernel removes the
gather entirely:

  * `(block_table, lengths)` are scalar-prefetched, and the block table IS
    the K/V `index_map`: grid step (b, h, p) streams physical page
    `block_table[b, p]` HBM->VMEM directly from the pool. No contiguous
    copy ever exists.
  * steps past a slot's live pages re-map to its last live page — Pallas
    elides the DMA for a revisited block — and `pl.when` skips their
    compute, so per-step read volume is O(sum ceil(len/page)) pages, not
    O(B * max_pages_per_seq).
  * unmapped (-1) pages and in-page positions past `length` are pruned /
    masked; COW-shared pages (fan-out forks) are just page ids that happen
    to repeat across rows — each reader streams the page once, instead of
    the gather re-materializing it N times.
  * all `q_per_kv` query heads of one KV head ride each streamed page tile
    (same GQA arithmetic-intensity reuse as the dense decode kernel), with
    a running-softmax scratch accumulated across pages (flash-decode).

Grid: (B, Hkv, P) with P = block-table width (callers should pre-trim it
to the live width). Rows with length 0 return zeros.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _paged_dec_kernel(tbl_ref,                 # scalar prefetch: (B, P) pages
                      len_ref,                 # scalar prefetch: (B,) lengths
                      q_ref, k_ref, v_ref, o_ref,
                      m_scr, l_scr, acc_scr,
                      *, np_: int, ps: int, scale: float):
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    page = tbl_ref[b, pi]
    s_start = pi * ps

    # live page with tokens to attend: unmapped (-1) and past-length pages
    # contribute nothing and are skipped (their block was not re-fetched
    # either — see the clamped index_map in paged_decode_attention_pallas)
    @pl.when((s_start < length) & (page >= 0))
    def _body():
        kpos = s_start + jax.lax.broadcasted_iota(jnp.int32, (ps, 1), 0)
        valid = kpos < length                       # (ps, 1)
        q = q_ref[0, 0].astype(jnp.float32)         # (q_per_kv, hd)
        # zero invalid rows BEFORE the matmul: a ragged tail page holds
        # stale pool bytes that must not reach the MXU as NaN/inf
        k = jnp.where(valid, k_ref[0].astype(jnp.float32)[:, 0], 0.0)
        v = jnp.where(valid, v_ref[0].astype(jnp.float32)[:, 0], 0.0)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid[:, 0][None, :], s, NEG_INF)

        m_prev = m_scr[...][:, 0]
        l_prev = l_scr[...][:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = (l_prev * alpha + jnp.sum(p, axis=1))[:, None]
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new[:, None]

    @pl.when(pi == np_ - 1)
    def _finish():
        l = l_scr[...][:, 0]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def paged_decode_attention_pallas(q, k_pages, v_pages, block_table, lengths,
                                  *, interpret: bool = True):
    """q: (B,1,Hq,hd); k/v_pages: (n_pages, page, Hkv, hd);
    block_table: (B, P) int32 page ids (-1 = unmapped); lengths: (B,) valid
    token counts. -> (B,1,Hq,hd); zero-length rows return zeros."""
    B, _, Hq, hd = q.shape
    ps, Hkv = k_pages.shape[1], k_pages.shape[2]
    P = block_table.shape[1]
    rep = Hq // Hkv
    table = block_table.astype(jnp.int32)
    lens = lengths.astype(jnp.int32)

    # (B, Hkv, q_per_kv, hd): group q heads by their kv head
    qg = q[:, 0].reshape(B, Hkv, rep, hd)

    def kv_map(b, h, p, tbl_ref, len_ref):
        # steps past the live range re-stream the last live page: Pallas
        # skips the DMA for a block index equal to the previous step's, so
        # pruned pages cost neither bandwidth nor compute
        n_live = jax.lax.div(len_ref[b] + ps - 1, ps)
        pi = jnp.minimum(p, jnp.maximum(n_live - 1, 0))
        pg = tbl_ref[b, pi]
        return (jnp.maximum(pg, 0), 0, h, 0)

    kernel = functools.partial(_paged_dec_kernel, np_=P, ps=ps,
                               scale=1.0 / float(hd) ** 0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, P),
        in_specs=[
            pl.BlockSpec((1, 1, rep, hd), lambda b, h, p, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, hd), kv_map),
            pl.BlockSpec((1, ps, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hd),
                               lambda b, h, p, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rep, hd), q.dtype),
        interpret=interpret,
    )(table, lens, qg, k_pages, v_pages)
    return out.reshape(B, 1, Hq, hd)


def _paged_dec_kernel_quant(tbl_ref,           # scalar prefetch: (B, P) pages
                            len_ref,           # scalar prefetch: (B,) lengths
                            q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
                            m_scr, l_scr, acc_scr,
                            *, np_: int, ps: int, scale: float):
    """Quantized-pool variant: identical flash-decode loop, but each page
    tile is dequantized in VMEM right after the DMA with its streamed
    per-(page, kv-head) scale scalar — HBM reads stay at the storage dtype
    width."""
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    page = tbl_ref[b, pi]
    s_start = pi * ps

    @pl.when((s_start < length) & (page >= 0))
    def _body():
        kpos = s_start + jax.lax.broadcasted_iota(jnp.int32, (ps, 1), 0)
        valid = kpos < length                       # (ps, 1)
        q = q_ref[0, 0].astype(jnp.float32)         # (q_per_kv, hd)
        # dequantize in-VMEM: stale rows past `length` are zeroed before
        # the MXU, same as the float kernel
        k = jnp.where(valid,
                      k_ref[0].astype(jnp.float32)[:, 0] * ks_ref[0, 0], 0.0)
        v = jnp.where(valid,
                      v_ref[0].astype(jnp.float32)[:, 0] * vs_ref[0, 0], 0.0)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid[:, 0][None, :], s, NEG_INF)

        m_prev = m_scr[...][:, 0]
        l_prev = l_scr[...][:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = (l_prev * alpha + jnp.sum(p, axis=1))[:, None]
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new[:, None]

    @pl.when(pi == np_ - 1)
    def _finish():
        l = l_scr[...][:, 0]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def paged_decode_attention_quant_pallas(q, k_pages, v_pages, k_scales,
                                        v_scales, block_table, lengths,
                                        *, interpret: bool = True):
    """`paged_decode_attention_pallas` over a quantized pool.

    k/v_pages: (n_pages, page, Hkv, hd) int8 / fp8; k/v_scales: (n_pages,
    Hkv) f32 per-(page, kv-head) dequant scales, streamed as (1, 1) blocks
    through the same clamped block-table index map as their page."""
    B, _, Hq, hd = q.shape
    ps, Hkv = k_pages.shape[1], k_pages.shape[2]
    P = block_table.shape[1]
    rep = Hq // Hkv
    table = block_table.astype(jnp.int32)
    lens = lengths.astype(jnp.int32)

    qg = q[:, 0].reshape(B, Hkv, rep, hd)

    def kv_map(b, h, p, tbl_ref, len_ref):
        n_live = jax.lax.div(len_ref[b] + ps - 1, ps)
        pi = jnp.minimum(p, jnp.maximum(n_live - 1, 0))
        pg = tbl_ref[b, pi]
        return (jnp.maximum(pg, 0), 0, h, 0)

    def scale_map(b, h, p, tbl_ref, len_ref):
        # same page clamp as kv_map, on the (n_pages, Hkv) scale tensor
        n_live = jax.lax.div(len_ref[b] + ps - 1, ps)
        pi = jnp.minimum(p, jnp.maximum(n_live - 1, 0))
        pg = tbl_ref[b, pi]
        return (jnp.maximum(pg, 0), h)

    kernel = functools.partial(_paged_dec_kernel_quant, np_=P, ps=ps,
                               scale=1.0 / float(hd) ** 0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, P),
        in_specs=[
            pl.BlockSpec((1, 1, rep, hd), lambda b, h, p, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, hd), kv_map),
            pl.BlockSpec((1, ps, 1, hd), kv_map),
            pl.BlockSpec((1, 1), scale_map),
            pl.BlockSpec((1, 1), scale_map),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hd),
                               lambda b, h, p, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rep, hd), q.dtype),
        interpret=interpret,
    )(table, lens, qg, k_pages, v_pages, k_scales, v_scales)
    return out.reshape(B, 1, Hq, hd)
