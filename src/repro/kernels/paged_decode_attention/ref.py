"""Pure-jnp oracle for paged decode attention: gather-then-attend.

This is exactly the serving engine's fallback read path — materialize each
slot's block table into the contiguous layout, then run masked attention —
kept as the numerics contract for the Pallas kernel. The deliberate
inefficiency (reading the full table width per step) is what the kernel
removes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import paged_cache as pc

NEG_INF = -1e30


def paged_decode_attention_ref(q, k_pages, v_pages, block_table, lengths):
    """q: (B,1,Hq,hd); k/v_pages: (n_pages, page, Hkv, hd); block_table:
    (B, P) int32 (-1 = unmapped); lengths: (B,) valid token counts.
    Returns (B,1,Hq,hd); zero-length rows return zeros (matching the
    kernel), not the uniform-softmax garbage of an all-masked SDPA."""
    B, _, Hq, hd = q.shape
    Hkv = k_pages.shape[2]
    rep = Hq // Hkv
    gk = pc.gather_sequence(k_pages, block_table)     # (B, P*page, Hkv, hd)
    gv = pc.gather_sequence(v_pages, block_table)
    S = gk.shape[1]
    k = jnp.repeat(gk, rep, axis=2) if rep > 1 else gk
    v = jnp.repeat(gv, rep, axis=2) if rep > 1 else gv
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    logits = jnp.einsum("bqnh,bknh->bnqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale       # (B,Hq,1,S)
    mask = (jnp.arange(S)[None, :] < lengths[:, None])[:, None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bnqk,bknh->bqnh", probs.astype(v.dtype), v)
    return jnp.where((lengths > 0)[:, None, None, None], out,
                     jnp.zeros_like(out))


def paged_decode_attention_quant_ref(q, k_pages, v_pages, k_scales, v_scales,
                                     block_table, lengths):
    """Quantized-pool oracle: dequantize-gather into the contiguous f32
    layout, then attend exactly as the float oracle. Kernel-vs-this is a
    reduction-order comparison (tight tolerance); this-vs-the-float-pool
    oracle is the quantization tolerance contract (docs/serving.md)."""
    gk = pc.gather_sequence_dequant(k_pages, k_scales, block_table)
    gv = pc.gather_sequence_dequant(v_pages, v_scales, block_table)
    B, _, Hq, hd = q.shape
    Hkv = k_pages.shape[2]
    rep = Hq // Hkv
    S = gk.shape[1]
    k = jnp.repeat(gk, rep, axis=2) if rep > 1 else gk
    v = jnp.repeat(gv, rep, axis=2) if rep > 1 else gv
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    logits = jnp.einsum("bqnh,bknh->bnqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale       # (B,Hq,1,S)
    mask = (jnp.arange(S)[None, :] < lengths[:, None])[:, None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bnqk,bknh->bqnh", probs.astype(v.dtype), v)
    return jnp.where((lengths > 0)[:, None, None, None], out,
                     jnp.zeros_like(out)).astype(q.dtype)
