"""Jitted public wrapper for paged flash-decode attention."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.paged_decode_attention import kernel as _kernel
from repro.kernels.runtime import resolve_interpret


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pages, v_pages, block_table, lengths,
                           interpret: Optional[bool] = None):
    """Single-token GQA attention over a paged KV pool, streamed through
    the block table (no gather).

    q: (B,1,Hq,hd); k/v_pages: (n_pages, page_size, Hkv, hd);
    block_table: (B, P) int32 page ids (-1 = unmapped); lengths: (B,)
    valid token counts. Pre-trim `block_table` to the live width
    (ceil(max(lengths)/page_size) columns) so the grid does not walk
    columns no slot uses.
    """
    return _kernel.paged_decode_attention_pallas(
        q, k_pages, v_pages, block_table, lengths,
        interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_quant(q, k_pages, v_pages, k_scales, v_scales,
                                 block_table, lengths,
                                 interpret: Optional[bool] = None):
    """`paged_decode_attention` over an int8/fp8 pool: pages are streamed at
    the storage width and dequantized in-VMEM with their per-(page, kv-head)
    scales (k/v_scales: (n_pages, Hkv) f32). Numerics follow the quantized
    tolerance contract in docs/serving.md, not the bit-exact one."""
    return _kernel.paged_decode_attention_quant_pallas(
        q, k_pages, v_pages, k_scales, v_scales, block_table, lengths,
        interpret=resolve_interpret(interpret))
