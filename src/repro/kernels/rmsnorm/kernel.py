"""Pallas TPU fused RMSNorm.

One VMEM tile of (block_rows, D) rows per grid step; the f32 mean-of-squares
reduction, rsqrt, and scale multiply fuse into a single HBM round trip (the
unfused jnp version reads x twice and writes an f32 temporary). D stays whole
in the lane dimension — RMSNorm needs the full row; block_rows tiles the
sublane dimension in multiples of 8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rms_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(x, scale, eps: float = 1e-6, block_rows: int = 256,
                   interpret: bool = True):
    orig_shape = x.shape
    D = x.shape[-1]
    xf = x.reshape(-1, D)
    R = xf.shape[0]
    br = min(block_rows, R)
    while R % br:
        br -= 1
    kernel = functools.partial(_rms_kernel, eps=eps)
    out = pl.pallas_call(
        kernel,
        grid=(R // br,),
        in_specs=[
            pl.BlockSpec((br, D), lambda r: (r, 0)),
            pl.BlockSpec((1, D), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
    )(xf, scale.reshape(1, D))
    return out.reshape(orig_shape)
