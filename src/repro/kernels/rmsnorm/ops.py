"""Jitted public wrapper for fused RMSNorm."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rmsnorm import kernel as _kernel


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, scale, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = True):
    return _kernel.rmsnorm_pallas(x, scale, eps=eps, block_rows=block_rows,
                                  interpret=interpret)
