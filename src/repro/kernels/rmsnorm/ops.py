"""Jitted public wrapper for fused RMSNorm."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.rmsnorm import kernel as _kernel
from repro.kernels.runtime import resolve_interpret


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, scale, eps: float = 1e-6, block_rows: int = 256,
            interpret: Optional[bool] = None):
    return _kernel.rmsnorm_pallas(x, scale, eps=eps, block_rows=block_rows,
                                  interpret=resolve_interpret(interpret))
