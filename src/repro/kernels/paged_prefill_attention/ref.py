"""Pure-jnp oracle for paged chunked-prefill attention: gather-then-attend.

This is exactly the serving engine's fallback read path for one prompt
chunk — materialize the slot's block row into the contiguous layout, write
the chunk first, then run the causal grouped SDPA — kept as the numerics
contract for the Pallas kernel. The oracle deliberately uses the same
grouped-einsum formulation as the paged decode step: it is reduction-order
stable across query counts, which is what lets a C-token chunk reproduce C
single-token decode steps bitwise (the engine's fork-suffix / resume
replays rely on that).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import paged_cache as pc

NEG_INF = -1e30


def paged_prefill_attention_ref(q, k_pages, v_pages, block_row, offset,
                                chunk_len):
    """q: (1, C, Hq, hd) chunk queries (RoPE already applied, chunk K/V
    already written to the pages); k/v_pages: (n_pages, page, Hkv, hd);
    block_row: (P,) int32 page ids (-1 = unmapped); offset: () tokens
    already cached before this chunk; chunk_len: () valid tokens in the
    chunk. Returns (1, C, Hq, hd); rows past chunk_len are unspecified
    (the caller discards them)."""
    B, C, Hq, hd = q.shape
    Hkv = k_pages.shape[2]
    rep = Hq // Hkv
    gk = pc.gather_sequence(k_pages, block_row[None])    # (1, P*page, Hkv, hd)
    gv = pc.gather_sequence(v_pages, block_row[None])
    S = gk.shape[1]
    k = jnp.repeat(gk, rep, axis=2) if rep > 1 else gk
    v = jnp.repeat(gv, rep, axis=2) if rep > 1 else gv
    qpos = offset + jnp.arange(C)
    kpos = jnp.arange(S)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    logits = jnp.einsum("bqnh,bknh->bnqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale    # (1,Hq,C,S)
    total = offset + chunk_len
    mask = ((kpos[None, :] <= qpos[:, None])
            & (kpos[None, :] < total))[None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bnqk,bknh->bqnh", probs.astype(v.dtype), v)


def paged_prefill_attention_ragged_ref(q, k_pages, v_pages, block_rows,
                                       offsets, lens):
    """Ragged multi-slot oracle: R independent chunk reads in one batch.

    q: (R, C, Hq, hd) — row r is slot r's chunk queries (RoPE applied, chunk
    K/V already written); block_rows: (R, P) per-row block-table rows;
    offsets/lens: (R,). Returns (R, C, Hq, hd); row r positions past lens[r]
    are unspecified (callers discard them), as is every position of padding
    rows (lens[r] == 0).
    """
    R, C, Hq, hd = q.shape
    Hkv = k_pages.shape[2]
    rep = Hq // Hkv
    gk = pc.gather_sequence(k_pages, block_rows)         # (R, P*page, Hkv, hd)
    gv = pc.gather_sequence(v_pages, block_rows)
    S = gk.shape[1]
    k = jnp.repeat(gk, rep, axis=2) if rep > 1 else gk
    v = jnp.repeat(gv, rep, axis=2) if rep > 1 else gv
    qpos = offsets[:, None] + jnp.arange(C)[None, :]              # (R, C)
    kpos = jnp.arange(S)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    logits = jnp.einsum("bqnh,bknh->bnqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale            # (R,Hq,C,S)
    total = (offsets + lens)[:, None, None]                       # (R, 1, 1)
    mask = ((kpos[None, None, :] <= qpos[:, :, None])
            & (kpos[None, None, :] < total))[:, None]             # (R,1,C,S)
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bnqk,bknh->bqnh", probs.astype(v.dtype), v)


def paged_prefill_attention_quant_ref(q, k_pages, v_pages, k_scales, v_scales,
                                      block_row, offset, chunk_len):
    """Quantized-pool oracle for the single-slot chunk kernel: dequantize-
    gather into the contiguous f32 layout, then attend exactly as the float
    oracle (docs/serving.md tolerance contract)."""
    gk = pc.gather_sequence_dequant(k_pages, k_scales, block_row[None])
    gv = pc.gather_sequence_dequant(v_pages, v_scales, block_row[None])
    return _attend_chunk(q, gk, gv, offset, chunk_len).astype(q.dtype)


def paged_prefill_attention_ragged_quant_ref(q, k_pages, v_pages, k_scales,
                                             v_scales, block_rows, offsets,
                                             lens):
    """Quantized-pool oracle for the ragged multi-slot chunk kernel."""
    gk = pc.gather_sequence_dequant(k_pages, k_scales, block_rows)
    gv = pc.gather_sequence_dequant(v_pages, v_scales, block_rows)
    R, C, Hq, hd = q.shape
    Hkv = k_pages.shape[2]
    rep = Hq // Hkv
    S = gk.shape[1]
    k = jnp.repeat(gk, rep, axis=2) if rep > 1 else gk
    v = jnp.repeat(gv, rep, axis=2) if rep > 1 else gv
    qpos = offsets[:, None] + jnp.arange(C)[None, :]              # (R, C)
    kpos = jnp.arange(S)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    logits = jnp.einsum("bqnh,bknh->bnqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale            # (R,Hq,C,S)
    total = (offsets + lens)[:, None, None]                       # (R, 1, 1)
    mask = ((kpos[None, None, :] <= qpos[:, :, None])
            & (kpos[None, None, :] < total))[:, None]             # (R,1,C,S)
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bnqk,bknh->bqnh", probs.astype(v.dtype), v)
    return out.astype(q.dtype)


def _attend_chunk(q, gk, gv, offset, chunk_len):
    """Causal chunk SDPA over gathered contiguous K/V (shared tail of the
    single-slot oracles)."""
    B, C, Hq, hd = q.shape
    Hkv = gk.shape[2]
    rep = Hq // Hkv
    S = gk.shape[1]
    k = jnp.repeat(gk, rep, axis=2) if rep > 1 else gk
    v = jnp.repeat(gv, rep, axis=2) if rep > 1 else gv
    qpos = offset + jnp.arange(C)
    kpos = jnp.arange(S)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    logits = jnp.einsum("bqnh,bknh->bnqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale    # (1,Hq,C,S)
    total = offset + chunk_len
    mask = ((kpos[None, :] <= qpos[:, None])
            & (kpos[None, :] < total))[None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bnqk,bknh->bqnh", probs.astype(v.dtype), v)
