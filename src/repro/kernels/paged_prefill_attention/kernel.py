"""Pallas TPU paged chunked-prefill kernel: one prompt chunk vs a paged KV
pool.

The serving engine's chunked prefill (models/transformer.prefill_chunk_paged)
ingests a prompt in fixed C-token chunks; each chunk's queries attend
causally within the chunk AND against every page the slot already wrote —
a ragged cross-chunk read the jnp oracle serves by gathering the slot's
whole block row into a contiguous buffer per layer per chunk. This kernel
removes the gather, mirroring the paged flash-decode kernel one PR back:

  * `(block_row, [offset, chunk_len])` are scalar-prefetched and the block
    row IS the K/V `index_map`: grid step (h, p) streams physical page
    `block_row[p]` HBM->VMEM straight from the pool.
  * steps past the live range (`ceil((offset+chunk_len)/page)` pages)
    re-map to the last live page — Pallas elides the DMA for a revisited
    block — and `pl.when` prunes their compute along with unmapped (-1)
    pages, so the read volume is O(offset + chunk_len), not O(P * page).
  * in-page positions past `offset+chunk_len` hold stale pool bytes and are
    zeroed before the MXU; the causal mask `kpos <= offset + (q mod C)`
    handles the intra-chunk triangle (the chunk's own K/V is written before
    the read, so self-attention within the chunk needs no special case).
  * the Q tile is the whole (q_per_kv * C, hd) chunk: every query head of
    one KV head rides each streamed page tile, with a running-softmax
    scratch accumulated across pages (flash style).

Grid: (Hkv, P) with P = block-row width (callers pre-trim to the live
width). Query rows past `chunk_len` are computed against whatever the mask
admits and must be discarded by the caller.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _paged_pref_kernel(row_ref,                # scalar prefetch: (P,) pages
                       info_ref,               # scalar prefetch: (2,) off,len
                       q_ref, k_ref, v_ref, o_ref,
                       m_scr, l_scr, acc_scr,
                       *, np_: int, ps: int, C: int, rep: int, scale: float):
    pi = pl.program_id(1)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    total = info_ref[0] + info_ref[1]          # offset + chunk_len
    page = row_ref[pi]
    s_start = pi * ps

    # live mapped page: pages past the covering range and unmapped (-1)
    # entries contribute nothing and are skipped (their block was not
    # re-fetched either — see the clamped index_map in
    # paged_prefill_attention_pallas)
    @pl.when((s_start < total) & (page >= 0))
    def _body():
        kpos = s_start + jax.lax.broadcasted_iota(jnp.int32, (ps, 1), 0)
        kvalid = kpos < total                   # (ps, 1)
        q = q_ref[0].reshape(rep * C, -1).astype(jnp.float32)
        # zero stale rows BEFORE the matmul: positions past offset+chunk_len
        # hold whatever the pool last held and must not reach the MXU
        k = jnp.where(kvalid, k_ref[0].astype(jnp.float32)[:, 0], 0.0)
        v = jnp.where(kvalid, v_ref[0].astype(jnp.float32)[:, 0], 0.0)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        # causal: query row r is chunk position r mod C at absolute
        # position offset + (r mod C)
        qpos = info_ref[0] + jax.lax.rem(
            jax.lax.broadcasted_iota(jnp.int32, (rep * C, 1), 0), C)
        m = kvalid[:, 0][None, :] & (kpos[:, 0][None, :] <= qpos)
        s = jnp.where(m, s, NEG_INF)

        m_prev = m_scr[...][:, 0]
        l_prev = l_scr[...][:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(m, p, 0.0)               # rows with no valid key yet
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = (l_prev * alpha + jnp.sum(p, axis=1))[:, None]
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new[:, None]

    @pl.when(pi == np_ - 1)
    def _finish():
        l = l_scr[...][:, 0]
        denom = jnp.where(l == 0.0, 1.0, l)
        hd = acc_scr.shape[-1]
        o_ref[0] = (acc_scr[...] / denom[:, None]).reshape(
            rep, C, hd).astype(o_ref.dtype)


def paged_prefill_attention_pallas(q, k_pages, v_pages, block_row, offset,
                                   chunk_len, *, interpret: bool = True):
    """q: (1, C, Hq, hd) one slot's chunk queries; k/v_pages: (n_pages,
    page, Hkv, hd) with the chunk already written; block_row: (P,) int32
    page ids (-1 = unmapped); offset/chunk_len: () int32. ->
    (1, C, Hq, hd); rows past chunk_len are unspecified."""
    _, C, Hq, hd = q.shape
    ps, Hkv = k_pages.shape[1], k_pages.shape[2]
    P = block_row.shape[0]
    rep = Hq // Hkv
    row = block_row.astype(jnp.int32)
    info = jnp.stack([jnp.asarray(offset, jnp.int32).reshape(()),
                      jnp.asarray(chunk_len, jnp.int32).reshape(())])

    # (Hkv, rep, C, hd): group q heads by their kv head
    qg = jnp.moveaxis(q[0], 1, 0).reshape(Hkv, rep, C, hd)

    def kv_map(h, p, row_ref, info_ref):
        # steps past the covering range re-stream the last live page:
        # Pallas skips the DMA for a block index equal to the previous
        # step's, so pruned pages cost neither bandwidth nor compute
        n_live = jax.lax.div(info_ref[0] + info_ref[1] + ps - 1, ps)
        pi = jnp.minimum(p, jnp.maximum(n_live - 1, 0))
        pg = row_ref[pi]
        return (jnp.maximum(pg, 0), 0, h, 0)

    kernel = functools.partial(_paged_pref_kernel, np_=P, ps=ps, C=C,
                               rep=rep, scale=1.0 / float(hd) ** 0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(Hkv, P),
        in_specs=[
            pl.BlockSpec((1, rep, C, hd), lambda h, p, *_: (h, 0, 0, 0)),
            pl.BlockSpec((1, ps, 1, hd), kv_map),
            pl.BlockSpec((1, ps, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, rep, C, hd),
                               lambda h, p, *_: (h, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep * C, 1), jnp.float32),
            pltpu.VMEM((rep * C, 1), jnp.float32),
            pltpu.VMEM((rep * C, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Hkv, rep, C, hd), q.dtype),
        interpret=interpret,
    )(row, info, qg, k_pages, v_pages)
    # (Hkv, rep, C, hd) -> (1, C, Hq, hd) with head index h = kv * rep + r
    return jnp.moveaxis(out.reshape(Hq, C, hd), 0, 1)[None]


def _paged_pref_ragged_kernel(rows_ref,        # scalar prefetch: (R, P) pages
                              info_ref,        # scalar prefetch: (R, 2)
                              q_ref, k_ref, v_ref, o_ref,
                              m_scr, l_scr, acc_scr,
                              *, np_: int, ps: int, C: int, rep: int,
                              scale: float):
    r = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    total = info_ref[r, 0] + info_ref[r, 1]    # offset + chunk_len
    page = rows_ref[r, pi]
    s_start = pi * ps

    # live mapped page of THIS row: pages past the row's covering range,
    # unmapped (-1) entries, and whole padding rows (len == 0 -> total ==
    # 0) contribute nothing and are skipped (their block was not re-fetched
    # either — see the clamped index_map in
    # paged_prefill_attention_ragged_pallas)
    @pl.when((s_start < total) & (page >= 0))
    def _body():
        kpos = s_start + jax.lax.broadcasted_iota(jnp.int32, (ps, 1), 0)
        kvalid = kpos < total                   # (ps, 1)
        q = q_ref[0, 0].reshape(rep * C, -1).astype(jnp.float32)
        # zero stale rows BEFORE the matmul: positions past offset+chunk_len
        # hold whatever the pool last held and must not reach the MXU
        k = jnp.where(kvalid, k_ref[0].astype(jnp.float32)[:, 0], 0.0)
        v = jnp.where(kvalid, v_ref[0].astype(jnp.float32)[:, 0], 0.0)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        # causal: query row j is chunk position j mod C at absolute
        # position offsets[r] + (j mod C)
        qpos = info_ref[r, 0] + jax.lax.rem(
            jax.lax.broadcasted_iota(jnp.int32, (rep * C, 1), 0), C)
        m = kvalid[:, 0][None, :] & (kpos[:, 0][None, :] <= qpos)
        s = jnp.where(m, s, NEG_INF)

        m_prev = m_scr[...][:, 0]
        l_prev = l_scr[...][:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(m, p, 0.0)               # rows with no valid key yet
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = (l_prev * alpha + jnp.sum(p, axis=1))[:, None]
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new[:, None]

    @pl.when(pi == np_ - 1)
    def _finish():
        l = l_scr[...][:, 0]
        denom = jnp.where(l == 0.0, 1.0, l)
        hd = acc_scr.shape[-1]
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).reshape(
            rep, C, hd).astype(o_ref.dtype)


def paged_prefill_attention_ragged_pallas(q, k_pages, v_pages, block_rows,
                                          offsets, lens, *,
                                          interpret: bool = True):
    """Multi-slot ragged chunk attention: the batched-ingest extension of
    `paged_prefill_attention_pallas`.

    q: (R, C, Hq, hd) — row r is one ingesting slot's chunk queries (chunk
    K/V already written); k/v_pages: (n_pages, page, Hkv, hd); block_rows:
    (R, P) int32 per-row page ids (-1 = unmapped); offsets/lens: (R,) int32.
    Grid (R, Hkv, P): the innermost axis walks row r's pages with the same
    per-row scalar-prefetched clamp/prune as the single-slot kernel, so the
    streamed volume is O(sum_r (offsets[r] + lens[r])). -> (R, C, Hq, hd);
    row r positions past lens[r] (and all of padding rows, lens[r] == 0)
    are unspecified."""
    R, C, Hq, hd = q.shape
    ps, Hkv = k_pages.shape[1], k_pages.shape[2]
    P = block_rows.shape[1]
    rep = Hq // Hkv
    rows = block_rows.astype(jnp.int32)
    info = jnp.stack([jnp.asarray(offsets, jnp.int32),
                      jnp.asarray(lens, jnp.int32)], axis=1)       # (R, 2)

    # (R, Hkv, rep, C, hd): group each row's q heads by their kv head
    qg = jnp.moveaxis(q, 2, 1).reshape(R, Hkv, rep, C, hd)

    def kv_map(r, h, p, rows_ref, info_ref):
        # steps past row r's covering range re-stream its last live page:
        # Pallas skips the DMA for a block index equal to the previous
        # step's, so pruned pages cost neither bandwidth nor compute
        n_live = jax.lax.div(info_ref[r, 0] + info_ref[r, 1] + ps - 1, ps)
        pi = jnp.minimum(p, jnp.maximum(n_live - 1, 0))
        pg = rows_ref[r, pi]
        return (jnp.maximum(pg, 0), 0, h, 0)

    kernel = functools.partial(_paged_pref_ragged_kernel, np_=P, ps=ps, C=C,
                               rep=rep, scale=1.0 / float(hd) ** 0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(R, Hkv, P),
        in_specs=[
            pl.BlockSpec((1, 1, rep, C, hd), lambda r, h, p, *_: (r, h, 0, 0,
                                                                  0)),
            pl.BlockSpec((1, ps, 1, hd), kv_map),
            pl.BlockSpec((1, ps, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, C, hd),
                               lambda r, h, p, *_: (r, h, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep * C, 1), jnp.float32),
            pltpu.VMEM((rep * C, 1), jnp.float32),
            pltpu.VMEM((rep * C, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, Hkv, rep, C, hd), q.dtype),
        interpret=interpret,
    )(rows, info, qg, k_pages, v_pages)
    # (R, Hkv, rep, C, hd) -> (R, C, Hq, hd) with head index h = kv*rep + r
    return jnp.moveaxis(out.reshape(R, Hq, C, hd), 1, 2)


def _paged_pref_kernel_quant(row_ref,          # scalar prefetch: (P,) pages
                             info_ref,         # scalar prefetch: (2,) off,len
                             q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
                             m_scr, l_scr, acc_scr,
                             *, np_: int, ps: int, C: int, rep: int,
                             scale: float):
    """Quantized-pool variant of `_paged_pref_kernel`: each page tile is
    dequantized in VMEM right after the DMA with its streamed
    per-(page, kv-head) scale scalar."""
    pi = pl.program_id(1)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    total = info_ref[0] + info_ref[1]          # offset + chunk_len
    page = row_ref[pi]
    s_start = pi * ps

    @pl.when((s_start < total) & (page >= 0))
    def _body():
        kpos = s_start + jax.lax.broadcasted_iota(jnp.int32, (ps, 1), 0)
        kvalid = kpos < total                   # (ps, 1)
        q = q_ref[0].reshape(rep * C, -1).astype(jnp.float32)
        k = jnp.where(kvalid,
                      k_ref[0].astype(jnp.float32)[:, 0] * ks_ref[0, 0], 0.0)
        v = jnp.where(kvalid,
                      v_ref[0].astype(jnp.float32)[:, 0] * vs_ref[0, 0], 0.0)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        qpos = info_ref[0] + jax.lax.rem(
            jax.lax.broadcasted_iota(jnp.int32, (rep * C, 1), 0), C)
        m = kvalid[:, 0][None, :] & (kpos[:, 0][None, :] <= qpos)
        s = jnp.where(m, s, NEG_INF)

        m_prev = m_scr[...][:, 0]
        l_prev = l_scr[...][:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(m, p, 0.0)               # rows with no valid key yet
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = (l_prev * alpha + jnp.sum(p, axis=1))[:, None]
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new[:, None]

    @pl.when(pi == np_ - 1)
    def _finish():
        l = l_scr[...][:, 0]
        denom = jnp.where(l == 0.0, 1.0, l)
        hd = acc_scr.shape[-1]
        o_ref[0] = (acc_scr[...] / denom[:, None]).reshape(
            rep, C, hd).astype(o_ref.dtype)


def paged_prefill_attention_quant_pallas(q, k_pages, v_pages, k_scales,
                                         v_scales, block_row, offset,
                                         chunk_len, *, interpret: bool = True):
    """`paged_prefill_attention_pallas` over a quantized pool (k/v_scales:
    (n_pages, Hkv) f32, streamed as (1, 1) blocks through the same clamped
    block-row index map as their page)."""
    _, C, Hq, hd = q.shape
    ps, Hkv = k_pages.shape[1], k_pages.shape[2]
    P = block_row.shape[0]
    rep = Hq // Hkv
    row = block_row.astype(jnp.int32)
    info = jnp.stack([jnp.asarray(offset, jnp.int32).reshape(()),
                      jnp.asarray(chunk_len, jnp.int32).reshape(())])

    qg = jnp.moveaxis(q[0], 1, 0).reshape(Hkv, rep, C, hd)

    def kv_map(h, p, row_ref, info_ref):
        n_live = jax.lax.div(info_ref[0] + info_ref[1] + ps - 1, ps)
        pi = jnp.minimum(p, jnp.maximum(n_live - 1, 0))
        pg = row_ref[pi]
        return (jnp.maximum(pg, 0), 0, h, 0)

    def scale_map(h, p, row_ref, info_ref):
        n_live = jax.lax.div(info_ref[0] + info_ref[1] + ps - 1, ps)
        pi = jnp.minimum(p, jnp.maximum(n_live - 1, 0))
        pg = row_ref[pi]
        return (jnp.maximum(pg, 0), h)

    kernel = functools.partial(_paged_pref_kernel_quant, np_=P, ps=ps, C=C,
                               rep=rep, scale=1.0 / float(hd) ** 0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(Hkv, P),
        in_specs=[
            pl.BlockSpec((1, rep, C, hd), lambda h, p, *_: (h, 0, 0, 0)),
            pl.BlockSpec((1, ps, 1, hd), kv_map),
            pl.BlockSpec((1, ps, 1, hd), kv_map),
            pl.BlockSpec((1, 1), scale_map),
            pl.BlockSpec((1, 1), scale_map),
        ],
        out_specs=pl.BlockSpec((1, rep, C, hd),
                               lambda h, p, *_: (h, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep * C, 1), jnp.float32),
            pltpu.VMEM((rep * C, 1), jnp.float32),
            pltpu.VMEM((rep * C, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Hkv, rep, C, hd), q.dtype),
        interpret=interpret,
    )(row, info, qg, k_pages, v_pages, k_scales, v_scales)
    return jnp.moveaxis(out.reshape(Hq, C, hd), 0, 1)[None]


def _paged_pref_ragged_kernel_quant(rows_ref,  # scalar prefetch: (R, P) pages
                                    info_ref,  # scalar prefetch: (R, 2)
                                    q_ref, k_ref, v_ref, ks_ref, vs_ref,
                                    o_ref, m_scr, l_scr, acc_scr,
                                    *, np_: int, ps: int, C: int, rep: int,
                                    scale: float):
    """Quantized-pool variant of `_paged_pref_ragged_kernel`."""
    r = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    total = info_ref[r, 0] + info_ref[r, 1]    # offset + chunk_len
    page = rows_ref[r, pi]
    s_start = pi * ps

    @pl.when((s_start < total) & (page >= 0))
    def _body():
        kpos = s_start + jax.lax.broadcasted_iota(jnp.int32, (ps, 1), 0)
        kvalid = kpos < total                   # (ps, 1)
        q = q_ref[0, 0].reshape(rep * C, -1).astype(jnp.float32)
        k = jnp.where(kvalid,
                      k_ref[0].astype(jnp.float32)[:, 0] * ks_ref[0, 0], 0.0)
        v = jnp.where(kvalid,
                      v_ref[0].astype(jnp.float32)[:, 0] * vs_ref[0, 0], 0.0)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        qpos = info_ref[r, 0] + jax.lax.rem(
            jax.lax.broadcasted_iota(jnp.int32, (rep * C, 1), 0), C)
        m = kvalid[:, 0][None, :] & (kpos[:, 0][None, :] <= qpos)
        s = jnp.where(m, s, NEG_INF)

        m_prev = m_scr[...][:, 0]
        l_prev = l_scr[...][:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(m, p, 0.0)               # rows with no valid key yet
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = (l_prev * alpha + jnp.sum(p, axis=1))[:, None]
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new[:, None]

    @pl.when(pi == np_ - 1)
    def _finish():
        l = l_scr[...][:, 0]
        denom = jnp.where(l == 0.0, 1.0, l)
        hd = acc_scr.shape[-1]
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).reshape(
            rep, C, hd).astype(o_ref.dtype)


def paged_prefill_attention_ragged_quant_pallas(q, k_pages, v_pages, k_scales,
                                                v_scales, block_rows, offsets,
                                                lens, *,
                                                interpret: bool = True):
    """`paged_prefill_attention_ragged_pallas` over a quantized pool."""
    R, C, Hq, hd = q.shape
    ps, Hkv = k_pages.shape[1], k_pages.shape[2]
    P = block_rows.shape[1]
    rep = Hq // Hkv
    rows = block_rows.astype(jnp.int32)
    info = jnp.stack([jnp.asarray(offsets, jnp.int32),
                      jnp.asarray(lens, jnp.int32)], axis=1)       # (R, 2)

    qg = jnp.moveaxis(q, 2, 1).reshape(R, Hkv, rep, C, hd)

    def kv_map(r, h, p, rows_ref, info_ref):
        n_live = jax.lax.div(info_ref[r, 0] + info_ref[r, 1] + ps - 1, ps)
        pi = jnp.minimum(p, jnp.maximum(n_live - 1, 0))
        pg = rows_ref[r, pi]
        return (jnp.maximum(pg, 0), 0, h, 0)

    def scale_map(r, h, p, rows_ref, info_ref):
        n_live = jax.lax.div(info_ref[r, 0] + info_ref[r, 1] + ps - 1, ps)
        pi = jnp.minimum(p, jnp.maximum(n_live - 1, 0))
        pg = rows_ref[r, pi]
        return (jnp.maximum(pg, 0), h)

    kernel = functools.partial(_paged_pref_ragged_kernel_quant, np_=P, ps=ps,
                               C=C, rep=rep, scale=1.0 / float(hd) ** 0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(R, Hkv, P),
        in_specs=[
            pl.BlockSpec((1, 1, rep, C, hd), lambda r, h, p, *_: (r, h, 0, 0,
                                                                  0)),
            pl.BlockSpec((1, ps, 1, hd), kv_map),
            pl.BlockSpec((1, ps, 1, hd), kv_map),
            pl.BlockSpec((1, 1), scale_map),
            pl.BlockSpec((1, 1), scale_map),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, C, hd),
                               lambda r, h, p, *_: (r, h, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep * C, 1), jnp.float32),
            pltpu.VMEM((rep * C, 1), jnp.float32),
            pltpu.VMEM((rep * C, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, Hkv, rep, C, hd), q.dtype),
        interpret=interpret,
    )(rows, info, qg, k_pages, v_pages, k_scales, v_scales)
    return jnp.moveaxis(out.reshape(R, Hq, C, hd), 1, 2)
