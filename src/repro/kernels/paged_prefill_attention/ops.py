"""Jitted public wrapper for paged chunked-prefill attention."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.paged_prefill_attention import kernel as _kernel
from repro.kernels.runtime import resolve_interpret


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_prefill_attention(q, k_pages, v_pages, block_row, offset, chunk_len,
                            interpret: Optional[bool] = None):
    """Chunked-prefill GQA attention over a paged KV pool, streamed through
    the block row (no gather).

    q: (1, C, Hq, hd) one slot's chunk queries (chunk K/V already written
    to the pages); k/v_pages: (n_pages, page_size, Hkv, hd); block_row:
    (P,) int32 page ids (-1 = unmapped); offset: () tokens already cached
    before the chunk; chunk_len: () valid chunk tokens. Pre-trim
    `block_row` to the live width (ceil((offset + chunk_len) / page_size)
    columns, bucketed) so the grid does not walk columns the slot's read
    never needs. Rows past chunk_len are unspecified — discard them.
    """
    return _kernel.paged_prefill_attention_pallas(
        q, k_pages, v_pages, block_row, offset, chunk_len,
        interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_prefill_attention_ragged(q, k_pages, v_pages, block_rows, offsets,
                                   lens, interpret: Optional[bool] = None):
    """Batched ragged chunked-prefill GQA attention: R slots' chunks against
    their own page chains in one call (the engine's batched-ingest op).

    q: (R, C, Hq, hd) — row r is slot r's next chunk queries (each row's
    chunk K/V already written to the pages); k/v_pages: (n_pages, page_size,
    Hkv, hd); block_rows: (R, P) int32 per-row page ids (-1 = unmapped);
    offsets/lens: (R,) int32. Pre-trim `block_rows` to the shared live width
    (ceil(max(offsets + lens) / page_size) columns, bucketed) — each row
    still prunes down to its own covering range via scalar prefetch. Row r
    positions past lens[r] are unspecified, as are padding rows (lens == 0).
    """
    return _kernel.paged_prefill_attention_ragged_pallas(
        q, k_pages, v_pages, block_rows, offsets, lens,
        interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_prefill_attention_quant(q, k_pages, v_pages, k_scales, v_scales,
                                  block_row, offset, chunk_len,
                                  interpret: Optional[bool] = None):
    """`paged_prefill_attention` over an int8/fp8 pool: pages stream at the
    storage width and are dequantized in-VMEM with their per-(page, kv-head)
    scales (k/v_scales: (n_pages, Hkv) f32). Numerics follow the quantized
    tolerance contract in docs/serving.md, not the bit-exact one."""
    return _kernel.paged_prefill_attention_quant_pallas(
        q, k_pages, v_pages, k_scales, v_scales, block_row, offset, chunk_len,
        interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_prefill_attention_ragged_quant(q, k_pages, v_pages, k_scales,
                                         v_scales, block_rows, offsets, lens,
                                         interpret: Optional[bool] = None):
    """`paged_prefill_attention_ragged` over an int8/fp8 pool (see
    `paged_prefill_attention_quant`)."""
    return _kernel.paged_prefill_attention_ragged_quant_pallas(
        q, k_pages, v_pages, k_scales, v_scales, block_rows, offsets, lens,
        interpret=resolve_interpret(interpret))
