"""Jitted public wrapper for flash attention."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.flash_attention import kernel as _kernel
from repro.kernels.runtime import resolve_interpret


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_kv", "interpret"))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 128,
                    block_kv: int = 128, interpret: Optional[bool] = None):
    """Causal/sliding-window GQA flash attention.

    q: (B,S,Hq,hd), k/v: (B,S,Hkv,hd) with Hq % Hkv == 0. Returns (B,S,Hq,hd).
    """
    return _kernel.flash_attention_pallas(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_kv=block_kv,
        interpret=resolve_interpret(interpret))
