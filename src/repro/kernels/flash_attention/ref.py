"""Pure-jnp oracle for causal (optionally sliding-window) GQA attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mha_ref(q, k, v, causal: bool = True, window: int = 0, softcap: float = 0.0):
    """q: (B,S,Hq,hd), k/v: (B,S,Hkv,hd) -> (B,S,Hq,hd)."""
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    logits = jnp.einsum("bqnh,bknh->bnqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask = ki <= qi
    if window:
        mask = mask & (ki > qi - window)
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bnqk,bknh->bqnh", probs.astype(v.dtype), v)
