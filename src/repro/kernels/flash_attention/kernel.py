"""Pallas TPU flash attention (prefill): causal / sliding-window, GQA.

TPU adaptation of the FlashAttention-2 schedule: the KV loop is the minor
(sequential) grid axis; a VMEM scratch holds the running (m, l, acc) softmax
state per Q block — TPU grids execute minor-to-major in order, which replaces
the GPU's per-SM software loop. Block sizes default to (128, 128), matching
the MXU's 128x128 systolic tile; the (Bq, hd) accumulator and the (Bq, Bkv)
logits tile both live in VMEM.

Sliding-window support prunes KV blocks entirely outside the window at the
grid level (they are masked, contributing nothing) — with window w, only
ceil(w / Bkv) + 1 KV blocks per Q block do real work.

Grid: (B * Hq, nQ, nKV).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref,
               m_scr, l_scr, acc_scr,
               *, nkv: int, bq: int, bkv: int, causal: bool, window: int,
               softcap: float, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bkv

    # Skip blocks that are fully masked (strictly above the diagonal, or
    # entirely left of the sliding window).
    run = ki >= 0
    if causal:
        run = run & (k_start <= q_start + bq - 1)
    if window:
        run = run & (k_start + bkv - 1 > q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)               # (bq, hd)
        k = k_ref[0].astype(jnp.float32)               # (bkv, hd)
        v = v_ref[0].astype(jnp.float32)               # (bkv, hd)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = jnp.ones((bq, bkv), jnp.bool_)
        if causal:
            mask = cols <= rows
        if window:
            mask = mask & (cols > rows - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...][:, 0]                      # (bq,)
        l_prev = l_scr[...][:, 0]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new[:, None]
        l_scr[...] = l_new[:, None]

    @pl.when(ki == nkv - 1)
    def _finish():
        l = l_scr[...][:, 0]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           softcap: float = 0.0, block_q: int = 128,
                           block_kv: int = 128, interpret: bool = True):
    """q: (B,S,Hq,hd), k/v: (B,S,Hkv,hd) -> (B,S,Hq,hd)."""
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    bq = min(block_q, S)
    while S % bq:
        bq //= 2
    bkv = min(block_kv, S)
    while S % bkv:
        bkv //= 2
    nq, nkv = S // bq, S // bkv

    qf = jnp.moveaxis(q, 2, 1).reshape(B * Hq, S, hd)
    kf = jnp.moveaxis(k, 2, 1).reshape(B * Hkv, S, hd)
    vf = jnp.moveaxis(v, 2, 1).reshape(B * Hkv, S, hd)

    kernel = functools.partial(
        _fa_kernel, nkv=nkv, bq=bq, bkv=bkv, causal=causal, window=window,
        softcap=softcap, scale=1.0 / float(hd) ** 0.5)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda g, qi, ki: (g, qi, 0)),
            pl.BlockSpec((1, bkv, hd), lambda g, qi, ki, rep=rep: (g // rep, ki, 0)),
            pl.BlockSpec((1, bkv, hd), lambda g, qi, ki, rep=rep: (g // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda g, qi, ki: (g, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return jnp.moveaxis(out.reshape(B, Hq, S, hd), 1, 2)
