"""Synthetic structured corpus for the PICE testbed.

The corpus is a templated Q->A language in which answers are multi-sentence
and compressible: each answer sentence has a "key tokens" core (subject,
relation, object) plus deterministic filler — exactly the redundancy
phenomenon PICE exploits (Observation 1). A *sketch* of an answer keeps only
the key tokens; the full answer is recoverable from the sketch by re-applying
the filler grammar, so a model that has learned the grammar can expand
sketches faithfully (Observation 2).

This gives us measurable quality: expansion quality = token agreement between
the expanded answer and the ground-truth full answer.
"""
from __future__ import annotations

import dataclasses
import random
from typing import List, Tuple

SUBJECTS = ["the system", "a network", "the model", "an agent", "the server",
            "a device", "the cache", "an index", "the router", "a queue"]
RELATIONS = ["stores", "routes", "computes", "balances", "caches", "splits",
             "merges", "predicts", "encodes", "ranks"]
OBJECTS = ["tokens", "queries", "weights", "batches", "packets", "sketches",
           "layers", "answers", "tasks", "scores"]
FILL_PRE = "in practice "
FILL_MID = " carefully "
FILL_POST = " at scale for every user"

CATEGORIES = ["generic", "knowledge", "roleplay", "fermi", "coding", "math",
              "writing", "reasoning", "stem", "humanities", "common-sense",
              "counterfactual"]

# categories with inherently short answers (paper Fig. 7: low parallelism)
SHORT_CATEGORIES = {"math", "common-sense", "coding"}


@dataclasses.dataclass
class QAExample:
    query: str
    answer: str            # full answer (ground truth y)
    sketch: str            # gold compressed sketch r
    category: str
    answer_sentences: List[str]
    sketch_sentences: List[str]


def make_sentence(rng: random.Random) -> Tuple[str, str]:
    """Returns (full_sentence, sketch_sentence)."""
    s, r, o = rng.choice(SUBJECTS), rng.choice(RELATIONS), rng.choice(OBJECTS)
    sketch = f"{s} {r} {o}"
    full = f"{FILL_PRE}{s}{FILL_MID}{r} {o}{FILL_POST}"
    return full, sketch


def make_example(rng: random.Random, category: str = None) -> QAExample:
    category = category or rng.choice(CATEGORIES)
    n = rng.randint(1, 3) if category in SHORT_CATEGORIES else rng.randint(3, 8)
    fulls, sketches = [], []
    for _ in range(n):
        f, s = make_sentence(rng)
        fulls.append(f)
        sketches.append(s)
    topic = sketches[0]
    query = f"explain how {topic} works"
    return QAExample(
        query=query,
        answer=". ".join(fulls) + ".",
        sketch=". ".join(sketches) + ".",
        category=category,
        answer_sentences=fulls,
        sketch_sentences=sketches,
    )


def expand_sketch_sentence(sketch_sentence: str) -> str:
    """Ground-truth grammar expansion of one sketch sentence."""
    words = sketch_sentence.strip().rstrip(".").split()
    if len(words) < 3:
        return sketch_sentence
    o = words[-1]
    r = words[-2]
    s = " ".join(words[:-2])
    return f"{FILL_PRE}{s}{FILL_MID}{r} {o}{FILL_POST}"


def corpus(n: int, seed: int = 0, category: str = None) -> List[QAExample]:
    rng = random.Random(seed)
    return [make_example(rng, category) for _ in range(n)]


def lm_text(n: int, seed: int = 0, categories: List[str] = None,
            bias: float = 0.8) -> str:
    """Plain LM training text: Q/A transcripts (teaches the filler grammar).

    `categories` biases the mix toward those categories (prob `bias`) —
    used to give each edge SLM *diverse strengths* (paper §IV-C: SLMs are
    complementary due to variations in training data)."""
    rng = random.Random(seed)
    parts = []
    for i in range(n):
        cat = None
        if categories and rng.random() < bias:
            cat = rng.choice(categories)
        ex = make_example(rng, cat)
        parts.append(f"Q: {ex.query}\nA: {ex.answer}\n")
        # expansion transcripts teach the sketch->answer mapping
        if i % 3 == 0:
            parts.append(f"Q: {ex.query}\nS: {ex.sketch}\nE: "
                         f"{ex.sketch_sentences[0]}| {ex.answer_sentences[0]}\n")
    return "".join(parts)


def sketch_sft_pairs(n: int, seed: int = 0) -> List[Tuple[str, str]]:
    """(document, summary/sketch) pairs for §IV-D supervised fine-tuning."""
    return [(ex.answer, ex.sketch) for ex in corpus(n, seed)]
