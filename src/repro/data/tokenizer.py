"""Byte-level tokenizer (vocab 256) with reserved control tokens.

Token 0 is EOS/pad. Printable ASCII round-trips; sentences are delimited by
'.' and newline, which is what the PICE sketch segmentation keys on.
"""
from __future__ import annotations

from typing import List

EOS = 0
VOCAB_SIZE = 256
SENTENCE_DELims = (ord("."), ord("\n"), ord(";"))


def encode(text: str) -> List[int]:
    return [b if b != EOS else ord(" ") for b in text.encode("utf-8", "replace")]


def decode(tokens: List[int]) -> str:
    out = bytes(t for t in tokens if 0 < t < 256)
    return out.decode("utf-8", "replace")


def split_sentences(text: str) -> List[str]:
    """Split a sketch into semantically-complete short sentences."""
    parts: List[str] = []
    cur = []
    for ch in text:
        cur.append(ch)
        if ch in ".;\n":
            s = "".join(cur).strip()
            if s and s not in (".", ";"):
                parts.append(s)
            cur = []
    tail = "".join(cur).strip()
    if tail:
        parts.append(tail)
    return parts
