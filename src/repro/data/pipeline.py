"""Training data pipeline: tokenization, packing, batching.

Deterministic, host-side (numpy) pipeline feeding jitted train steps; on a
real cluster each host packs its own shard (batch dim is data-parallel).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Tuple

import numpy as np

from repro.data import tokenizer as tok


@dataclasses.dataclass
class PackedDataset:
    """Pack a token stream into (B, S+1) rows; yields (tokens, targets)."""
    text: str
    seq_len: int
    batch_size: int
    seed: int = 0

    def __post_init__(self):
        ids = np.asarray(tok.encode(self.text), np.int32)
        row = self.seq_len + 1
        n_rows = len(ids) // row
        if n_rows == 0:
            reps = row // max(len(ids), 1) + 1
            ids = np.tile(ids, reps)
            n_rows = len(ids) // row
        self.rows = ids[: n_rows * row].reshape(n_rows, row)
        self.rng = np.random.default_rng(self.seed)

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        while True:
            idx = self.rng.integers(0, len(self.rows), self.batch_size)
            chunk = self.rows[idx]
            yield chunk[:, :-1], chunk[:, 1:]


def seq2seq_batch(pairs: List[Tuple[str, str]], seq_len: int,
                  rng: np.random.Generator,
                  batch_size: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(input -> output) examples packed as 'IN <sep> OUT' with loss mask on OUT.

    Returns (tokens, targets, mask) of shape (B, seq_len).
    """
    B = batch_size
    tokens = np.zeros((B, seq_len + 1), np.int32)
    mask = np.zeros((B, seq_len), np.float32)
    idx = rng.integers(0, len(pairs), B)
    for b, i in enumerate(idx):
        src, dst = pairs[i]
        ids = tok.encode(src)[: seq_len // 2] + [ord("|")] + tok.encode(dst)
        ids = ids[: seq_len] + [tok.EOS]
        tokens[b, : len(ids)] = ids
        out_start = min(len(tok.encode(src)[: seq_len // 2]) + 1, seq_len)
        mask[b, out_start - 1: len(ids) - 1] = 1.0
    return tokens[:, :-1], tokens[:, 1:], mask
