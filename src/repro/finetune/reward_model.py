"""Reward model (paper §IV-D step 2): scalar sketch-preference scorer trained
with the Bradley-Terry pairwise loss

    L_R(phi) = -E_{(x, r_w, r_l)} [ log sigmoid( R(x, r_w) - R(x, r_l) ) ].

R is a small transformer with a mean-pooled scalar head over 'x | r'.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import tokenizer as tok
from repro.finetune.preference import PreferenceTriple
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.models.layers import dense_init
from repro.training import optimizer as opt_lib


def init_reward_model(cfg: ModelConfig, seed: int = 0) -> dict:
    key = jax.random.PRNGKey(seed)
    params = transformer.init_params(cfg, key)
    params["reward_head"] = dense_init(jax.random.fold_in(key, 1),
                                       (cfg.d_model, 1))
    return params


def reward_fwd(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    """tokens: (B, S) -> scalar reward (B,)."""
    _, _, hidden = transformer.forward(cfg, params, tokens, return_hidden=True)
    mask = (tokens != tok.EOS).astype(jnp.float32)[..., None]
    pooled = jnp.sum(hidden.astype(jnp.float32) * mask, axis=1) / jnp.maximum(
        jnp.sum(mask, axis=1), 1.0)
    return (pooled @ params["reward_head"].astype(jnp.float32))[:, 0]


def encode_pair(x: str, r: str, seq_len: int) -> np.ndarray:
    ids = tok.encode(x)[: seq_len // 2] + [ord("|")] + tok.encode(r)
    ids = ids[:seq_len]
    out = np.zeros((seq_len,), np.int32)
    out[: len(ids)] = ids
    return out


def bt_loss(cfg: ModelConfig, params: dict, tok_w: jax.Array,
            tok_l: jax.Array) -> jax.Array:
    rw = reward_fwd(cfg, params, tok_w)
    rl = reward_fwd(cfg, params, tok_l)
    return -jnp.mean(jax.nn.log_sigmoid(rw - rl)), jnp.mean(
        (rw > rl).astype(jnp.float32))


def train_reward_model(cfg: ModelConfig, triples: Sequence[PreferenceTriple],
                       n_steps: int = 150, batch: int = 8, seq_len: int = 160,
                       lr: float = 1e-3, seed: int = 0, log_fn=print):
    params = init_reward_model(cfg, seed)
    opt_cfg = opt_lib.AdamWConfig(lr=lr, warmup_steps=10, total_steps=n_steps)
    opt_state = opt_lib.init_opt_state(params)
    rng = np.random.default_rng(seed)

    tw = np.stack([encode_pair(t.x, t.r_w, seq_len) for t in triples])
    tl = np.stack([encode_pair(t.x, t.r_l, seq_len) for t in triples])

    @jax.jit
    def step(params, opt_state, bw, bl):
        (loss, acc), grads = jax.value_and_grad(
            lambda p: bt_loss(cfg, p, bw, bl), has_aux=True)(params)
        params, opt_state, _ = opt_lib.adamw_update(opt_cfg, params, grads,
                                                    opt_state)
        return params, opt_state, loss, acc

    for i in range(n_steps):
        idx = rng.integers(0, len(triples), batch)
        params, opt_state, loss, acc = step(params, opt_state,
                                            jnp.asarray(tw[idx]),
                                            jnp.asarray(tl[idx]))
        if (i + 1) % 25 == 0 or i == n_steps - 1:
            # repro-analysis: disable=RA103 reason=log-interval readback; one transfer instead of two scalar syncs
            loss_h, acc_h = jax.device_get((loss, acc))
            log_fn(f"RM step {i+1}: loss={loss_h:.4f} "
                   f"pair_acc={acc_h:.3f}")
    return params
