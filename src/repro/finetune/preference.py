"""Preference labeling for sketch quality (paper §IV-D step 2).

Given input x, the SFT model produces a full answer y and a pair of sketches
(r1, r2). Each sketch is scored:

    score(r) = beta1 * (1 / l_r) + beta2 * Rouge-L(y_hat, y)

where y_hat is the base model's expansion of r back into a full answer —
shorter sketches that still reconstruct the answer win. The higher-scoring
sketch becomes r_w, the other r_l, forming the triplet dataset D={(x,r_w,r_l)}.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core.metrics import rouge_l


@dataclasses.dataclass
class PreferenceTriple:
    x: str
    r_w: str
    r_l: str
    score_w: float
    score_l: float


def sketch_score(sketch: str, expanded: str, reference: str,
                 beta1: float = 8.0, beta2: float = 1.0) -> float:
    l_r = max(len(sketch.split()), 1)
    _, _, f1 = rouge_l(reference, expanded)
    return beta1 / l_r + beta2 * f1


def label_pair(x: str, y: str, r1: str, r2: str,
               expand_fn: Callable[[str, str], str],
               beta1: float = 8.0, beta2: float = 1.0) -> PreferenceTriple:
    """expand_fn(x, sketch) -> full answer reconstructed by the base LLM."""
    s1 = sketch_score(r1, expand_fn(x, r1), y, beta1, beta2)
    s2 = sketch_score(r2, expand_fn(x, r2), y, beta1, beta2)
    if s1 >= s2:
        return PreferenceTriple(x=x, r_w=r1, r_l=r2, score_w=s1, score_l=s2)
    return PreferenceTriple(x=x, r_w=r2, r_l=r1, score_w=s2, score_l=s1)
