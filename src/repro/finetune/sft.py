"""Supervised fine-tuning (paper §IV-D step 1): teach the LLM to emit concise
sketches. Data: (document -> sketch) pairs from the corpus, packed as
'A <sep> S' with loss only on the sketch tokens."""
from __future__ import annotations

from typing import Iterator, List, Tuple

import numpy as np

from repro.data import corpus as corpus_lib
from repro.data import pipeline
from repro.models.config import ModelConfig
from repro.training import optimizer as opt_lib
from repro.training.train_loop import TrainState, init_train_state, train


def sft_batches(pairs: List[Tuple[str, str]], seq_len: int, batch: int,
                seed: int = 0) -> Iterator:
    rng = np.random.default_rng(seed)
    while True:
        yield pipeline.seq2seq_batch(pairs, seq_len, rng, batch)


def run_sft(cfg: ModelConfig, n_steps: int = 200, seq_len: int = 192,
            batch: int = 8, n_pairs: int = 2000, seed: int = 0,
            state: TrainState = None, lr: float = 1e-3,
            log_fn=print) -> TrainState:
    pairs = corpus_lib.sketch_sft_pairs(n_pairs, seed)
    state = state or init_train_state(cfg, seed)
    opt_cfg = opt_lib.AdamWConfig(lr=lr, warmup_steps=20, total_steps=n_steps)
    return train(cfg, state, sft_batches(pairs, seq_len, batch, seed),
                 opt_cfg, n_steps, masked=True, log_fn=log_fn)
