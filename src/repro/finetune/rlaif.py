"""RL fine-tuning from AI feedback (paper §IV-D step 3).

Policy pi_theta initialized from the SFT model, optimized for

    J(theta) = E_{r ~ pi_theta(.|x)} [ (1 - gamma) R_phi(r|x)
                                       - gamma D_KL(pi_theta || pi_SFT) ]

via REINFORCE with a moving-average baseline; the KL term is estimated
token-wise on sampled sketches (log pi_theta - log pi_SFT).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import corpus as corpus_lib
from repro.data import tokenizer as tok
from repro.finetune.reward_model import encode_pair, reward_fwd
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.serving.engine import InferenceEngine
from repro.serving.sampler import SamplerConfig
from repro.training import optimizer as opt_lib


@dataclasses.dataclass
class RLAIFConfig:
    gamma: float = 0.2             # KL weight
    lr: float = 3e-4
    n_steps: int = 60
    batch: int = 4
    max_sketch_tokens: int = 64
    seq_len: int = 160
    seed: int = 0


def _pow2_bucket(n: int, cap: int) -> int:
    """Pow2 bucket clamped to cap: O(log cap) jit shape variants total,
    instead of one variant per distinct (prompt, sketch) length pair."""
    b = 1
    while b < n:
        b *= 2
    return min(b, cap)


def _seq_logprob(cfg: ModelConfig, params, full_ids, prompt_len, gen_len):
    """Differentiable sum log pi(gen | prompt) over a right-padded buffer.

    `full_ids` is prompt+gen zero-padded to a bucketed length; causal
    attention makes logits at positions < prompt_len + gen_len independent
    of the padding, so bucketing changes trace shapes, not values.
    prompt_len/gen_len are traced scalars (they select the mask, they do
    not shape the computation). Returns (sum_lp, masked per-token lp, mask)."""
    logits, _ = transformer.forward(cfg, params, full_ids[None, :-1])
    logp = jax.nn.log_softmax(logits[0].astype(jnp.float32), axis=-1)
    targets = full_ids[1:]
    lp = jnp.take_along_axis(logp, targets[:, None], axis=-1)[:, 0]
    pos = jnp.arange(lp.shape[0])
    mask = ((pos >= prompt_len - 1)
            & (pos < prompt_len - 1 + gen_len)).astype(jnp.float32)
    gen_lp = lp * mask
    return jnp.sum(gen_lp), gen_lp, mask


def run_rlaif(policy_cfg: ModelConfig, policy_params,
              sft_params, rm_cfg: ModelConfig, rm_params,
              cfg: RLAIFConfig = RLAIFConfig(), log_fn=print):
    """Returns fine-tuned policy params."""
    rng = np.random.default_rng(cfg.seed)
    examples = corpus_lib.corpus(512, cfg.seed)
    opt_cfg = opt_lib.AdamWConfig(lr=cfg.lr, warmup_steps=5,
                                  total_steps=cfg.n_steps, grad_clip=1.0)
    opt_state = opt_lib.init_opt_state(policy_params)
    baseline = 0.0

    def loss_fn(params, full_ids, prompt_len, gen_len, advantage, ref_lp):
        sum_lp, gen_lp, mask = _seq_logprob(policy_cfg, params, full_ids,
                                            prompt_len, gen_len)
        n_gen = jnp.maximum(jnp.sum(mask), 1.0)
        # E[log pi - log pi_sft] over the generated positions only
        kl = jnp.sum((gen_lp - ref_lp) * mask) / n_gen
        pg = -advantage * sum_lp / n_gen
        return pg + cfg.gamma * kl, (kl, sum_lp)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))
    update = jax.jit(lambda p, g, o: opt_lib.adamw_update(opt_cfg, p, g, o))
    rm_reward = jax.jit(lambda toks: reward_fwd(rm_cfg, rm_params, toks))
    ref_lp_fn = jax.jit(
        lambda full, pl, gl: _seq_logprob(policy_cfg, sft_params,
                                          full, pl, gl)[1])

    # one engine, params swapped per step (sampling is non-differentiable;
    # rebuilding the engine would re-jit its decode/prefill closures)
    engine = InferenceEngine(policy_cfg, policy_params, max_batch=cfg.batch,
                             max_len=512,
                             sampler=SamplerConfig(temperature=0.9, top_k=40))
    history = []
    for step in range(cfg.n_steps):
        engine.params = policy_params
        idx = rng.integers(0, len(examples), cfg.batch)
        prompts, gens, rewards_d = [], [], []
        for i in idx:
            ex = examples[i]
            prompt = tok.encode(f"A: {ex.answer[:200]}\nS:")
            (out, _), = engine.generate([prompt], max_new=cfg.max_sketch_tokens)
            sketch = tok.decode(out)
            r_in = encode_pair(ex.answer[:200], sketch, cfg.seq_len)
            rewards_d.append(rm_reward(jnp.asarray(r_in[None]))[0])
            prompts.append(np.asarray(prompt, np.int32))
            gens.append(np.asarray(out if out else [tok.EOS], np.int32))
        # repro-analysis: disable=RA103 reason=one batched reward readback per step (was one scalar sync per sample)
        rewards = [float(v) for v in jax.device_get(rewards_d)]
        mean_r = float(np.mean(rewards))
        baseline = 0.9 * baseline + 0.1 * mean_r if step else mean_r
        kls_d = []
        grads_acc = None
        for p_ids, g_ids, r in zip(prompts, gens, rewards):
            n_p, n_g = len(p_ids), len(g_ids)
            L = _pow2_bucket(n_p + n_g, 512)
            n_g = min(n_g, max(L - n_p, 0))     # tail-truncate at the cap
            full = np.zeros((L,), np.int32)
            full[:n_p] = p_ids
            full[n_p:n_p + n_g] = g_ids[:n_g]
            full_j = jnp.asarray(full)
            pl_j = jnp.asarray(n_p, jnp.int32)
            gl_j = jnp.asarray(n_g, jnp.int32)
            ref_lp = ref_lp_fn(full_j, pl_j, gl_j)
            adv = (1.0 - cfg.gamma) * (r - baseline)
            (loss, (kl, _)), grads = grad_fn(policy_params, full_j, pl_j,
                                             gl_j, jnp.asarray(adv), ref_lp)
            kls_d.append(kl)
            grads_acc = grads if grads_acc is None else jax.tree.map(
                jnp.add, grads_acc, grads)
        grads_acc = jax.tree.map(lambda g: g / cfg.batch, grads_acc)
        policy_params, opt_state, _ = update(policy_params, grads_acc, opt_state)
        # repro-analysis: disable=RA103 reason=one batched KL readback per step (was one scalar sync per sample)
        kls = [float(v) for v in jax.device_get(kls_d)]
        history.append({"step": step, "mean_reward": mean_r,
                        "kl": float(np.mean(kls))})
        if (step + 1) % 10 == 0 or step == cfg.n_steps - 1:
            log_fn(f"RLAIF step {step+1}: reward={mean_r:.4f} "
                   f"kl={np.mean(kls):.4f}")
    return policy_params, history
