"""Dynamic scheduler (paper §IV-A): lexicographic multi-objective scheduling
with the Eq. (2) end-to-end latency hard constraint.

Cloud-side scheduling picks a sketch-length *level*:
    f(|r_i|) + Delta(r_i) + c*f(l_i) + sum_{r_j in Q} c*f(l_j)/(p*N) <= f(l_i)
choosing the shortest sketch the selected SLM can expand reliably; level 0
(no sketch that satisfies the constraint / capability floor) falls back to a
full cloud answer.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

from repro.core.profiler import LatencyModel, RuntimeMonitor
from repro.serving.network import NetworkModel
from repro.serving.requests import SLA

METRICS = ("error", "throughput", "latency", "server_cost", "edge_cost")


@dataclasses.dataclass
class EdgeModelInfo:
    name: str
    latency: LatencyModel          # f(l) of this SLM on its edge device
    capability: float              # quality proxy in (0,1)
    # minimum sketch compression this SLM can reliably expand: the sketch must
    # keep at least this fraction of the expected answer (more capable SLMs
    # tolerate shorter sketches — paper §IV-A-2)
    @property
    def min_sketch_ratio(self) -> float:
        return max(0.08, 0.55 - 0.5 * self.capability)


@dataclasses.dataclass
class ScheduleDecision:
    mode: str                      # "cloud_full" | "progressive"
    sketch_tokens: int = 0         # |r_i| target (level)
    level: int = 0
    edge_model: str = ""
    parallelism: int = 1
    est_latency_s: float = 0.0
    est_cloud_latency_s: float = 0.0
    metrics: Dict[str, float] = dataclasses.field(default_factory=dict)


class DynamicScheduler:
    """Cloud-side level selection + metric bookkeeping."""

    def __init__(self, cloud: LatencyModel, edges: Sequence[EdgeModelInfo],
                 network: NetworkModel, n_edge_devices: int,
                 monitor: Optional[RuntimeMonitor] = None,
                 n_levels: int = 6, queue_max: int = 8):
        self.cloud = cloud
        self.edges = {e.name: e for e in edges}
        self.network = network
        self.n_edge = max(n_edge_devices, 1)
        self.monitor = monitor or RuntimeMonitor()
        self.n_levels = n_levels
        self.queue_max = queue_max

    # -- memory pressure ---------------------------------------------------
    def memory_pressure_factor(self) -> float:
        """Queueing-delay inflation from KV page-pool occupancy (M/M/1-style
        1/(1-rho)). At util 0 (dense backend / no telemetry) this is 1.0, so
        the seed behavior is unchanged; near exhaustion waits blow up and the
        scheduler backs off to shorter sketches / cloud_full.

        rho is the *physical* occupancy, so copy-on-write prefix sharing
        lowers the factor directly (an N-way fan-out pins one prefix, not N).
        The flip side: shared pages cannot be reclaimed by evicting a single
        fork, so when most of the used pool is shared the evictable headroom
        shrinks — rho is nudged toward the logical (unshared-equivalent)
        load in proportion to the shared fraction.

        rho uses the *predicted* occupancy when it exceeds the physical one:
        the length predictor's queued_expected_tokens, converted to pages
        (`kv_predicted_utilization`), anticipates the pool the queued work
        is about to pin, so Eq.(2) admission tightens BEFORE the pool
        actually fills instead of reacting to evictions after the fact.
        With an empty queue (or no page telemetry) the predicted value
        collapses to the physical one and the seed behavior is unchanged."""
        util = min(max(self.monitor.kv_utilization,
                       self.monitor.kv_predicted_utilization), 0.95)
        # non-reclaimable share of the occupancy: at shared_fraction 0 this
        # is plain physical rho; at 1.0 (eviction frees nothing) rho climbs
        # toward saturation by util/2 of the remaining headroom — the extra
        # util factor keeps the nudge negligible when the pool is near-empty
        rho = util + 0.5 * self.monitor.kv_shared_fraction * (0.95 - util) \
            * util
        rho = min(rho, 0.95)
        return 1.0 / (1.0 - rho)

    # forecast-occupancy ceiling for ADMISSION (not just pressure): above
    # it the progressive path is refused outright and the request answers
    # from the cloud — sketching work the pool cannot hold only converts
    # admission failures into mid-flight evictions
    admission_ceiling: float = 0.92

    def forecast_utilization(self, expected_len: int = 0) -> float:
        """Forecast KV occupancy if this request's expansion is admitted:
        max(physical, predicted-from-queue) utilization plus the pages the
        request's own expected output would pin. 0.0 without page telemetry
        (dense backend), so admission is inert there."""
        mon = self.monitor
        if mon.kv_pages_total <= 0:
            return 0.0
        util = max(mon.kv_utilization, mon.kv_predicted_utilization)
        if expected_len > 0 and mon.kv_page_tokens > 0:
            extra = math.ceil(expected_len / mon.kv_page_tokens)
            util += extra / mon.kv_pages_total
        return min(util, 1.0)

    def admit_progressive(self, expected_len: int) -> bool:
        """Eq.(2)'s memory leg as an ADMISSION decision: the progressive
        path is only open while the forecast occupancy — queued expected
        tokens included, so admission tightens as the backlog's predicted
        lengths grow — stays under `admission_ceiling`."""
        return self.forecast_utilization(expected_len) < \
            self.admission_ceiling

    # -- Eq. (2) -----------------------------------------------------------
    def e2e_latency(self, sketch_tokens: int, expected_len: int,
                    edge: EdgeModelInfo, parallelism: int) -> float:
        c_f_l = edge.latency.f(expected_len / max(parallelism, 1))
        wait = (self.monitor.queued_expected_tokens / edge.latency.rate
                ) / (max(parallelism, 1) * self.n_edge)
        wait *= self.memory_pressure_factor()
        # observed edge failure rate inflates the edge-side term: a member
        # that fails with probability q is expected to cost 1/(1-q) runs
        # (retry/hedge), so repeated faults push Eq.(2) past the budget and
        # admission steers back toward cloud_full. At rate 0 (fault-free or
        # no telemetry yet) this is exactly the seed expression.
        fail = min(self.monitor.edge_failure_rate, 0.9)
        return (self.cloud.f(sketch_tokens)
                + self.network.delay_s(sketch_tokens)
                + (c_f_l + wait) / (1.0 - fail))

    def feasible(self, sketch_tokens: int, expected_len: int,
                 edge: EdgeModelInfo, parallelism: int,
                 sla: Optional[SLA] = None) -> bool:
        budget = self.cloud.f(expected_len)           # cloud-only latency
        if sla and sla.max_latency_s:
            budget = min(budget, sla.max_latency_s)
        return self.e2e_latency(sketch_tokens, expected_len, edge,
                                parallelism) <= budget

    def levels(self, expected_len: int) -> List[int]:
        """Sketch-length levels from ~0 to l_i (level 0 = no sketch)."""
        out = [0]
        for i in range(1, self.n_levels):
            out.append(int(round(expected_len * i / self.n_levels)))
        return out

    # -- parallelism estimate -----------------------------------------------
    # The paper sets p=1 as the conservative default; with its own hardware
    # constants (fp16 SLMs on Orin are ~2.3x slower per token than the cloud
    # A100), Eq.(2) is then never satisfiable — so, as a documented
    # strengthening, the scheduler anticipates the execution optimizer's
    # binary-tree merge plan: a sketch of `sk` tokens segments into ~sk/12
    # sentences, merged pairwise into ~sk/24 groups.
    TOKENS_PER_SENTENCE = 12
    max_parallelism: int = 8

    def estimate_parallelism(self, sketch_tokens: int) -> int:
        groups = sketch_tokens // (2 * self.TOKENS_PER_SENTENCE)
        return int(max(1, min(self.max_parallelism, groups)))

    # -- decision -----------------------------------------------------------
    def schedule(self, expected_len: int, sla: Optional[SLA] = None,
                 parallelism: Optional[int] = None) -> ScheduleDecision:
        """Pick (level, SLM) lexicographically: feasibility (hard latency) ->
        error (SLM capability floor on sketch ratio) -> throughput (shortest
        feasible sketch = fewest cloud tokens) -> edge cost."""
        cloud_lat = self.cloud.f(expected_len)
        if not self.admit_progressive(expected_len):
            self.monitor.admission_rejects += 1
            return self._cloud_full_decision(cloud_lat, expected_len)
        options: List[ScheduleDecision] = []
        for name, edge in self.edges.items():
            min_tokens = int(math.ceil(edge.min_sketch_ratio * expected_len))
            for level_idx, sk in enumerate(self.levels(expected_len)):
                if level_idx == 0 or sk < min_tokens:
                    continue
                p = (parallelism if parallelism is not None
                     else self.estimate_parallelism(sk))
                if not self.feasible(sk, expected_len, edge, p, sla):
                    continue
                est = self.e2e_latency(sk, expected_len, edge, p)
                options.append(ScheduleDecision(
                    mode="progressive", sketch_tokens=sk, level=level_idx,
                    edge_model=name, parallelism=p,
                    est_latency_s=est, est_cloud_latency_s=cloud_lat,
                    metrics={
                        "error": 1.0 - edge.capability,
                        "throughput": -1.0 / max(sk, 1),   # fewer cloud tokens
                        "latency": est,
                        "server_cost": float(sk),
                        "edge_cost": float(expected_len),
                    }))
        if not options:
            return self._cloud_full_decision(cloud_lat, expected_len)
        order = sla.metric_order if sla else SLA().metric_order
        return lexicographic_select(options, order)

    @staticmethod
    def _cloud_full_decision(cloud_lat: float,
                             expected_len: int) -> ScheduleDecision:
        return ScheduleDecision(
            mode="cloud_full", est_latency_s=cloud_lat,
            est_cloud_latency_s=cloud_lat,
            metrics={"error": 0.0, "latency": cloud_lat,
                     "server_cost": float(expected_len),
                     "edge_cost": 0.0,
                     "throughput": -1.0 / max(expected_len, 1)})


def lexicographic_select(options: List[ScheduleDecision],
                         order: Sequence[str],
                         tolerance: float = 0.05) -> ScheduleDecision:
    """Multi-objective lexicographic formulation (paper Eq. after (1)):
    minimize metrics in importance order; each earlier metric's achieved
    optimum becomes a constraint (within `tolerance`) for later ones."""
    remaining = list(options)
    for m in order:
        vals = [o.metrics.get(m, 0.0) for o in remaining]
        best = min(vals)
        slack = abs(best) * tolerance + 1e-9
        remaining = [o for o, v in zip(remaining, vals) if v <= best + slack]
        if len(remaining) == 1:
            break
    return remaining[0]
