"""Job dispatching (paper Algorithm 1): multi-list scheduling.

Tasks are bucketed into lists by expected answer length l_i; an idle edge
device pulls a batch from the list with the most jobs. Batching
uniform-length tasks avoids short sequences waiting on long ones (the
quadratic-cost padding waste the paper calls out).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

from repro.serving.requests import SketchTask


@dataclasses.dataclass
class MultiListQueue:
    """Lists q_1..q_n bucketed by expected length."""
    boundaries: Sequence[int] = (64, 128, 256, 512, 1024)
    max_size: int = 64

    def __post_init__(self):
        self.lists: List[List[SketchTask]] = [[] for _ in
                                              range(len(self.boundaries) + 1)]

    def _index(self, l: int) -> int:
        for j, b in enumerate(self.boundaries):
            if l <= b:
                return j
        return len(self.boundaries)

    def __len__(self) -> int:
        return sum(len(q) for q in self.lists)

    @property
    def full(self) -> bool:
        return len(self) >= self.max_size

    def push(self, task: SketchTask) -> None:
        # Lines 3-6: determine list index by l_i, append
        self.lists[self._index(task.expected_length)].append(task)

    def pull_batch(self, batch_size: int) -> List[SketchTask]:
        """Lines 7-11: pull a batch from the longest list (FIFO within it)."""
        if not len(self):
            return []
        jmax = max(range(len(self.lists)), key=lambda j: len(self.lists[j]))
        q = self.lists[jmax]
        batch, self.lists[jmax] = q[:batch_size], q[batch_size:]
        return batch

    def peek_expected_tokens(self) -> float:
        return float(sum(t.expected_length for q in self.lists for t in q))
