"""Job dispatching (paper Algorithm 1): multi-list scheduling.

Tasks are bucketed into lists by expected answer length l_i; an idle edge
device pulls a batch from the list with the most jobs. Batching
uniform-length tasks avoids short sequences waiting on long ones (the
quadratic-cost padding waste the paper calls out).

The queue is generic over any task carrying an `expected_length` attribute:
the PICE pipeline queues `SketchTask`s, and the serving front-end
(serving/frontend.py) reuses the same structure — and the same shedding
policy — as its admission waiting room, with `on_shed_task` notifying it
which queued request a shed displaced and `peek_best`/`remove` providing
priority-ordered (rather than batch-pulled) admission.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence


@dataclasses.dataclass
class MultiListQueue:
    """Lists q_1..q_n bucketed by expected length.

    `max_size` is enforced at push: a full queue sheds its least latency-
    critical work (the longest queued expected length) to admit a shorter
    incoming task, or rejects the incoming task outright when it is itself
    the longest. Shed/reject counts land in `shed_count` and, when a
    `monitor` (RuntimeMonitor) is attached, in `monitor.queue_shed`; a
    shed of an already-QUEUED victim additionally fires `on_shed_task`
    (push returning False signals an incoming-task refusal)."""
    boundaries: Sequence[int] = (64, 128, 256, 512, 1024)
    max_size: int = 64
    monitor: Optional[object] = None
    on_shed_task: Optional[Callable[[object], None]] = None

    def __post_init__(self):
        self.lists: List[List[object]] = [[] for _ in
                                          range(len(self.boundaries) + 1)]
        self.shed_count = 0

    def _index(self, l: int) -> int:
        for j, b in enumerate(self.boundaries):
            if l <= b:
                return j
        return len(self.boundaries)

    def __len__(self) -> int:
        return sum(len(q) for q in self.lists)

    @property
    def full(self) -> bool:
        return len(self) >= self.max_size

    def push(self, task) -> bool:
        """Enqueue `task`; returns False when it was refused (queue full and
        the task is the least-critical candidate). Lines 3-6 of Algorithm 1
        (bucket by l_i) are unchanged when the queue has room."""
        if len(self) >= self.max_size:
            victim = self._shed_candidate()
            if victim is None or victim.expected_length <= \
                    task.expected_length:
                # incoming task is itself the longest: refuse it
                self._record_shed(task)
                return False
            self.lists[self._index(victim.expected_length)].remove(victim)
            self._record_shed(victim)
            if self.on_shed_task is not None:
                self.on_shed_task(victim)
        self.lists[self._index(task.expected_length)].append(task)
        return True

    def _shed_candidate(self):
        """The queued task shedding frees the most time for: the largest
        expected length (the least latency-critical by the multi-list
        ordering), youngest within a list so older work keeps its place."""
        longest = None
        for q in self.lists:
            for t in q:
                if longest is None or t.expected_length >= \
                        longest.expected_length:
                    longest = t
        return longest

    def _record_shed(self, task) -> None:
        self.shed_count += 1
        if self.monitor is not None:
            self.monitor.on_shed(task.expected_length)

    def pull_batch(self, batch_size: int) -> List[object]:
        """Lines 7-11: pull a batch from the longest list (FIFO within it)."""
        if not len(self):
            return []
        jmax = max(range(len(self.lists)), key=lambda j: len(self.lists[j]))
        q = self.lists[jmax]
        batch, self.lists[jmax] = q[:batch_size], q[batch_size:]
        return batch

    def peek_best(self, key: Callable[[object], object]):
        """The queued task minimizing `key` across every list, without
        removing it — the front-end peeks its admission candidate, attempts
        engine admission, and only `remove`s on success (so a task that
        must wait for pages keeps its queue position)."""
        best = None
        for q in self.lists:
            for t in q:
                if best is None or key(t) < key(best):
                    best = t
        return best

    def remove(self, task) -> bool:
        """Remove a specific queued task (admitted or cancelled)."""
        for q in self.lists:
            if task in q:
                q.remove(task)
                return True
        return False

    def peek_expected_tokens(self) -> float:
        return float(sum(t.expected_length for q in self.lists for t in q))
