"""The PICE progressive-inference orchestrator (paper Fig. 4 workflow).

Real-compute mode: drives actual InferenceEngine instances (cloud LLM + edge
SLM fleet) through the full pipeline —
  (1) cloud assesses expected response length l_i,
  (2a) short answer -> full cloud response, or
  (2b) cloud emits a sketch at the scheduler-chosen level,
  (3) the dispatcher queues the expansion task; the execution optimizer plans
      the parallel sentence groups (binary-tree merge),
  (4) edge SLMs expand groups in parallel; the ensemble picks the most
      confident expansion per group,
  (5) the stitched response returns to the user.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

from repro.core import ensemble as ens
from repro.core import exec_optimizer, sketch as sketch_lib
from repro.core.dispatch import MultiListQueue
from repro.core.profiler import LatencyModel, RuntimeMonitor
from repro.core.scheduler import DynamicScheduler, EdgeModelInfo, ScheduleDecision
from repro.core.selection import select_model
from repro.data import tokenizer as tok
from repro.serving.engine import InferenceEngine
from repro.serving.network import NetworkModel
from repro.serving.requests import Request, Response, SketchTask


@dataclasses.dataclass
class PICEConfig:
    alpha1: float = 0.4            # Eq.(3) perplexity weight
    alpha2: float = 0.2            # Eq.(3) length weight
    max_sketch_tokens: int = 160
    short_answer_tokens: int = 48  # below this, always answer from cloud
    queue_max: int = 8
    max_parallelism: int = 8
    ensemble_size: int = 2         # how many edge models expand each group


class PICEPipeline:
    def __init__(self, cloud_engine: InferenceEngine,
                 edge_engines: Dict[str, InferenceEngine],
                 cloud_latency: LatencyModel,
                 edge_infos: List[EdgeModelInfo],
                 network: Optional[NetworkModel] = None,
                 cfg: PICEConfig = PICEConfig(),
                 n_edge_devices: Optional[int] = None):
        self.cloud = cloud_engine
        self.edges = edge_engines
        self.cfg = cfg
        self.network = network or NetworkModel()
        self.monitor = RuntimeMonitor()
        self.queue = MultiListQueue(max_size=cfg.queue_max)
        self.edge_infos = sorted(edge_infos, key=lambda e: e.capability)
        self.scheduler = DynamicScheduler(
            cloud_latency, self.edge_infos, self.network,
            n_edge_devices or len(edge_engines), monitor=self.monitor,
            queue_max=cfg.queue_max)
        self.stats = {"progressive": 0, "cloud_full": 0}

    # ------------------------------------------------------------------
    def predict_length(self, req: Request) -> int:
        return sketch_lib.heuristic_expected_length(req.query, req.category)

    def _cloud_generate(self, prompt: str, max_new: int):
        toks = tok.encode(prompt)
        (out, lps), = self.cloud.generate([toks], max_new=max_new)
        return tok.decode(out), out, lps

    # ------------------------------------------------------------------
    def handle(self, req: Request) -> Response:
        t_start = time.perf_counter()
        # refresh KV-memory telemetry so Eq.(2) sees real page-pool pressure
        self.monitor.observe_engines(self.edges.values())
        l_i = min(self.predict_length(req), req.max_new_tokens)

        # short answers: no progressive inference (workflow step 2a)
        if l_i <= self.cfg.short_answer_tokens:
            decision = ScheduleDecision(mode="cloud_full")
        else:
            decision = self.scheduler.schedule(l_i, sla=req.sla)

        if decision.mode == "cloud_full":
            self.stats["cloud_full"] += 1
            text, out, _ = self._cloud_generate(
                sketch_lib.cloud_full_prompt(req.query), max_new=l_i)
            return Response(req_id=req.req_id, text=text.strip(),
                            mode="cloud_full", cloud_tokens=len(out),
                            latency_s=time.perf_counter() - t_start,
                            model_used=self.cloud.name)

        # ---- progressive path (2b..5) -----------------------------------
        self.stats["progressive"] += 1
        sketch_text, sk_toks, _ = self._cloud_generate(
            sketch_lib.cloud_sketch_prompt(req.query, decision.sketch_tokens),
            max_new=min(decision.sketch_tokens + 10, self.cfg.max_sketch_tokens))
        sketch_text = sketch_text.strip()
        sentences = sketch_lib.segment_sketch(sketch_text)
        if not sentences:
            sentences = [sketch_text or req.query]

        task = SketchTask(req_id=req.req_id, query=req.query,
                          sketch=sketch_text, sentences=sentences,
                          expected_length=l_i, sketch_tokens=len(sk_toks))
        self.queue.push(task)
        self.monitor.on_enqueue(l_i)
        net_delay = self.network.delay_s(task.sketch_tokens)

        # Algorithm 2: (re)select the SLM against the remaining budget
        sel = select_model(decision.edge_model, self.edge_infos, l_i,
                           task.sketch_tokens, self.scheduler.cloud,
                           queue_len=len(self.queue),
                           queue_max=self.cfg.queue_max)
        primary = sel.model

        # execution optimizer: binary-tree merge plan
        einfo = next(e for e in self.edge_infos if e.name == primary)
        budget = self.scheduler.cloud.f(l_i) - self.scheduler.cloud.f(
            task.sketch_tokens)

        def lat(p, longest_tokens):
            return einfo.latency.f(longest_tokens)

        plan = exec_optimizer.plan_expansion(
            sentences, lat, budget,
            max_parallelism=self.cfg.max_parallelism)

        # pull the task (single-node real-compute: the queue round-trips)
        self.queue.pull_batch(1)
        self.monitor.on_dequeue(l_i)

        # expand groups on the ensemble of edge engines; under KV-memory
        # pressure fall back to the primary model alone — unless the fleet
        # is already absorbing the fan-out via COW prefix sharing (mostly-
        # shared occupancy means an extra member costs tail pages, not a
        # second prefix)
        names = self._ensemble_names(primary)
        if (self.monitor.kv_utilization > 0.85
                and self.monitor.kv_shared_fraction <= 0.5):
            names = names[:1]
        per_tok = max(len(tok.encode(" ".join(g))) for g in plan.groups)
        max_new = min(int(per_tok * 3.5) + 24, req.max_new_tokens)
        # the exec-optimizer's parallel segments all repeat the same
        # (query, sketch) context: prefill it once per engine and fork the
        # per-group suffixes off it (paged backend; dense falls back to
        # independent submissions inside generate_fanout)
        prefix_toks = tok.encode(
            sketch_lib.edge_expand_prefix(req.query, sketch_text))
        suffix_toks = [tok.encode(sketch_lib.edge_expand_suffix(g))
                       for g in plan.groups]
        chosen: List[str] = []
        total_conf, edge_tokens = 0.0, 0
        group_results = {}
        for name in names:
            eng = self.edges[name]
            # SLA intent rides with the work: the primary member's
            # expansion is latency-critical (priority 1), extra ensemble
            # members opportunistic (0). In this synchronous single-tenant
            # loop each engine only ever holds one fanout at a time, so the
            # distinction bites when a fleet multiplexes engines across
            # requests — eviction and chunk-ingest bandwidth then favor
            # the critical work (see engine._evict_victim)
            prio = 1 if name == primary else 0
            if hasattr(eng, "generate_fanout"):
                outs = eng.generate_fanout(prefix_toks, suffix_toks,
                                           max_new=max_new, priority=prio)
            else:
                outs = eng.generate([prefix_toks + sfx for sfx in suffix_toks],
                                    max_new=max_new,
                                    priorities=[prio] * len(suffix_toks))
            group_results[name] = outs
        for gi in range(len(plan.groups)):
            cands = []
            for name in names:
                out, lps = group_results[name][gi]
                cands.append(ens.Candidate(
                    text=tok.decode(out).strip(),
                    mean_log2_prob=ens.mean_log2_from_nats(lps),
                    n_tokens=len(out), model=name))
            best, scores = ens.select_best(cands, sketch_text,
                                           self.cfg.alpha1, self.cfg.alpha2)
            chosen.append(best.text)
            total_conf += max(scores)
            edge_tokens += best.n_tokens
        text = " ".join(chosen).strip()
        return Response(req_id=req.req_id, text=text, mode="progressive",
                        cloud_tokens=len(sk_toks), edge_tokens=edge_tokens,
                        latency_s=time.perf_counter() - t_start + net_delay,
                        network_s=net_delay,
                        confidence=total_conf / max(len(plan.groups), 1),
                        model_used=primary)

    def _ensemble_names(self, primary: str) -> List[str]:
        names = [primary]
        for e in reversed(self.edge_infos):         # most capable first
            if e.name != primary and e.name in self.edges:
                names.append(e.name)
            if len(names) >= self.cfg.ensemble_size:
                break
        return [n for n in names if n in self.edges]
