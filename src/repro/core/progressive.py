"""The PICE progressive-inference orchestrator (paper Fig. 4 workflow).

Real-compute mode: drives actual InferenceEngine instances (cloud LLM + edge
SLM fleet) through the full pipeline —
  (1) cloud assesses expected response length l_i,
  (2a) short answer -> full cloud response, or
  (2b) cloud emits a sketch at the scheduler-chosen level,
  (3) the dispatcher queues the expansion task; the execution optimizer plans
      the parallel sentence groups (binary-tree merge),
  (4) edge SLMs expand groups IN PARALLEL; the ensemble picks the most
      confident expansion per group,
  (5) the stitched response returns to the user.

Engines are MULTIPLEXED: the pipeline wraps the cloud engine and each edge
engine in an `EngineFrontend` (serving/frontend.py) and submits every role —
sketch, full cloud answers, per-member expansion fan-outs — as prioritized,
cancellable requests through the request-handle API instead of owning the
engines. Ensemble members expand concurrently (`handle_async` gathers
them on one event loop), and many in-flight `handle_async` calls share one
engine fleet — the serving front-end's load path. `handle` is the
synchronous single-request facade over it.
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from typing import Dict, List, Optional

from repro.core import ensemble as ens
from repro.core import exec_optimizer, sketch as sketch_lib
from repro.core.dispatch import MultiListQueue
from repro.core.profiler import LatencyModel, RuntimeMonitor
from repro.core.scheduler import DynamicScheduler, EdgeModelInfo, ScheduleDecision
from repro.core.selection import select_model
from repro.data import tokenizer as tok
from repro.serving.engine import InferenceEngine
from repro.serving.faults import EngineCrash
from repro.serving.frontend import as_frontend
from repro.serving.network import NetworkModel
from repro.serving.requests import Request, Response, SketchTask


@dataclasses.dataclass
class PICEConfig:
    alpha1: float = 0.4            # Eq.(3) perplexity weight
    alpha2: float = 0.2            # Eq.(3) length weight
    max_sketch_tokens: int = 160
    short_answer_tokens: int = 48  # below this, always answer from cloud
    queue_max: int = 8
    max_parallelism: int = 8
    ensemble_size: int = 2         # how many edge models expand each group
    # sketch-transfer retry policy (NetworkModel.transfer_with_retry)
    transfer_max_attempts: int = 4
    transfer_backoff_s: float = 0.05


class PICEPipeline:
    def __init__(self, cloud_engine: InferenceEngine,
                 edge_engines: Dict[str, InferenceEngine],
                 cloud_latency: LatencyModel,
                 edge_infos: List[EdgeModelInfo],
                 network: Optional[NetworkModel] = None,
                 cfg: Optional[PICEConfig] = None,
                 n_edge_devices: Optional[int] = None):
        # default-construct per pipeline: a dataclass default instance in
        # the signature was SHARED across every pipeline, so one caller
        # mutating cfg.ensemble_size reconfigured all of them
        self.cfg = cfg = cfg or PICEConfig()
        self.network = network or NetworkModel()
        self.monitor = RuntimeMonitor()
        # every engine is served through a multiplexed front-end: raw
        # engines get wrapped here, pre-shared EngineFrontends pass through
        # (several pipelines — or the pipeline plus a load generator — can
        # then contend for the same slots/pages/priorities)
        self.cloud = as_frontend(cloud_engine, self.monitor)
        self.edges = {k: as_frontend(v, self.monitor)
                      for k, v in edge_engines.items()}
        self.queue = MultiListQueue(max_size=cfg.queue_max,
                                    monitor=self.monitor)
        self.edge_infos = sorted(edge_infos, key=lambda e: e.capability)
        self.scheduler = DynamicScheduler(
            cloud_latency, self.edge_infos, self.network,
            n_edge_devices or len(edge_engines), monitor=self.monitor,
            queue_max=cfg.queue_max)
        self.stats = {"progressive": 0, "cloud_full": 0}

    # ------------------------------------------------------------------
    def predict_length(self, req: Request) -> int:
        return sketch_lib.heuristic_expected_length(req.query, req.category)

    async def _cloud_generate(self, prompt: str, max_new: int,
                              deadline_s: Optional[float] = None,
                              role: str = "cloud_full"):
        toks = tok.encode(prompt)
        (out, lps), = await self.cloud.generate_async(
            [toks], max_new=max_new, deadline_s=deadline_s, role=role)
        return tok.decode(out), out, lps

    def _edge_info_for(self, primary: str) -> EdgeModelInfo:
        """The EdgeModelInfo for `primary`, guarding against a model name
        the selector produced that no longer has a profile (a bare
        StopIteration otherwise): fall back to the most capable edge info
        and record the mismatch."""
        info = next((e for e in self.edge_infos if e.name == primary), None)
        if info is None:
            info = self.edge_infos[-1]      # sorted ascending by capability
            self.monitor.fallback_primaries += 1
        return info

    def _finish(self, resp: Response,
                queue_wait_s: float = 0.0) -> Response:
        self.stats[resp.mode] = self.stats.get(resp.mode, 0) + 1
        if resp.degraded:
            self.monitor.record_degraded(resp.degraded)
        resp.queue_wait_s = queue_wait_s
        # arrival-relative end-to-end latency window (queue wait included
        # when the request carried an arrival stamp)
        self.monitor.record_latency(resp.latency_s)
        return resp

    # ------------------------------------------------------------------
    async def _degrade_cloud(self, req: Request, l_i: int, t_start: float,
                             budget_s: float, deadline: Optional[float],
                             sketch_text: str, n_sketch_toks: int,
                             faults: Dict[str, int], retries: int,
                             net_delay: float = 0.0,
                             queue_wait_s: float = 0.0) -> Response:
        """Degradation rungs when the edge path is unavailable (all members
        faulted, the sketch transfer was lost, or the dispatch queue shed
        the task): re-answer from the cloud while budget remains, else hand
        back the sketch itself — every request gets SOME answer."""
        now = time.perf_counter()
        if deadline is None or now < deadline:
            text, out, _ = await self._cloud_generate(
                sketch_lib.cloud_full_prompt(req.query), max_new=l_i,
                deadline_s=deadline, role="cloud_full")
            return self._finish(Response(
                req_id=req.req_id, text=text.strip(), mode="cloud_full",
                cloud_tokens=n_sketch_toks + len(out),
                latency_s=time.perf_counter() - t_start + net_delay,
                network_s=net_delay, model_used=self.cloud.name,
                degraded="cloud_full_fallback", retries=retries,
                deadline_s=budget_s, faults=faults), queue_wait_s)
        return self._finish(Response(
            req_id=req.req_id, text=(sketch_text or req.query).strip(),
            mode="progressive", cloud_tokens=n_sketch_toks,
            latency_s=now - t_start + net_delay, network_s=net_delay,
            model_used=self.cloud.name, degraded="sketch_passthrough",
            retries=retries, deadline_s=budget_s, faults=faults),
            queue_wait_s)

    def handle(self, req: Request) -> Response:
        """Synchronous single-request facade over `handle_async`: runs one
        fresh event loop to completion. Callers already inside a loop (the
        serving front-end, concurrent pipelines) use `handle_async`."""
        return asyncio.run(self.handle_async(req))

    async def handle_async(self, req: Request) -> Response:
        now = time.perf_counter()
        # latency (and the SLA deadline) anchor at ARRIVAL when the request
        # carries a stamp — time queued upstream counts against the budget
        t_start = req.arrival_time_s if req.arrival_time_s is not None \
            else now
        queue_wait = now - t_start
        budget_s = req.sla.max_latency_s or 0.0
        deadline = (t_start + budget_s) if budget_s else None
        faults: Dict[str, int] = {}

        def fault(kind: str) -> None:
            faults[kind] = faults.get(kind, 0) + 1

        # refresh KV-memory telemetry so Eq.(2) sees real page-pool pressure
        self.monitor.observe_engines(self.edges.values())
        l_i = min(self.predict_length(req), req.max_new_tokens)

        # short answers: no progressive inference (workflow step 2a)
        if l_i <= self.cfg.short_answer_tokens:
            decision = ScheduleDecision(mode="cloud_full")
        else:
            decision = self.scheduler.schedule(l_i, sla=req.sla)

        if decision.mode == "cloud_full":
            text, out, _ = await self._cloud_generate(
                sketch_lib.cloud_full_prompt(req.query), max_new=l_i,
                deadline_s=deadline, role="cloud_full")
            return self._finish(Response(
                req_id=req.req_id, text=text.strip(),
                mode="cloud_full", cloud_tokens=len(out),
                latency_s=time.perf_counter() - t_start,
                model_used=self.cloud.name, deadline_s=budget_s,
                faults=faults), queue_wait)

        # ---- progressive path (2b..5) -----------------------------------
        sketch_text, sk_toks, _ = await self._cloud_generate(
            sketch_lib.cloud_sketch_prompt(req.query, decision.sketch_tokens),
            max_new=min(decision.sketch_tokens + 10,
                        self.cfg.max_sketch_tokens),
            deadline_s=deadline, role="sketch")
        sketch_text = sketch_text.strip()
        sentences = sketch_lib.segment_sketch(sketch_text)
        if not sentences:
            sentences = [sketch_text or req.query]

        task = SketchTask(req_id=req.req_id, query=req.query,
                          sketch=sketch_text, sentences=sentences,
                          expected_length=l_i, sketch_tokens=len(sk_toks))
        if not self.queue.push(task):
            # the dispatch queue is full and this task is the least critical
            # of the lot: shed it from the edge path, not from service
            fault("queue_shed")
            return await self._degrade_cloud(
                req, l_i, t_start, budget_s, deadline, sketch_text,
                len(sk_toks), faults, retries=0, queue_wait_s=queue_wait)
        self.monitor.on_enqueue(l_i)

        # ship the sketch to the edge over the faultable link (retry with
        # capped jittered exponential backoff; latency is modeled)
        xfer = self.network.transfer_with_retry(
            task.sketch_tokens * self.network.bytes_per_token,
            max_attempts=self.cfg.transfer_max_attempts,
            base_backoff_s=self.cfg.transfer_backoff_s)
        self.monitor.record_transfer(xfer.ok, xfer.attempts)
        retries = xfer.attempts - 1
        net_delay = xfer.latency_s
        if xfer.failure:
            fault("transfer_" + xfer.failure)
        if not xfer.ok:
            # the sketch never reached the edge fleet: unqueue and degrade
            self.queue.pull_batch(1)
            self.monitor.on_dequeue(l_i)
            return await self._degrade_cloud(
                req, l_i, t_start, budget_s, deadline, sketch_text,
                len(sk_toks), faults, retries, net_delay,
                queue_wait_s=queue_wait)

        # Algorithm 2: (re)select the SLM against the remaining budget
        sel = select_model(decision.edge_model, self.edge_infos, l_i,
                           task.sketch_tokens, self.scheduler.cloud,
                           queue_len=len(self.queue),
                           queue_max=self.cfg.queue_max)
        einfo = self._edge_info_for(sel.model)
        primary = einfo.name

        # execution optimizer: binary-tree merge plan
        budget = self.scheduler.cloud.f(l_i) - self.scheduler.cloud.f(
            task.sketch_tokens)

        def lat(p, longest_tokens):
            return einfo.latency.f(longest_tokens)

        plan = exec_optimizer.plan_expansion(
            sentences, lat, budget,
            max_parallelism=self.cfg.max_parallelism)

        # pull the task (single-node real-compute: the queue round-trips)
        self.queue.pull_batch(1)
        self.monitor.on_dequeue(l_i)

        # expand groups on the ensemble of edge engines; under KV-memory
        # pressure fall back to the primary model alone — unless the fleet
        # is already absorbing the fan-out via COW prefix sharing (mostly-
        # shared occupancy means an extra member costs tail pages, not a
        # second prefix)
        names = self._ensemble_names(primary)
        if (self.monitor.kv_utilization > 0.85
                and self.monitor.kv_shared_fraction <= 0.5):
            names = names[:1]
        per_tok = max(len(tok.encode(" ".join(g))) for g in plan.groups)
        max_new = min(int(per_tok * 3.5) + 24, req.max_new_tokens)
        # the exec-optimizer's parallel segments all repeat the same
        # (query, sketch) context: prefill it once per engine and fork the
        # per-group suffixes off it (paged backend; dense falls back to
        # independent submissions inside generate_fanout)
        prefix_toks = tok.encode(
            sketch_lib.edge_expand_prefix(req.query, sketch_text))
        suffix_toks = [tok.encode(sketch_lib.edge_expand_suffix(g))
                       for g in plan.groups]
        chosen: List[str] = []
        total_conf, edge_tokens = 0.0, 0
        hedges = 0

        async def run_member(name: str):
            """One ensemble member's expansion, submitted through its
            engine's multiplexed front-end. SLA intent rides with the work:
            the primary member's fan-out is latency-critical (priority 1),
            extra ensemble members opportunistic (0) — on a shared engine,
            eviction and admission order favor the critical work (see
            engine._evict_victim)."""
            eng = self.edges[name]
            prio = 1 if name == primary else 0
            role = "expansion_primary" if name == primary \
                else "expansion_extra"
            try:
                outs = await eng.generate_fanout_async(
                    prefix_toks, suffix_toks, max_new=max_new,
                    priority=prio, deadline_s=deadline, role=role)
            except (EngineCrash, MemoryError) as exc:
                # injected crash / pool exhaustion: drop this member, scrub
                # its engine state, and let quorum-1 pick from the rest
                eng.abort_all()
                self.monitor.record_edge_result(False)
                fault("edge_" + type(exc).__name__)
                return name, None
            self.monitor.record_edge_result(True)
            return name, outs

        launched = []
        for name in names:
            if deadline is not None and time.perf_counter() >= deadline:
                # budget exhausted: don't launch further members — ensemble
                # selects from whatever already returned (quorum 1)
                break
            if name != primary:
                hedges += 1
            launched.append(run_member(name))
        # members expand CONCURRENTLY (workflow step 4's parallel edge
        # expansion): each fan-out is its own stream of prioritized
        # requests on its engine's front-end, all driven by one event loop
        member_outs = await asyncio.gather(*launched) if launched else []
        group_results = {n: outs for n, outs in member_outs
                         if outs is not None}
        if not group_results:
            # every member faulted or the deadline arrived before any could
            # launch: the edge path produced nothing
            return await self._degrade_cloud(
                req, l_i, t_start, budget_s, deadline, sketch_text,
                len(sk_toks), faults, retries, net_delay,
                queue_wait_s=queue_wait)
        degraded = "ensemble_partial" if len(group_results) < len(names) \
            else ""
        for gi in range(len(plan.groups)):
            cands = []
            for name, outs in group_results.items():
                out, lps = outs[gi]
                if not out:
                    continue      # deadline-cancelled before its first token
                cands.append(ens.Candidate(
                    text=tok.decode(out).strip(),
                    mean_log2_prob=ens.mean_log2_from_nats(lps),
                    n_tokens=len(out), model=name))
            if not cands:
                # no member produced this group: the sketch sentences
                # themselves are the (terse but correct-topic) fallback
                chosen.append(" ".join(plan.groups[gi]))
                degraded = "sketch_groups"
                continue
            best, scores = ens.select_best(cands, sketch_text,
                                           self.cfg.alpha1, self.cfg.alpha2)
            chosen.append(best.text)
            total_conf += max(scores)
            edge_tokens += best.n_tokens
        text = " ".join(chosen).strip()
        return self._finish(Response(
            req_id=req.req_id, text=text, mode="progressive",
            cloud_tokens=len(sk_toks), edge_tokens=edge_tokens,
            latency_s=time.perf_counter() - t_start + net_delay,
            network_s=net_delay,
            confidence=total_conf / max(len(plan.groups), 1),
            model_used=primary, degraded=degraded, retries=retries,
            hedges=hedges, deadline_s=budget_s, faults=faults), queue_wait)

    def _ensemble_names(self, primary: str) -> List[str]:
        names = [primary]
        for e in reversed(self.edge_infos):         # most capable first
            if e.name != primary and e.name in self.edges:
                names.append(e.name)
            if len(names) >= self.cfg.ensemble_size:
                break
        return [n for n in names if n in self.edges]
