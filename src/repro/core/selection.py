"""Edge-side online model selection (paper Algorithm 2).

When an edge device picks up task r_i it checks the remaining latency budget
f(l_i) - f(|r_i|): if the current SLM cannot finish in time it downgrades to
a smaller SLM; if there is slack AND the job queue is short it upgrades to a
higher-quality SLM (avoiding model-switch churn under load).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.profiler import LatencyModel
from repro.core.scheduler import EdgeModelInfo


@dataclasses.dataclass
class SelectionResult:
    model: str
    action: str            # "keep" | "downgrade" | "upgrade"
    est_time_s: float


def select_model(current: str,
                 candidates: Sequence[EdgeModelInfo],
                 expected_len: int,
                 sketch_tokens: int,
                 cloud: LatencyModel,
                 queue_len: int,
                 queue_max: int,
                 parallelism: int = 1) -> SelectionResult:
    """Algorithm 2. candidates must be sorted by capability ascending."""
    by_name = {c.name: c for c in candidates}
    names = [c.name for c in candidates]
    cur = by_name[current]
    budget = cloud.f(expected_len) - cloud.f(sketch_tokens)   # f(l_i)-f(|r_i|)

    def est(m: EdgeModelInfo) -> float:
        return m.latency.f(expected_len / max(parallelism, 1))

    tau = est(cur)
    if tau > budget:                                   # Lines 3-4: downgrade
        idx = names.index(current)
        for j in range(idx - 1, -1, -1):
            m = by_name[names[j]]
            if est(m) <= budget:
                return SelectionResult(m.name, "downgrade", est(m))
        smallest = by_name[names[0]]
        return SelectionResult(smallest.name, "downgrade", est(smallest))
    # Lines 6-12: consider upgrading only when the queue is short
    if queue_len < queue_max:
        idx = names.index(current)
        for j in range(len(names) - 1, idx, -1):       # largest first
            m = by_name[names[j]]
            if est(m) <= budget:
                return SelectionResult(m.name, "upgrade", est(m))
    return SelectionResult(current, "keep", tau)
