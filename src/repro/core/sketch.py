"""Sketch generation & response-length awareness (paper §III / §IV-A-2).

Prompt templates follow the paper's progressive-inference engine. With the
byte-level testbed models the templates use compact markers the models are
trained on (data/corpus.py grammar):

    cloud sketch:     "Q: {query}\nS:"          -> sketch
    cloud full:       "Q: {query}\nA:"          -> full answer
    edge expansion:   "Q: {query}\nS: {sketch}\nE: {sentence}|" -> expansion

Length prediction: LLMs can perceive response length in advance (paper cites
[22]); we implement it as (a) the trained bucket head on the cloud model
(ModelConfig.length_buckets) and (b) a calibrated heuristic fallback.
"""
from __future__ import annotations

from typing import List

from repro.data import tokenizer as tok
from repro.data.corpus import SHORT_CATEGORIES

LENGTH_BUCKET_TOKENS = 64      # bucket b predicts ~ (b + 0.5) * 64 tokens


def cloud_full_prompt(query: str) -> str:
    return f"Q: {query}\nA:"


def cloud_sketch_prompt(query: str, max_sketch_tokens: int) -> str:
    # the token budget is enforced by max_new_tokens at generation time; the
    # paper notes |r_i| may differ from the requested level by ~10 tokens.
    return f"Q: {query}\nS:"


def edge_expand_prefix(query: str, sketch: str) -> str:
    """The (query, sketch) context every parallel expansion group repeats —
    with the byte-level tokenizer, encode(prefix) + encode(suffix) ==
    encode(prefix + suffix), so the serving engine can prefill this once and
    fan groups out over copy-on-write shared KV pages."""
    return f"Q: {query}\nS: {sketch}\nE: "


def edge_expand_suffix(sentences: List[str]) -> str:
    """The per-group tail of the expansion prompt (see edge_expand_prefix)."""
    sent = ". ".join(s.rstrip(".") for s in sentences)
    return f"{sent}|"


def edge_expand_prompt(query: str, sketch: str, sentences: List[str]) -> str:
    """The paper's §IV-B template, adapted to the testbed grammar; merged
    groups concatenate their sentences ('complete only this sentence')."""
    return edge_expand_prefix(query, sketch) + edge_expand_suffix(sentences)


def segment_sketch(sketch_text: str) -> List[str]:
    return tok.split_sentences(sketch_text)


def heuristic_expected_length(query: str, category: str = "generic") -> int:
    """Fallback length predictor (calibrated on the synthetic corpus)."""
    base = 40 if category in SHORT_CATEGORIES else 220
    return base + 6 * len(query.split())


def bucket_to_tokens(bucket: int) -> int:
    return int((bucket + 0.5) * LENGTH_BUCKET_TOKENS)


def tokens_to_bucket(n_tokens: int, n_buckets: int = 16) -> int:
    return min(max(n_tokens // LENGTH_BUCKET_TOKENS, 0), n_buckets - 1)
