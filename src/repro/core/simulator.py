"""Event-driven cloud-edge serving simulator (calibrated mode).

Reproduces the paper's testbed benchmarks (Table III, Figs 3/6/12/13/14)
with latency models calibrated to the paper's own hardware numbers
(profiler.PAPER_CLOUD_SPEEDS / Table II bandwidth ratio). Four methods:

  cloud_only   — all queries served by the cloud LLM (vLLM-style slots)
  edge_only    — load-balanced across edge SLM devices
  routing      — difficulty router sends easy queries to edge, rest to cloud
  pice         — progressive inference (dynamic or static scheduling)

The simulator models: cloud batch slots (continuous batching), per-request
decode time f(l), network Delta(r), the multi-list job queue, edge devices
pulling uniform-length batches, execution-optimizer parallelism, and
Algorithm-2 model up/downgrades.
"""
from __future__ import annotations

import dataclasses
import heapq
import random
from typing import Dict, List, Optional

from repro.core.dispatch import MultiListQueue
from repro.core.exec_optimizer import plan_expansion
from repro.core.profiler import RuntimeMonitor, capability, paper_latency_model
from repro.core.scheduler import DynamicScheduler, EdgeModelInfo
from repro.serving.network import NetworkModel
from repro.serving.requests import SketchTask


@dataclasses.dataclass
class SimRequest:
    req_id: int
    arrival_s: float
    answer_len: int               # true response length l_i
    sketch_ratio: float = 0.3     # gold sketch compression
    category: str = "generic"
    difficulty: float = 0.5       # for the routing baseline
    # filled during sim:
    done_s: float = -1.0
    mode: str = ""


@dataclasses.dataclass
class SimResult:
    throughput_per_min: float
    avg_latency_s: float
    p95_latency_s: float
    completed: int
    offered: int
    cloud_tokens: int
    edge_tokens: int
    mode_counts: Dict[str, int]

    def row(self) -> dict:
        return dataclasses.asdict(self)


def make_requests(n: int, rpm: float, seed: int = 0, mean_len: int = 500,
                  short_frac: float = 0.2) -> List[SimRequest]:
    rng = random.Random(seed)
    out = []
    t = 0.0
    for i in range(n):
        t += rng.expovariate(rpm / 60.0)
        if rng.random() < short_frac:
            l = max(10, int(rng.gauss(40, 15)))         # short answers
        else:
            l = max(60, int(rng.gauss(mean_len, mean_len * 0.3)))
        out.append(SimRequest(req_id=i, arrival_s=t, answer_len=l,
                              difficulty=rng.random()))
    return out


class _Server:
    """A batch-slot server (cloud LLM under continuous batching, or one edge
    device). Work items occupy a slot for `duration`; queue when full.

    `contention` models memory-bandwidth sharing across a full batch: the
    per-request decode rate degrades as slots fill (vLLM per-request tok/s at
    max batch is well below the solo speed; this derating calibrates
    cloud-only saturation to the paper's Table III latencies)."""

    def __init__(self, slots: int, contention: float = 1.6):
        self.slots = slots
        self.contention = contention
        self.free_at = [0.0] * slots

    def submit(self, now: float, duration: float) -> float:
        """Returns completion time; occupies the earliest-free slot."""
        i = min(range(self.slots), key=lambda j: self.free_at[j])
        busy = sum(1 for t in self.free_at if t > now)
        duration *= 1.0 + self.contention * busy / max(self.slots, 1)
        start = max(now, self.free_at[i])
        end = start + duration
        self.free_at[i] = end
        return end


def ScheduleDecisionStatic(sketch_tokens: int, edge_model: str):
    from repro.core.scheduler import ScheduleDecision
    return ScheduleDecision(mode="progressive", sketch_tokens=sketch_tokens,
                            edge_model=edge_model, parallelism=2)


@dataclasses.dataclass
class SimConfig:
    cloud_model: str = "llama3-70b"
    edge_models: tuple = ("llama3-8b", "qwen2.5-7b", "qwen2.5-1.5b")
    n_edge_devices: int = 4
    cloud_batch: int = 20
    edge_batch: int = 4
    rpm: float = 30.0
    n_requests: int = 200
    bandwidth_mbps: float = 100.0
    queue_max: int = 8
    dynamic: bool = True           # dynamic vs static PICE scheduling
    static_sketch_ratio: float = 0.4
    max_parallelism: int = 8
    seed: int = 0


def _edge_infos(cfg: SimConfig) -> List[EdgeModelInfo]:
    return [EdgeModelInfo(name=m, latency=paper_latency_model(m, "edge"),
                          capability=capability(m))
            for m in cfg.edge_models]


def _finalize(reqs: List[SimRequest], cloud_toks: int, edge_toks: int
              ) -> SimResult:
    done = [r for r in reqs if r.done_s >= 0]
    lat = sorted(r.done_s - r.arrival_s for r in done)
    horizon = max((r.done_s for r in done), default=1.0)
    modes: Dict[str, int] = {}
    for r in done:
        modes[r.mode] = modes.get(r.mode, 0) + 1
    return SimResult(
        throughput_per_min=60.0 * len(done) / max(horizon, 1e-9),
        avg_latency_s=sum(lat) / max(len(lat), 1),
        p95_latency_s=lat[int(0.95 * (len(lat) - 1))] if lat else 0.0,
        completed=len(done), offered=len(reqs),
        cloud_tokens=cloud_toks, edge_tokens=edge_toks, mode_counts=modes)


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

def simulate_cloud_only(cfg: SimConfig, reqs: Optional[List[SimRequest]] = None
                        ) -> SimResult:
    reqs = reqs or make_requests(cfg.n_requests, cfg.rpm, cfg.seed)
    cloud = paper_latency_model(cfg.cloud_model, "cloud")
    server = _Server(cfg.cloud_batch)
    toks = 0
    for r in reqs:
        r.done_s = server.submit(r.arrival_s, cloud.f(r.answer_len))
        r.mode = "cloud_full"
        toks += r.answer_len
    return _finalize(reqs, toks, 0)


def simulate_edge_only(cfg: SimConfig, reqs: Optional[List[SimRequest]] = None
                       ) -> SimResult:
    reqs = reqs or make_requests(cfg.n_requests, cfg.rpm, cfg.seed)
    infos = _edge_infos(cfg)
    # each edge device hosts one SLM, queries dispatched load-balanced
    devices = [(_Server(cfg.edge_batch),
                infos[i % len(infos)]) for i in range(cfg.n_edge_devices)]
    net = NetworkModel(bandwidth_mbps=cfg.bandwidth_mbps)
    toks = 0
    for i, r in enumerate(reqs):
        server, info = devices[i % len(devices)]
        d = net.delay_s(64) + info.latency.f(r.answer_len)
        r.done_s = server.submit(r.arrival_s, d)
        r.mode = "edge_only"
        toks += r.answer_len
    return _finalize(reqs, 0, toks)


def simulate_routing(cfg: SimConfig, reqs: Optional[List[SimRequest]] = None,
                     easy_threshold: float = 0.45) -> SimResult:
    """Hybrid-LLM-style difficulty router [8]."""
    reqs = reqs or make_requests(cfg.n_requests, cfg.rpm, cfg.seed)
    cloud = paper_latency_model(cfg.cloud_model, "cloud")
    infos = _edge_infos(cfg)
    cloud_srv = _Server(cfg.cloud_batch)
    edges = [(_Server(cfg.edge_batch), infos[i % len(infos)])
             for i in range(cfg.n_edge_devices)]
    net = NetworkModel(bandwidth_mbps=cfg.bandwidth_mbps)
    ct = et = 0
    k = 0
    for r in reqs:
        if r.difficulty < easy_threshold:
            srv, info = edges[k % len(edges)]
            k += 1
            r.done_s = srv.submit(r.arrival_s,
                                  net.delay_s(64) + info.latency.f(r.answer_len))
            r.mode = "edge"
            et += r.answer_len
        else:
            r.done_s = cloud_srv.submit(r.arrival_s, cloud.f(r.answer_len))
            r.mode = "cloud"
            ct += r.answer_len
    return _finalize(reqs, ct, et)


# ---------------------------------------------------------------------------
# PICE
# ---------------------------------------------------------------------------

def simulate_pice(cfg: SimConfig, reqs: Optional[List[SimRequest]] = None
                  ) -> SimResult:
    reqs = reqs or make_requests(cfg.n_requests, cfg.rpm, cfg.seed)
    cloud = paper_latency_model(cfg.cloud_model, "cloud")
    infos = sorted(_edge_infos(cfg), key=lambda e: e.capability)
    net = NetworkModel(bandwidth_mbps=cfg.bandwidth_mbps)
    monitor = RuntimeMonitor()
    sched = DynamicScheduler(cloud, infos, net, cfg.n_edge_devices,
                             monitor=monitor, queue_max=cfg.queue_max)
    cloud_srv = _Server(cfg.cloud_batch)
    edge_srvs = [_Server(1) for _ in range(cfg.n_edge_devices)]
    queue = MultiListQueue(max_size=cfg.queue_max)
    ct = et = 0
    short_cut = 48

    # event loop: requests arrive -> cloud phase done -> edge phase done
    events: list = []   # (time, seq, kind, payload)
    seq = 0
    for r in reqs:
        heapq.heappush(events, (r.arrival_s, seq, "arrive", r)); seq += 1
    edge_free = [0.0] * cfg.n_edge_devices
    edge_cur_model = [infos[-1 if cfg.dynamic else 0].name] * cfg.n_edge_devices

    def dispatch_edge(now: float):
        nonlocal seq, et
        for d in range(cfg.n_edge_devices):
            if edge_free[d] > now or not len(queue):
                continue
            batch = queue.pull_batch(cfg.edge_batch)
            if not batch:
                continue
            for t in batch:
                monitor.on_dequeue(t.expected_length)
            if cfg.dynamic:
                # Algorithm 2: model up/downgrade for this batch
                from repro.core.selection import select_model
                lead = max(batch, key=lambda t: t.expected_length)
                sel = select_model(edge_cur_model[d], infos,
                                   lead.expected_length, lead.sketch_tokens,
                                   cloud, len(queue), cfg.queue_max)
                edge_cur_model[d] = sel.model
            info = next(e for e in infos if e.name == edge_cur_model[d])
            # execution optimizer: parallel groups per task; Eq.(2) budget
            # nets out the sketch-generation time already spent on the cloud
            dur = 0.0
            for t in batch:
                budget = (cloud.f(t.expected_length) - cloud.f(t.sketch_tokens)
                          if cfg.dynamic else 1e18)
                plan = plan_expansion(
                    t.sentences,
                    lambda p, lt: info.latency.f(lt),
                    latency_budget_s=budget,
                    max_parallelism=(cfg.max_parallelism if cfg.dynamic else 2))
                dur = max(dur, plan.est_latency_s)
                et_inc = t.expected_length
                heapq.heappush(events, (now + dur, seq, "edge_done",
                                        (t, d, et_inc))); seq += 1
            edge_free[d] = now + dur

    while events:
        now, _, kind, payload = heapq.heappop(events)
        if kind == "arrive":
            r: SimRequest = payload
            l = r.answer_len
            if cfg.dynamic:
                decision = sched.schedule(l)
            else:
                # static scheduling (paper Fig. 6 baseline): predefined rules
                # on predicted length only — fixed sketch ratio, fixed edge
                # model, no Eq.(2) feasibility / runtime adaptation.
                sk = int(cfg.static_sketch_ratio * l)
                decision = ScheduleDecisionStatic(sk, infos[0].name)
            if l <= short_cut or decision.mode == "cloud_full" or queue.full:
                done = cloud_srv.submit(now, cloud.f(l))
                r.done_s, r.mode = done, "cloud_full"
                ct += l
            else:
                sk = decision.sketch_tokens
                ct += sk
                cloud_done = cloud_srv.submit(now, cloud.f(sk))
                heapq.heappush(events, (cloud_done + net.delay_s(sk), seq,
                                        "sketch_ready", (r, sk))); seq += 1
        elif kind == "sketch_ready":
            r, sk = payload
            n_sent = max(1, sk // 12)        # ~12 tokens per sketch sentence
            sentences = [f"s{j} key tokens here" for j in range(n_sent)]
            task = SketchTask(req_id=r.req_id, query="", sketch="",
                              sentences=sentences, expected_length=r.answer_len,
                              sketch_tokens=sk, created_s=now)
            queue.push(task)
            monitor.on_enqueue(r.answer_len)
            r.mode = "progressive"
            r._task = task                    # type: ignore[attr-defined]
            dispatch_edge(now)
        elif kind == "edge_done":
            t, d, toks = payload
            et += toks
            for r in reqs:
                if r.req_id == t.req_id:
                    r.done_s = now
                    break
            dispatch_edge(now)
    return _finalize(reqs, ct, et)


METHODS = {
    "cloud_only": simulate_cloud_only,
    "edge_only": simulate_edge_only,
    "routing": simulate_routing,
    "pice": simulate_pice,
}
