"""Text-similarity metrics: ROUGE-1 / ROUGE-L (pure python, no deps)."""
from __future__ import annotations

from collections import Counter
from typing import List, Tuple


def _tokens(text: str) -> List[str]:
    return text.lower().replace(".", " ").replace(",", " ").split()


def rouge_1(reference: str, candidate: str) -> Tuple[float, float, float]:
    """Unigram (precision, recall, f1) of candidate against reference."""
    ref, cand = Counter(_tokens(reference)), Counter(_tokens(candidate))
    if not ref or not cand:
        return 0.0, 0.0, 0.0
    overlap = sum((ref & cand).values())
    p = overlap / max(sum(cand.values()), 1)
    r = overlap / max(sum(ref.values()), 1)
    f1 = 0.0 if (p + r) == 0 else 2 * p * r / (p + r)
    return p, r, f1


def _lcs_len(a: List[str], b: List[str]) -> int:
    if not a or not b:
        return 0
    prev = [0] * (len(b) + 1)
    for x in a:
        cur = [0] * (len(b) + 1)
        for j, y in enumerate(b, 1):
            cur[j] = prev[j - 1] + 1 if x == y else max(prev[j], cur[j - 1])
        prev = cur
    return prev[-1]


def rouge_l(reference: str, candidate: str) -> Tuple[float, float, float]:
    """LCS-based (precision, recall, f1)."""
    ra, ca = _tokens(reference), _tokens(candidate)
    if not ra or not ca:
        return 0.0, 0.0, 0.0
    lcs = _lcs_len(ra, ca)
    p, r = lcs / len(ca), lcs / len(ra)
    f1 = 0.0 if (p + r) == 0 else 2 * p * r / (p + r)
    return p, r, f1


def token_agreement(reference: str, candidate: str) -> float:
    """Position-aligned word agreement (quality proxy for grammar expansion)."""
    ra, ca = _tokens(reference), _tokens(candidate)
    if not ra:
        return 0.0
    n = sum(1 for x, y in zip(ra, ca) if x == y)
    return n / max(len(ra), len(ca))
