"""Ensemble learning (paper §IV-C): select the best SLM expansion by the
confidence score Eq. (3):

    con(y^) = a1 * 2^{(1/N) sum_i log2 p(w_i)}        (inverse perplexity)
            + a2 * Norm(|y^|)                          (length score)
            + (1 - a1 - a2) * Rouge-1(r, y^)           (sketch similarity)

The perplexity term uses the generating model's own token log-probs (no
reward model — the paper explicitly avoids that overhead). Norm(|y^|)
normalizes response length across the candidate set (longer, more detailed
expansions score higher). Rouge-1 recall measures how much of the sketch the
expansion preserves.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence

from repro.core.metrics import rouge_1


@dataclasses.dataclass
class Candidate:
    text: str
    mean_log2_prob: float          # (1/N) sum log2 p(w_i)
    n_tokens: int
    model: str
    extra: dict = dataclasses.field(default_factory=dict)


def length_norm(n: int, candidates: Sequence[Candidate]) -> float:
    mx = max((c.n_tokens for c in candidates), default=1)
    return n / max(mx, 1)


def confidence(cand: Candidate, sketch: str, candidates: Sequence[Candidate],
               alpha1: float = 0.4, alpha2: float = 0.2) -> float:
    inv_ppl = 2.0 ** cand.mean_log2_prob            # in (0, 1]
    ln = length_norm(cand.n_tokens, candidates)
    _, r1_recall, _ = rouge_1(sketch, cand.text)
    return (alpha1 * inv_ppl + alpha2 * ln
            + (1.0 - alpha1 - alpha2) * r1_recall)


def select_best(candidates: List[Candidate], sketch: str,
                alpha1: float = 0.4, alpha2: float = 0.2
                ) -> tuple[Candidate, List[float]]:
    assert candidates, "ensemble needs at least one candidate"
    scores = [confidence(c, sketch, candidates, alpha1, alpha2)
              for c in candidates]
    best = max(range(len(scores)), key=lambda i: scores[i])
    return candidates[best], scores


def mean_log2_from_nats(logprobs_nats: Sequence[float]) -> float:
    if not len(logprobs_nats):
        return -30.0
    mean_nats = sum(logprobs_nats) / len(logprobs_nats)
    return mean_nats / math.log(2.0)
