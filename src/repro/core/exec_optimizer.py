"""Execution optimizer (paper §IV-B): semantic-level parallelism with
binary-tree sentence merging.

Each sketch sentence is semantically complete, so expansions are independent
and can run as a parallel batch. But (1) sentence lengths vary — naive
batching pads short ones while long ones finish — and (2) every parallel
prompt repeats the sketch context in its KV cache. The fix: sort the k
sentences by word count and merge pairwise (longest with shortest):
(s_1, s_k), (s_2, s_{k-1}), ... giving ceil(k/2) groups with near-uniform
total length; recurse while the latency hard-constraint still holds.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence


@dataclasses.dataclass
class MergePlan:
    groups: List[List[str]]        # sentences per expansion prompt
    parallelism: int               # len(groups)
    est_latency_s: float
    merge_depth: int


def _word_count(s: str) -> int:
    return max(len(s.split()), 1)


def merge_once(groups: List[List[str]]) -> List[List[str]]:
    """One binary-tree merge level: sort by total word count, pair ends."""
    order = sorted(groups, key=lambda g: sum(_word_count(s) for s in g))
    merged: List[List[str]] = []
    i, j = 0, len(order) - 1
    while i < j:
        merged.append(order[i] + order[j])     # shortest with longest
        i, j = i + 1, j - 1
    if i == j:
        merged.append(order[i])
    return merged


def plan_expansion(sentences: Sequence[str],
                   latency_of_parallelism: Callable[[int, float], float],
                   latency_budget_s: float,
                   expansion_factor: float = 2.5,
                   max_parallelism: Optional[int] = None) -> MergePlan:
    """Choose the merge depth.

    latency_of_parallelism(p, longest_group_tokens) -> estimated edge latency
    for p parallel prompts whose longest group expands to ~longest_group_tokens.
    Starts fully parallel (p=k); while the NEXT merge level still satisfies
    the budget, merge (lower p => less prompt/KV overhead — the paper's
    "higher parallelism is not always preferable").
    """
    groups = [[s] for s in sentences if s.strip()]
    if not groups:
        return MergePlan(groups=[[""]], parallelism=1, est_latency_s=0.0,
                         merge_depth=0)
    if max_parallelism:
        while len(groups) > max_parallelism:
            groups = merge_once(groups)

    def est(gs: List[List[str]]) -> float:
        longest = max(sum(_word_count(s) for s in g) for g in gs)
        return latency_of_parallelism(len(gs), longest * expansion_factor)

    depth = 0
    cur = est(groups)
    while len(groups) > 1:
        cand = merge_once(groups)
        lat = est(cand)
        if lat <= latency_budget_s:
            groups, cur, depth = cand, lat, depth + 1
        else:
            break
    return MergePlan(groups=groups, parallelism=len(groups),
                     est_latency_s=cur, merge_depth=depth)
