"""Profiler (paper §III): offline latency estimation + runtime monitoring.

Offline phase fits the latency function f(l) = t0 + l / rate for every
(model, device) pair — either by *measuring* a real InferenceEngine (tiny
models on this host) or from the paper's published hardware calibration
(Table I speeds on A100, Table II cloud/edge specs). The cost coefficient c
is the ratio of edge-SLM to cloud-LLM per-token time (paper §IV-A-1).

Runtime phase tracks queue depth, in-flight work, and network state for the
scheduler's Eq. (2) feasibility checks.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import numpy as np

from repro.serving.requests import BoundedRecord


@dataclasses.dataclass
class LatencyModel:
    """f(l) = t0 + l / rate  (seconds for a response of l tokens)."""
    t0: float
    rate: float                   # tokens / second
    name: str = ""

    def f(self, l: float) -> float:
        return self.t0 + max(l, 0.0) / self.rate


# Paper Table I: tokens/s on 2xA100 with vLLM; MMLU as capability proxy.
PAPER_CLOUD_SPEEDS = {
    "qwen2.5-72b": (18.19, 86.1),
    "llama3-70b": (18.82, 79.5),
    "qwen2.5-32b": (22.13, 83.3),
    "llama3-8b": (76.5, 66.6),
    "qwen2.5-7b": (84.28, 74.2),
    "qwen2.5-1.5b": (183.33, 60.9),
}

# Table II: decode is HBM-bandwidth-bound, so edge/cloud per-token time scales
# with the bandwidth ratio (Jetson AGX Orin 204.8 GB/s vs A100 1935 GB/s).
# The paper's edge engine is fp16 PyTorch/Transformers (no quantization) —
# this calibration reproduces its Table III edge-only row (~6 req/min, ~800 s
# latency for Llama3-8B on 4 Orins at RPM 30).
EDGE_BW_RATIO = 204.8 / 1935.0
EDGE_QUANT_SPEEDUP = 1.0        # set >1 to model INT-quantized edge weights
PAPER_T0 = 0.5          # request overhead (prefill + framework)


def paper_latency_model(model: str, device: str = "cloud") -> LatencyModel:
    rate, _ = PAPER_CLOUD_SPEEDS[model]
    if device == "edge":
        rate *= EDGE_BW_RATIO * EDGE_QUANT_SPEEDUP
    return LatencyModel(t0=PAPER_T0, rate=rate, name=f"{model}@{device}")


def capability(model: str) -> float:
    """MMLU-derived capability score in (0,1) (paper Table I)."""
    return PAPER_CLOUD_SPEEDS[model][1] / 100.0


def fit_latency_model(samples: List[tuple], name: str = "") -> LatencyModel:
    """Least-squares fit of f(l)=t0+l/rate from (l, seconds) samples."""
    ls = np.asarray([s[0] for s in samples], np.float64)
    ts = np.asarray([s[1] for s in samples], np.float64)
    A = np.stack([np.ones_like(ls), ls], axis=1)
    coef, *_ = np.linalg.lstsq(A, ts, rcond=None)
    t0, slope = float(coef[0]), float(coef[1])
    slope = max(slope, 1e-6)
    return LatencyModel(t0=max(t0, 0.0), rate=1.0 / slope, name=name)


def profile_engine(engine, lengths=(16, 32, 64, 128), prompt=None,
                   name: str = "") -> LatencyModel:
    """Offline-profile a real engine: measure generation time vs length."""
    from repro.data import tokenizer as tok
    prompt = prompt or tok.encode("Q: explain how the system stores tokens works\nA:")
    samples = []
    engine.generate([prompt], max_new=8)          # warmup / compile
    for l in lengths:
        t0 = time.perf_counter()
        engine.generate([prompt], max_new=l)
        samples.append((l, time.perf_counter() - t0))
    return fit_latency_model(samples, name=name or engine.name)


def cost_coefficient(cloud: LatencyModel, edge: LatencyModel,
                     ref_len: int = 256) -> float:
    """c = SLM-at-edge time / LLM-at-cloud time (paper §IV-A-1)."""
    return edge.f(ref_len) / max(cloud.f(ref_len), 1e-9)


@dataclasses.dataclass
class RuntimeMonitor:
    """Runtime telemetry for the scheduler."""
    queue_depth: int = 0
    queued_expected_tokens: float = 0.0
    edge_busy: Dict[str, float] = dataclasses.field(default_factory=dict)
    net_bandwidth_mbps: float = 100.0
    net_rtt_s: float = 0.02
    # engine KV-memory telemetry (paged backend): the scheduler admits work
    # against real page-pool pressure instead of a fixed max_batch.
    # `used` is PHYSICAL occupancy (shared pages counted once); `logical` is
    # what an unshared layout would hold — the gap is the copy-on-write
    # prefix-sharing saving; `shared` is physical pages referenced >1 time.
    kv_pages_total: int = 0
    kv_pages_used: int = 0
    kv_pages_shared: int = 0
    kv_pages_logical: int = 0
    kv_evictions: int = 0
    # tokens one KV page holds (page_size, from observe_engines): converts
    # the length predictor's queued_expected_tokens into a page-count
    # forecast for `kv_predicted_utilization`
    kv_page_tokens: int = 0
    # fault/degradation telemetry (PICE fault model): edge member attempts
    # and failures feed `edge_failure_rate`, which inflates the scheduler's
    # Eq.(2) edge term so repeated faults steer admission back toward cloud
    edge_attempts: int = 0
    edge_failures: int = 0
    net_retries: int = 0
    net_failures: int = 0
    queue_shed: int = 0
    fallback_primaries: int = 0     # unknown-model guard hits (progressive)
    admission_rejects: int = 0      # progressive path refused on forecast
    #                                 KV occupancy (scheduler admission gate)
    degraded: Dict[str, int] = dataclasses.field(default_factory=dict)
    # arrival-relative request telemetry (serving front-end + pipeline):
    # TTFT and end-to-end latency measured FROM ARRIVAL — queue wait
    # included — not from admission. Bounded windows (BoundedRecord) so a
    # long-running fleet keeps the most recent ~4096 samples.
    ttft_window: BoundedRecord = dataclasses.field(
        default_factory=BoundedRecord)
    latency_window: BoundedRecord = dataclasses.field(
        default_factory=BoundedRecord)

    def on_enqueue(self, expected_tokens: float):
        self.queue_depth += 1
        self.queued_expected_tokens += expected_tokens

    def on_dequeue(self, expected_tokens: float):
        self.queue_depth = max(0, self.queue_depth - 1)
        self.queued_expected_tokens = max(
            0.0, self.queued_expected_tokens - expected_tokens)

    def on_shed(self, expected_tokens: float):
        """A queue admission was refused (or a queued task dropped) because
        the dispatch queue hit max_size. Counts only — depth bookkeeping
        stays with on_enqueue/on_dequeue, which shed tasks never reached."""
        del expected_tokens
        self.queue_shed += 1

    def record_edge_result(self, ok: bool):
        """One ensemble-member expansion attempt finished (ok) or faulted/
        timed out (not ok)."""
        self.edge_attempts += 1
        if not ok:
            self.edge_failures += 1

    def record_transfer(self, ok: bool, attempts: int):
        """Account a `transfer_with_retry` outcome."""
        self.net_retries += max(attempts - 1, 0)
        if not ok:
            self.net_failures += 1

    def record_degraded(self, mode: str):
        """A request landed on a degradation rung (see Response.degraded)."""
        self.degraded[mode] = self.degraded.get(mode, 0) + 1

    def record_ttft(self, ttft_s: float):
        """First token delivered `ttft_s` seconds after ARRIVAL (the wait in
        the admission queue is part of it — a request that queued 2s and
        decoded its first token in 50ms has TTFT 2.05s, not 0.05s)."""
        self.ttft_window.append(float(ttft_s))

    def record_latency(self, latency_s: float):
        """A request finished `latency_s` seconds after arrival."""
        self.latency_window.append(float(latency_s))

    def ttft_percentile(self, q: float) -> float:
        return self.ttft_window.percentile(q)

    def latency_percentile(self, q: float) -> float:
        return self.latency_window.percentile(q)

    @property
    def edge_failure_rate(self) -> float:
        """Observed fraction of edge expansion attempts that faulted; 0.0
        until any attempt is recorded, so a fault-free fleet reproduces the
        seed scheduler behavior exactly."""
        if self.edge_attempts <= 0:
            return 0.0
        return self.edge_failures / self.edge_attempts

    def update_memory(self, pages_used: int, pages_total: int,
                      evictions: int = 0, pages_shared: int = 0,
                      pages_logical: int = 0):
        self.kv_pages_used = pages_used
        self.kv_pages_total = pages_total
        self.kv_evictions = evictions
        self.kv_pages_shared = pages_shared
        self.kv_pages_logical = max(pages_logical, pages_used)

    def observe_engines(self, engines) -> None:
        """Aggregate KV memory pressure across a fleet of InferenceEngines.

        Uses each engine's windowed peak (`consume_window`) rather than its
        instantaneous occupancy: in the synchronous pipeline pools drain to
        zero between requests, so only the high-water mark since the last
        observation carries signal."""
        used = total = ev = shared = logical = 0
        for eng in engines:
            st = eng.memory_stats()
            if hasattr(eng, "consume_window"):
                w = eng.consume_window()
                used += w["pages"]
                shared += w["shared"]
                logical += w["logical"]
            elif hasattr(eng, "consume_peak"):
                peak = eng.consume_peak()
                used += peak
                logical += peak
            else:
                cur = int(st.get("pages_in_use", 0))
                used += cur
                logical += cur
            total += int(st.get("pages_total", 0))
            ev += int(st.get("evictions", 0))
            ps = int(getattr(eng, "page_size", 0) or 0)
            if ps:
                self.kv_page_tokens = ps
        self.update_memory(used, total, ev, pages_shared=shared,
                           pages_logical=logical)

    @property
    def kv_utilization(self) -> float:
        """Physical pool occupancy — COW sharing lowers this directly."""
        if self.kv_pages_total <= 0:
            return 0.0
        return self.kv_pages_used / self.kv_pages_total

    @property
    def kv_predicted_utilization(self) -> float:
        """Forecast pool occupancy: current physical pages plus the pages
        the queue's *predicted* output lengths will demand (the length
        predictor feeds `queued_expected_tokens` via `on_enqueue`). Equals
        `kv_utilization` exactly when nothing is queued or no page geometry
        has been observed, so callers that gate on it reproduce the
        physical-only behavior in those cases."""
        if self.kv_pages_total <= 0:
            return 0.0
        if self.kv_page_tokens <= 0 or self.queued_expected_tokens <= 0:
            return self.kv_utilization
        forecast = -(-self.queued_expected_tokens // self.kv_page_tokens)
        return min(1.0, (self.kv_pages_used + forecast)
                   / self.kv_pages_total)

    @property
    def kv_shared_fraction(self) -> float:
        """Fraction of used pages referenced by >1 slot. High values mean
        the occupancy is mostly shared prefixes: extra fan-out members are
        nearly free, but single-fork eviction reclaims little."""
        if self.kv_pages_used <= 0:
            return 0.0
        return self.kv_pages_shared / self.kv_pages_used

    @property
    def kv_sharing_savings(self) -> float:
        """1 - physical/logical: how much of the unshared footprint COW
        prefix sharing is currently absorbing."""
        if self.kv_pages_logical <= 0:
            return 0.0
        return 1.0 - self.kv_pages_used / self.kv_pages_logical
