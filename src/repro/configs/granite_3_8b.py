"""granite-3-8b [dense]: 40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.

[hf:ibm-granite/granite-3.0-2b-base family, 8b geometry as assigned]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49155,
    max_seq_len=524288,
    rope_theta=1e7,
    source="hf:ibm-granite/granite-3.0-2b-base (8b geometry)",
)
