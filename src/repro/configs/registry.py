"""Architecture registry + input shape suite.

Each assigned architecture has a module `repro.configs.<id>` exposing CONFIG
(the exact full-size config, with its source citation) — registered here under
its public --arch id. `input_specs(cfg, shape)` builds ShapeDtypeStruct
stand-ins for every model input of a (config, input-shape) pair: weak-type
correct, shardable, no device allocation.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig

ARCH_IDS = (
    "whisper_tiny",
    "qwen3_8b",
    "mixtral_8x7b",
    "xlstm_1p3b",
    "qwen3_moe_30b_a3b",
    "granite_3_8b",
    "zamba2_2p7b",
    "internvl2_2b",
    "minitron_8b",
    "qwen2_1p5b",
)

# public --arch names (hyphenated, as assigned) -> module name
ALIASES = {
    "whisper-tiny": "whisper_tiny",
    "qwen3-8b": "qwen3_8b",
    "mixtral-8x7b": "mixtral_8x7b",
    "xlstm-1.3b": "xlstm_1p3b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "granite-3-8b": "granite_3_8b",
    "zamba2-2.7b": "zamba2_2p7b",
    "internvl2-2b": "internvl2_2b",
    "minitron-8b": "minitron_8b",
    "qwen2-1.5b": "qwen2_1p5b",
}


def get_config(arch: str) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "p"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ALIASES}


# ---------------------------------------------------------------------------
# Input shapes (assigned suite)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def shape_supported(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    """None if supported; else a reason string for the documented skip."""
    if shape.name == "long_500k":
        if cfg.family == "encdec":
            return ("whisper decoder is pure full-attention with a 30s-audio "
                    "448-token model card; no meaningful sub-quadratic variant "
                    "(documented skip in DESIGN.md)")
    return None


def adapt_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Shape-specific config adaptation (documented in DESIGN.md):

    long_500k requires sub-quadratic decode state. SSM/hybrid archs are
    natively O(1)/windowed; mixtral already uses SWA. Pure full-attention
    dense archs switch to their sliding-window variant (window 4096) for this
    shape only.
    """
    if shape.name == "long_500k" and cfg.family in ("dense", "vlm") and not cfg.sliding_window:
        return cfg.with_(sliding_window=4096)
    return cfg


def input_specs(cfg: ModelConfig, shape: InputShape, spec: bool = True) -> dict:
    """ShapeDtypeStruct stand-ins for every input of the step function.

    Returns kwargs for train_step / prefill_step / decode_step respectively.
    """
    mk = (lambda sh, dt: jax.ShapeDtypeStruct(sh, dt)) if spec else (
        lambda sh, dt: jnp.zeros(sh, dt))
    B, S = shape.global_batch, shape.seq_len
    adt = jnp.dtype(cfg.dtype)
    out: dict = {}
    if shape.kind == "train":
        out["tokens"] = mk((B, S), jnp.int32)
        out["targets"] = mk((B, S), jnp.int32)
        if cfg.family == "encdec":
            e = cfg.encoder
            out["enc_frames"] = mk((B, e.n_ctx, e.d_model), adt)
        if cfg.family == "vlm" and cfg.n_prefix_tokens:
            out["prefix_embeds"] = mk((B, cfg.n_prefix_tokens, cfg.d_model), adt)
        return out
    if shape.kind == "prefill":
        out["tokens"] = mk((B, S), jnp.int32)
        out["prompt_lengths"] = mk((B,), jnp.int32)
        cache_len = S
        if cfg.family == "encdec":
            e = cfg.encoder
            out["enc_frames"] = mk((B, e.n_ctx, e.d_model), adt)
        if cfg.family == "vlm" and cfg.n_prefix_tokens:
            out["prefix_embeds"] = mk((B, cfg.n_prefix_tokens, cfg.d_model), adt)
            cache_len = S + cfg.n_prefix_tokens   # patch prefix lives in cache
        out["cache"] = transformer.init_cache(cfg, B, cache_len, spec=spec)
        return out
    # decode: ONE new token against a seq_len cache
    out["tokens"] = mk((B, 1), jnp.int32)
    out["cache"] = transformer.init_cache(cfg, B, S, spec=spec)
    return out
