"""internvl2-2b [vlm]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.

InternViT vision encoder is a stub — input_specs provides 256 projected patch
embeddings (B, 256, 2048); the InternLM2 language decoder consuming them IS
implemented. [arXiv:2404.16821]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    max_seq_len=524288,
    n_prefix_tokens=256,
    rope_theta=1e6,
    source="arXiv:2404.16821 (InternVL2), InternLM2-1.8B LM backbone",
)
