"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000.

Mamba2 backbone (ssm_state=64) with a shared (weight-tied) attention block
applied every 6 Mamba layers. [arXiv:2411.15242]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    max_seq_len=524288,
    ssm_state=64,
    ssm_expand=2,
    ssm_chunk=256,
    shared_attn_every=6,
    source="arXiv:2411.15242 (Zamba2)",
)
