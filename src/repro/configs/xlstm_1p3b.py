"""xlstm-1.3b [ssm]: 48L d_model=2048 4H d_ff=0 vocab=50304.

sLSTM + mLSTM blocks at 7:1 mLSTM:sLSTM ratio (sLSTM every 8th block);
d_ff=0 — the mLSTM up-projection replaces the FFN. [arXiv:2405.04517]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    max_seq_len=524288,
    slstm_at=(0, 8, 16, 24, 32, 40),
    ssm_chunk=256,
    source="arXiv:2405.04517 (xLSTM), 1.3B config",
)
