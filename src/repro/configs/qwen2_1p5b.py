"""qwen2-1.5b [dense]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.

GQA with QKV bias. kv=2 heads cannot split a 16-way model axis — the
divisibility-aware sharding helper replicates KV over `model` (standard GQA
tensor parallelism). [arXiv:2407.10671]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    max_seq_len=524288,
    qkv_bias=True,
    rope_theta=1e6,
    source="arXiv:2407.10671 (Qwen2), 1.5B",
)
