"""whisper-tiny [audio]: enc-dec, conv frontend stubbed. [arXiv:2212.04356]

4L decoder, d_model=384, 6H (kv=6), d_ff=1536, vocab=51865. The mel+conv
frontend is a stub: input_specs provides (B, 1500, 384) frame embeddings; the
4-layer transformer encoder over them IS implemented. LayerNorm + GELU +
learned positions per the Whisper architecture.
"""
from repro.models.config import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    max_seq_len=32768,          # decode_32k shape support (model card: 448)
    use_rope=False,
    use_layernorm=True,
    tie_embeddings=True,
    encoder=EncoderConfig(n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
                          d_ff=1536, n_ctx=1500),
    source="arXiv:2212.04356 (Whisper); tiny variant",
)
