"""PICE's own serving configuration: the cloud LLM + edge SLM fleet pairing.

The paper's testbed pairs Qwen2.5-72B/Llama3-70B on a cloud A100 server with
<8B SLMs on Jetson edge devices, recommending LLM >= 10x SLM. Full-size
configs reference the assigned archs (qwen3-8b cloud, qwen2-1.5b/xlstm/zamba2
edge ensemble = 5.3-8x parameter gap, the closest available pairing). TINY_*
variants are runnable-on-CPU models used by the examples and the real-compute
serving benchmarks; they keep the >=10x size ratio the paper recommends.
"""
from repro.configs.registry import get_config
from repro.models.config import ModelConfig


def cloud_config() -> ModelConfig:
    return get_config("qwen3-8b").with_(length_buckets=16)


def edge_configs() -> dict:
    return {
        "qwen2-1.5b": get_config("qwen2-1.5b"),
        "xlstm-1.3b": get_config("xlstm-1.3b"),
        "zamba2-2.7b": get_config("zamba2-2.7b"),
    }


# ---------------------------------------------------------------------------
# Tiny (CPU-runnable) variants — same families, >=10x cloud/edge param ratio.
# ---------------------------------------------------------------------------

TINY_CLOUD = ModelConfig(
    name="tiny-cloud",
    family="dense",
    n_layers=6,
    d_model=256,
    n_heads=8,
    n_kv_heads=4,
    head_dim=32,
    d_ff=1024,
    vocab_size=256,          # byte tokenizer
    max_seq_len=2048,
    qk_norm=True,
    length_buckets=16,
    remat=False,
    source="tiny qwen3-style cloud model for CPU testbed",
)

TINY_EDGE_A = ModelConfig(
    name="tiny-edge-a",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=256,
    max_seq_len=2048,
    qkv_bias=True,
    remat=False,
    source="tiny qwen2-style edge SLM",
)

TINY_EDGE_B = ModelConfig(
    name="tiny-edge-b",
    family="dense",
    n_layers=2,
    d_model=96,
    n_heads=4,
    n_kv_heads=4,
    head_dim=24,
    d_ff=192,
    vocab_size=256,
    max_seq_len=2048,
    remat=False,
    source="tiny llama-style edge SLM",
)

TINY_EDGE_C = ModelConfig(
    name="tiny-edge-c",
    family="ssm",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=256,
    max_seq_len=2048,
    ssm_state=16,
    ssm_chunk=64,
    remat=False,
    source="tiny mamba2-style edge SLM (O(1) decode state)",
)

TINY_EDGE_CONFIGS = {
    "tiny-edge-a": TINY_EDGE_A,
    "tiny-edge-b": TINY_EDGE_B,
    "tiny-edge-c": TINY_EDGE_C,
}
