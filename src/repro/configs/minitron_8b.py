"""minitron-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.

Pruned/distilled Nemotron-4. The 256k vocabulary stresses the sharded
embedding + logits path. [arXiv:2407.14679]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    max_seq_len=524288,
    rope_theta=1e6,
    source="arXiv:2407.14679 (Minitron / compact LMs via pruning+distillation)",
)
