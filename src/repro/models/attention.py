"""GQA attention: training/prefill (full-sequence) and decode (cached) paths.

Pure-jnp reference implementations; `cfg.use_pallas=True` routes the hot paths
through the Pallas kernels in repro.kernels (flash_attention for prefill,
decode_attention for cached decode).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import cache as cache_lib
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, dense_init, rmsnorm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attention(cfg: ModelConfig, key, cross: bool = False,
                   kv_d_model: Optional[int] = None) -> dict:
    pd = jnp.dtype(cfg.param_dtype)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    n_q, n_kv = cfg.n_heads, cfg.n_kv_heads
    kd = kv_d_model or d
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "wq": dense_init(k1, (d, n_q, hd), dtype=pd),
        "wk": dense_init(k2, (kd, n_kv, hd), dtype=pd),
        "wv": dense_init(k3, (kd, n_kv, hd), dtype=pd),
        "wo": dense_init(k4, (n_q, hd, d), in_axis=1, dtype=pd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((n_q, hd), pd)
        p["bk"] = jnp.zeros((n_kv, hd), pd)
        p["bv"] = jnp.zeros((n_kv, hd), pd)
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), pd)
        p["k_norm"] = jnp.ones((hd,), pd)
    return p


def _project_qkv(cfg: ModelConfig, params: dict, x: jax.Array,
                 kv_x: Optional[jax.Array] = None):
    dt = x.dtype
    kv_x = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dnh->bsnh", kv_x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dnh->bsnh", kv_x, params["wv"].astype(dt))
    if "bq" in params:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    if "q_norm" in params:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)
    return q, k, v


def _repeat_kv(k: jax.Array, q_per_kv: int) -> jax.Array:
    """(B,S,n_kv,hd) -> (B,S,n_q,hd) by repeating each kv head."""
    if q_per_kv == 1:
        return k
    return jnp.repeat(k, q_per_kv, axis=2)


def _sdpa(q, k, v, mask, softcap: float = 0.0):
    """q: (B,Tq,N,hd), k/v: (B,Tk,N,hd), mask broadcastable (B,1,Tq,Tk)."""
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    logits = jnp.einsum("bqnh,bknh->bnqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bnqk,bknh->bqnh", probs.astype(v.dtype), v)
    return out


# Use q-blocked attention when the logits matrix would exceed this many
# elements per (batch, head) — avoids materializing S x S at long context.
CHUNK_THRESHOLD = 4096 * 4096
CHUNK_BQ = 512


def chunked_sdpa(q, k, v, *, causal: bool, window: int = 0,
                 kv_lengths: Optional[jax.Array] = None,
                 softcap: float = 0.0) -> jax.Array:
    """Q-blocked attention (flash-style, pure jnp, lax.map over q blocks).

    q: (B,Sq,N,hd), k/v: (B,Sk,N,hd) already head-repeated. Never materializes
    more than (B, bq, N, Sk_eff) logits; with a sliding window only a
    (window + bq) K/V slice is read per block (true sub-quadratic compute).
    """
    B, Sq, N, hd = q.shape
    Sk = k.shape[1]
    bq = min(CHUNK_BQ, Sq)
    while Sq % bq:
        bq //= 2
    nb = Sq // bq
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))

    use_window_slice = bool(window) and (window + bq) <= Sk

    def block(i):
        qi = jax.lax.dynamic_slice_in_dim(q, i * bq, bq, axis=1)
        rows = i * bq + jnp.arange(bq)
        if use_window_slice:
            start = jnp.clip(i * bq + bq - (window + bq), 0, Sk - (window + bq))
            ki = jax.lax.dynamic_slice_in_dim(k, start, window + bq, axis=1)
            vi = jax.lax.dynamic_slice_in_dim(v, start, window + bq, axis=1)
            cols = start + jnp.arange(window + bq)
        else:
            ki, vi = k, v
            cols = jnp.arange(Sk)
        logits = jnp.einsum("bqnh,bknh->bnqk", qi.astype(jnp.float32),
                            ki.astype(jnp.float32)) * scale
        if softcap:
            logits = jnp.tanh(logits / softcap) * softcap
        m = jnp.ones((bq, cols.shape[0]), bool)
        if causal:
            m = m & (cols[None, :] <= rows[:, None])
        if window:
            m = m & (cols[None, :] > rows[:, None] - window)
        m = m[None, None]
        if kv_lengths is not None:
            m = m & (cols[None, None, None, :] < kv_lengths[:, None, None, None])
        logits = jnp.where(m, logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bnqk,bknh->bqnh", probs.astype(v.dtype), vi)

    # checkpoint each q-block: the VJP otherwise stores every block's f32
    # probs — a full (B, N, Sq, Sk) attention matrix across the loop (§Perf:
    # 343 GB/device at granite train_4k). Recomputed in backward instead.
    outs = jax.lax.map(jax.checkpoint(block), jnp.arange(nb))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, N, hd)


def full_or_chunked_sdpa(q, k, v, *, causal: bool, window: int = 0,
                         kv_lengths: Optional[jax.Array] = None,
                         softcap: float = 0.0) -> jax.Array:
    """Dense SDPA for short sequences, q-blocked for long ones."""
    Sq, Sk = q.shape[1], k.shape[1]
    if Sq * Sk >= CHUNK_THRESHOLD and Sq > 1:
        return chunked_sdpa(q, k, v, causal=causal, window=window,
                            kv_lengths=kv_lengths, softcap=softcap)
    mask = jnp.ones((1, 1, Sq, Sk), bool)
    if causal and Sq == Sk:
        mask = causal_mask(Sq, Sk, window=window)
    if kv_lengths is not None:
        mask = mask & (jnp.arange(Sk)[None, None, None, :]
                       < kv_lengths[:, None, None, None])
    return _sdpa(q, k, v, mask, softcap)


def causal_mask(Tq: int, Tk: int, q_offset: int = 0,
                window: int = 0) -> jax.Array:
    """(1,1,Tq,Tk) bool; window>0 applies sliding-window causality."""
    qi = jnp.arange(Tq)[:, None] + q_offset
    ki = jnp.arange(Tk)[None, :]
    m = ki <= qi
    if window:
        m = m & (ki > qi - window)
    return m[None, None]


# ---------------------------------------------------------------------------
# Full-sequence (train / prefill)
# ---------------------------------------------------------------------------

def attention_fwd(cfg: ModelConfig, params: dict, x: jax.Array,
                  positions: jax.Array, *, causal: bool = True,
                  segment_mask: Optional[jax.Array] = None) -> jax.Array:
    """Self-attention over a full sequence. x: (B, S, D)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(cfg, params, x)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.use_pallas:
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(q, k, v, causal=causal,
                                     window=cfg.sliding_window,
                                     softcap=cfg.attn_logit_softcap)
    else:
        k = _repeat_kv(k, cfg.q_per_kv)
        v = _repeat_kv(v, cfg.q_per_kv)
        if segment_mask is not None:
            mask = causal_mask(S, S, window=cfg.sliding_window) if causal \
                else jnp.ones((1, 1, S, S), bool)
            out = _sdpa(q, k, v, mask & segment_mask, cfg.attn_logit_softcap)
        else:
            out = full_or_chunked_sdpa(q, k, v, causal=causal,
                                       window=cfg.sliding_window,
                                       softcap=cfg.attn_logit_softcap)
    dt = x.dtype
    return jnp.einsum("bsnh,nhd->bsd", out, params["wo"].astype(dt))


def cross_attention_fwd(cfg: ModelConfig, params: dict, x: jax.Array,
                        enc_out: jax.Array) -> jax.Array:
    """Cross-attention (whisper decoder): x (B,T,D) attends enc_out (B,Se,De)."""
    q, k, v = _project_qkv(cfg, params, x, kv_x=enc_out)
    k = _repeat_kv(k, cfg.q_per_kv)
    v = _repeat_kv(v, cfg.q_per_kv)
    out = full_or_chunked_sdpa(q, k, v, causal=False,
                               softcap=cfg.attn_logit_softcap)
    return jnp.einsum("bsnh,nhd->bsd", out, params["wo"].astype(x.dtype))


def cross_attention_cached(cfg: ModelConfig, params: dict, x: jax.Array,
                           ck: jax.Array, cv: jax.Array) -> jax.Array:
    """Decode-time cross-attention against precomputed enc K/V (B,Se,n_kv,hd)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"].astype(dt))
    if "bq" in params:
        q = q + params["bq"].astype(dt)
    k = _repeat_kv(ck, cfg.q_per_kv)
    v = _repeat_kv(cv, cfg.q_per_kv)
    out = full_or_chunked_sdpa(q, k, v, causal=False,
                               softcap=cfg.attn_logit_softcap)
    return jnp.einsum("bsnh,nhd->bsd", out, params["wo"].astype(dt))


# ---------------------------------------------------------------------------
# Decode (single or few new tokens against a cache)
# ---------------------------------------------------------------------------

def attention_decode(cfg: ModelConfig, params: dict, x: jax.Array,
                     layer_k: jax.Array, layer_v: jax.Array,
                     lengths: jax.Array, window: int = 0
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Decode step. x: (B, T, D) with T new tokens (usually 1).

    layer_k/layer_v: (B, Scache, n_kv, hd); lengths: (B,) tokens already in
    cache. Returns (out, new_layer_k, new_layer_v).
    """
    B, T, _ = x.shape
    q, k, v = _project_qkv(cfg, params, x)
    positions = lengths[:, None] + jnp.arange(T)[None, :]
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    layer_k, layer_v = cache_lib.update_layer_kv(layer_k, layer_v, lengths,
                                                 k, v, window=window)
    Sc = layer_k.shape[1]
    if cfg.use_pallas and T == 1 and not window:
        from repro.kernels.decode_attention import ops as da_ops
        out = da_ops.decode_attention(q, layer_k, layer_v, lengths + T)
    else:
        ki = jnp.arange(Sc)[None, None, :]                     # (1,1,Sc)
        qpos = positions[:, :, None]                           # (B,T,1)
        if window:
            # ring buffer: entry at slot s holds absolute position p iff
            # p % window == s and p <= qpos and p > qpos - window.
            # Reconstruct absolute position of each slot given current length.
            total = lengths[:, None, None] + T                 # tokens after write
            abs_pos = ki + ((total - 1 - ki) // window) * window
            valid = (abs_pos <= qpos) & (abs_pos > qpos - window) & (abs_pos >= 0)
            mask = valid[:, None]                              # (B,1,T,Sc)
        else:
            mask = (ki <= qpos)[:, None]
        out = _grouped_sdpa(q, layer_k, layer_v, mask, cfg.q_per_kv,
                            cfg.attn_logit_softcap)
    dt = x.dtype
    out = jnp.einsum("bsnh,nhd->bsd", out, params["wo"].astype(dt))
    return out, layer_k, layer_v


def attention_decode_paged(cfg: ModelConfig, params: dict, x: jax.Array,
                           k_pages: jax.Array, v_pages: jax.Array,
                           block_table: jax.Array, lengths: jax.Array,
                           live_pages: Optional[int] = None,
                           active: Optional[jax.Array] = None,
                           k_scales: Optional[jax.Array] = None,
                           v_scales: Optional[jax.Array] = None):
    """Decode step against a paged KV pool (vLLM-style block table).

    x: (B, 1, D); k_pages/v_pages: (n_pages, page, n_kv, hd) this layer's
    pools; block_table: (B, P) page ids (-1 = unmapped); lengths: (B,) tokens
    already cached per slot. Returns (out, new_k_pages, new_v_pages,
    new_k_scales, new_v_scales) — the scales are None unless
    cfg.kv_quantized, in which case k/v_scales: (n_pages, n_kv) f32 are the
    pool's per-(page, kv-head) dequant scales and the whole path follows the
    quantized tolerance contract (docs/serving.md) instead of bit-exactness.

    live_pages (static) trims the READ width to the first `live_pages`
    block-table columns — callers pass ceil((max(lengths)+1)/page_size),
    bucketed to bound recompilation. Trimmed columns are beyond every slot's
    valid positions, whose softmax weight is exactly zero, so outputs are
    bit-identical at any covering width; the token write uses the full table.

    The read path is keyed on cfg.use_pallas: the paged flash-decode kernel
    streams only mapped pages through the block table (per-step KV volume
    O(sum lengths)); the fallback/oracle gathers the (trimmed) table into
    the contiguous layout and runs the same masked grouped SDPA as the
    dense path, so dense and paged backends stay bit-identical on it.

    `active` (B,) bool, when given, drops inactive rows' K/V writes — the
    plan/run engine defers freed slots' block-table clears, so a stale row
    may still map pages a COW sibling owns (see pc.write_token).
    """
    from repro.models import paged_cache as pc
    B, T, _ = x.shape
    q, k, v = _project_qkv(cfg, params, x)
    positions = lengths[:, None] + jnp.arange(T)[None, :]
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    dt = x.dtype
    table = block_table if live_pages is None \
        else block_table[:, :live_pages]
    if cfg.kv_quantized:
        k_pages, v_pages, k_scales, v_scales = pc.write_token_quant(
            k_pages, v_pages, k_scales, v_scales, block_table, lengths,
            k, v, cfg.kv_dtype, active=active)
        if cfg.use_pallas and T == 1 and not cfg.attn_logit_softcap:
            from repro.kernels.paged_decode_attention import ops as pda_ops
            out = pda_ops.paged_decode_attention_quant(
                q, k_pages, v_pages, k_scales, v_scales, table, lengths + T)
        else:
            gk = pc.gather_sequence_dequant(k_pages, k_scales, table)
            gv = pc.gather_sequence_dequant(v_pages, v_scales, table)
            Sc = gk.shape[1]
            ki = jnp.arange(Sc)[None, None, :]
            qpos = positions[:, :, None]
            mask = (ki <= qpos)[:, None]
            out = _grouped_sdpa(q.astype(jnp.float32), gk, gv, mask,
                                cfg.q_per_kv, cfg.attn_logit_softcap)
        out = out.astype(dt)
        out = jnp.einsum("bsnh,nhd->bsd", out, params["wo"].astype(dt))
        return out, k_pages, v_pages, k_scales, v_scales
    k_pages, v_pages = pc.write_token(k_pages, v_pages, block_table, lengths,
                                      k, v, active=active)
    if cfg.use_pallas and T == 1 and not cfg.attn_logit_softcap:
        from repro.kernels.paged_decode_attention import ops as pda_ops
        # the new token was just written at position `lengths`
        out = pda_ops.paged_decode_attention(q, k_pages, v_pages, table,
                                             lengths + T)
    else:
        gk = pc.gather_sequence(k_pages, table)
        gv = pc.gather_sequence(v_pages, table)
        Sc = gk.shape[1]
        ki = jnp.arange(Sc)[None, None, :]
        qpos = positions[:, :, None]
        mask = (ki <= qpos)[:, None]
        out = _grouped_sdpa(q, gk, gv, mask, cfg.q_per_kv,
                            cfg.attn_logit_softcap)
    out = jnp.einsum("bsnh,nhd->bsd", out, params["wo"].astype(dt))
    return out, k_pages, v_pages, None, None


def attention_prefill_chunk_paged(cfg: ModelConfig, params: dict, x: jax.Array,
                                  k_pages: jax.Array, v_pages: jax.Array,
                                  block_row: jax.Array, offset, chunk_len,
                                  live_pages: Optional[int] = None,
                                  k_scales: Optional[jax.Array] = None,
                                  v_scales: Optional[jax.Array] = None):
    """One prompt chunk against a paged KV pool (chunked prefill).

    x: (1, C, D) — C new tokens of ONE slot, right-padded to `chunk_len`
    valid; block_row: (P,) the slot's block-table row; offset: () tokens
    already written for this slot (the chunk's first logical position).
    Writes the chunk's K/V at offset..offset+chunk_len-1, then attends each
    chunk query causally within the chunk AND against everything the slot
    already holds (ragged cross-chunk read). Returns (out, k_pages, v_pages,
    k_scales, v_scales) — scales are None unless cfg.kv_quantized (see
    attention_decode_paged).

    The oracle/fallback reads through the same gather + `_grouped_sdpa`
    formulation as the paged decode step — deliberately: the grouped einsum
    is reduction-order stable across query counts, so a chunk of C tokens
    produces bitwise the outputs of C single-token decode steps (fork-suffix
    and eviction-resume replays stay bit-identical to uninterrupted decode),
    and at C > 1 it matches the monolithic `_prefill_block` SDPA bitwise.
    `cfg.use_pallas` routes the read through the paged-prefill Pallas kernel
    (kernels/paged_prefill_attention), which streams only the slot's mapped
    pages HBM->VMEM through the scalar-prefetched block row.
    """
    B, C, _ = x.shape
    q, k, v = _project_qkv(cfg, params, x)
    positions = jnp.asarray(offset, jnp.int32) + jnp.arange(C)[None]
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    from repro.models import paged_cache as pc
    dt = x.dtype
    row = block_row if live_pages is None else block_row[:live_pages]
    if cfg.kv_quantized:
        k_pages, v_pages, k_scales, v_scales = pc.write_prompt_quant(
            k_pages, v_pages, k_scales, v_scales, block_row, k, v,
            chunk_len, cfg.kv_dtype, offset=offset)
        if cfg.use_pallas and not cfg.attn_logit_softcap:
            from repro.kernels.paged_prefill_attention import ops as ppa_ops
            out = ppa_ops.paged_prefill_attention_quant(
                q, k_pages, v_pages, k_scales, v_scales, row, offset,
                chunk_len)
        else:
            gk = pc.gather_sequence_dequant(k_pages, k_scales, row[None])
            gv = pc.gather_sequence_dequant(v_pages, v_scales, row[None])
            Sc = gk.shape[1]
            ki = jnp.arange(Sc)[None, None, :]
            qpos = positions[:, :, None]
            mask = (ki <= qpos)[:, None]
            out = _grouped_sdpa(q.astype(jnp.float32), gk, gv, mask,
                                cfg.q_per_kv, cfg.attn_logit_softcap)
        out = out.astype(dt)
        out = jnp.einsum("bsnh,nhd->bsd", out, params["wo"].astype(dt))
        return out, k_pages, v_pages, k_scales, v_scales
    k_pages, v_pages = pc.write_prompt(k_pages, v_pages, block_row, k, v,
                                       chunk_len, offset=offset)
    if cfg.use_pallas and not cfg.attn_logit_softcap:
        from repro.kernels.paged_prefill_attention import ops as ppa_ops
        out = ppa_ops.paged_prefill_attention(q, k_pages, v_pages, row,
                                              offset, chunk_len)
    else:
        gk = pc.gather_sequence(k_pages, row[None])
        gv = pc.gather_sequence(v_pages, row[None])
        Sc = gk.shape[1]
        ki = jnp.arange(Sc)[None, None, :]
        qpos = positions[:, :, None]
        mask = (ki <= qpos)[:, None]
        out = _grouped_sdpa(q, gk, gv, mask, cfg.q_per_kv,
                            cfg.attn_logit_softcap)
    out = jnp.einsum("bsnh,nhd->bsd", out, params["wo"].astype(dt))
    return out, k_pages, v_pages, None, None


def attention_prefill_ragged_paged(cfg: ModelConfig, params: dict,
                                   x: jax.Array, k_pages: jax.Array,
                                   v_pages: jax.Array, block_rows: jax.Array,
                                   offsets: jax.Array, lens: jax.Array,
                                   live_pages: Optional[int] = None,
                                   k_scales: Optional[jax.Array] = None,
                                   v_scales: Optional[jax.Array] = None):
    """R prompt chunks — one per ingesting slot — against a paged KV pool in
    a single call (batched ragged ingest).

    x: (R, C, D) — row r is slot r's next chunk, right-padded to `lens[r]`
    valid tokens; block_rows: (R, P) the slots' block-table rows (pre-trimmed
    to the shared live width); offsets: (R,) tokens already written per slot.
    Writes every row's chunk K/V (`pc.write_prompt_ragged` — distinct slots
    own distinct pages, so the scatter is collision-free), then attends each
    row's queries causally within its chunk AND against everything that slot
    already holds. Returns (out, k_pages, v_pages, k_scales, v_scales) —
    scales are None unless cfg.kv_quantized (see attention_decode_paged);
    row r positions past lens[r] are unspecified, as are padding rows
    (lens == 0).

    Numerics contract: both read paths are row-independent — the oracle is
    the same gather + `_grouped_sdpa` formulation as the single-slot chunk
    path (batching adds rows, never changes a row's reduction order), and the
    ragged Pallas kernel walks each row's pages exactly as the single-slot
    kernel does — so batched ingest is bitwise the one-chunk-per-step
    scheduler, which is in turn bitwise monolithic prefill.
    """
    R, C, _ = x.shape
    q, k, v = _project_qkv(cfg, params, x)
    positions = offsets[:, None] + jnp.arange(C)[None, :]          # (R, C)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    from repro.models import paged_cache as pc
    dt = x.dtype
    rows = block_rows if live_pages is None else block_rows[:, :live_pages]
    if cfg.kv_quantized:
        k_pages, v_pages, k_scales, v_scales = pc.write_prompt_ragged_quant(
            k_pages, v_pages, k_scales, v_scales, block_rows, k, v, lens,
            offsets, cfg.kv_dtype)
        if cfg.use_pallas and not cfg.attn_logit_softcap:
            from repro.kernels.paged_prefill_attention import ops as ppa_ops
            out = ppa_ops.paged_prefill_attention_ragged_quant(
                q, k_pages, v_pages, k_scales, v_scales, rows, offsets, lens)
        else:
            gk = pc.gather_sequence_dequant(k_pages, k_scales, rows)
            gv = pc.gather_sequence_dequant(v_pages, v_scales, rows)
            Sc = gk.shape[1]
            ki = jnp.arange(Sc)[None, None, :]
            qpos = positions[:, :, None]
            mask = (ki <= qpos)[:, None]
            out = _grouped_sdpa(q.astype(jnp.float32), gk, gv, mask,
                                cfg.q_per_kv, cfg.attn_logit_softcap)
        out = out.astype(dt)
        out = jnp.einsum("bsnh,nhd->bsd", out, params["wo"].astype(dt))
        return out, k_pages, v_pages, k_scales, v_scales
    k_pages, v_pages = pc.write_prompt_ragged(k_pages, v_pages, block_rows,
                                              k, v, lens, offsets)
    if cfg.use_pallas and not cfg.attn_logit_softcap:
        from repro.kernels.paged_prefill_attention import ops as ppa_ops
        out = ppa_ops.paged_prefill_attention_ragged(q, k_pages, v_pages,
                                                     rows, offsets, lens)
    else:
        gk = pc.gather_sequence(k_pages, rows)         # (R, P*page, kv, hd)
        gv = pc.gather_sequence(v_pages, rows)
        Sc = gk.shape[1]
        ki = jnp.arange(Sc)[None, None, :]
        qpos = positions[:, :, None]
        mask = (ki <= qpos)[:, None]
        out = _grouped_sdpa(q, gk, gv, mask, cfg.q_per_kv,
                            cfg.attn_logit_softcap)
    out = jnp.einsum("bsnh,nhd->bsd", out, params["wo"].astype(dt))
    return out, k_pages, v_pages, None, None


def _grouped_sdpa(q, k, v, mask, q_per_kv: int, softcap: float = 0.0):
    """GQA attention WITHOUT materializing repeated K/V.

    q: (B,Tq,Nq,hd) -> grouped (B,Tq,Nkv,g,hd); k/v: (B,Tk,Nkv,hd); mask
    broadcastable to (B,1,Tq,Tk). jnp.repeat of the cache forces GSPMD to
    reshard it (involuntary full-rematerialization all-gathers — §Perf:
    77 GB/step at qwen3-8b decode_32k); the grouped einsum keeps the cache
    sharding intact.
    """
    if q_per_kv == 1:
        return _sdpa(q, k, v, mask, softcap)
    B, Tq, Nq, hd = q.shape
    Nkv = k.shape[2]
    qg = q.reshape(B, Tq, Nkv, q_per_kv, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    # keep operands in their storage dtype (bf16) with f32 MXU accumulation:
    # upcasting the cache first would double any resharding traffic (§Perf)
    logits = jnp.einsum("bqngh,bknh->bngqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    logits = jnp.where(mask[:, :, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bngqk,bknh->bqngh", probs.astype(v.dtype), v)
    return out.reshape(B, Tq, Nq, hd)
