"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable) and
sLSTM (scalar memory with recurrent gate connections).

mLSTM recurrence (per head, stabilized exponential gating):
    m_t = max(log f_t + m_{t-1}, log i_t)
    C_t = f~_t C_{t-1} + i~_t v_t k_t^T      (matrix memory, dk x dv)
    n_t = f~_t n_{t-1} + i~_t k_t
    h_t = (C_t^T q_t) / max(|n_t . q_t|, 1)
Training/prefill uses a chunkwise-parallel form (intra-chunk quasi-attention +
inter-chunk state carry); decode uses the O(1) recurrent step.

sLSTM keeps per-unit scalar memory with recurrent weights R, so it must scan
over time in all modes (the paper notes it is not parallelizable).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, rmsnorm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    inner = 2 * cfg.d_model
    H = cfg.n_heads
    hd = inner // H
    return inner, H, hd


def init_mlstm(cfg: ModelConfig, key) -> dict:
    pd = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    inner, H, hd = mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], (d, 2 * inner), dtype=pd),       # x_m | z
        "wq": dense_init(ks[1], (inner, inner), dtype=pd),
        "wk": dense_init(ks[2], (inner, inner), dtype=pd),
        "wv": dense_init(ks[3], (inner, inner), dtype=pd),
        "w_if": dense_init(ks[4], (inner, 2 * H), dtype=pd),       # i,f gate logits
        "b_i": jnp.zeros((H,), pd),
        "b_f": jnp.full((H,), 3.0, pd),                            # forget-bias init
        "norm_scale": jnp.ones((inner,), pd),
        "w_down": dense_init(ks[5], (inner, d), dtype=pd),
    }


def _mlstm_gates(params, xm, H):
    g = (xm @ params["w_if"].astype(xm.dtype)).astype(jnp.float32)
    log_i = g[..., :H] + params["b_i"].astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(g[..., H:] + params["b_f"].astype(jnp.float32))
    return log_i, log_f


def mlstm_fwd(cfg: ModelConfig, params: dict, u: jax.Array,
              state: Optional[dict] = None, return_state: bool = False):
    """Full-sequence chunkwise-parallel mLSTM. u: (B,S,D)."""
    dt_ = u.dtype
    B, S, D = u.shape
    inner, H, hd = mlstm_dims(cfg)
    up = u @ params["w_up"].astype(dt_)
    xm, z = up[..., :inner], up[..., inner:]
    q = (xm @ params["wq"].astype(dt_)).reshape(B, S, H, hd)
    k = (xm @ params["wk"].astype(dt_)).reshape(B, S, H, hd)
    v = (xm @ params["wv"].astype(dt_)).reshape(B, S, H, hd)
    log_i, log_f = _mlstm_gates(params, xm, H)                 # (B,S,H)

    Q = cfg.ssm_chunk or 256
    Q = min(Q, S)
    while S % Q:
        Q //= 2
    nC = S // Q
    qf = q.astype(jnp.float32).reshape(B, nC, Q, H, hd) / jnp.sqrt(float(hd))
    kf = k.astype(jnp.float32).reshape(B, nC, Q, H, hd)
    vf = v.astype(jnp.float32).reshape(B, nC, Q, H, hd)
    li = log_i.reshape(B, nC, Q, H)
    lf = log_f.reshape(B, nC, Q, H)

    csum_f = jnp.cumsum(lf, axis=2)                            # within-chunk cumsum
    total_f = csum_f[:, :, -1]                                 # (B,nC,H)

    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    def chunk_step(carry, inp):
        C, n, m = carry
        qc, kc, vc, lic, csumf, totf = inp                     # leading dim B
        # decay from chunk start to position t (state path) with stabilizer m
        log_a = csumf + m[:, None, :]                          # (B,Q,H)
        # intra-chunk pair decays: D[t,s] = sum_{s<r<=t} lf_r + li_s  (s<=t)
        dcum = csumf[:, :, None, :] - csumf[:, None, :, :]     # (B,Q,Q,H) t,s
        Dmat = dcum + lic[:, None, :, :]                       # add log_i at s
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        Dmat = jnp.where(tri[None, :, :, None], Dmat, -jnp.inf)
        m_intra = jnp.max(Dmat, axis=2)                        # (B,Q,H)
        m_new = jnp.maximum(log_a, m_intra)                    # running stabilizer
        # state contribution
        sa = jnp.exp(log_a - m_new)                            # (B,Q,H)
        h_state = jnp.einsum("bqhk,bhkv->bqhv", qc, C) * sa[..., None]
        n_state = jnp.einsum("bqhk,bhk->bqh", qc, n) * sa
        # intra contribution
        w = jnp.exp(Dmat - m_new[:, :, None, :])               # (B,Q,Q,H)
        scores = jnp.einsum("bqhk,bshk->bqsh", qc, kc) * w
        h_intra = jnp.einsum("bqsh,bshv->bqhv", scores, vc)
        n_intra = jnp.sum(scores, axis=2)                      # (B,Q,H)
        h_num = h_state + h_intra
        n_tot = n_state + n_intra
        denom = jnp.maximum(jnp.abs(n_tot), jnp.exp(-m_new))
        h = h_num / denom[..., None]                           # (B,Q,H,hd)
        # carry update to end of chunk
        m_end = jnp.maximum(totf + m, jnp.max(lic + (totf[:, None] - csumf), axis=1))
        decay_state = jnp.exp(totf + m - m_end)                # (B,H)
        kw = jnp.exp(lic + (totf[:, None] - csumf) - m_end[:, None])  # (B,Q,H)
        C_new = C * decay_state[..., None, None] + jnp.einsum(
            "bshk,bshv->bhkv", kc * kw[..., None], vc)
        n_new = n * decay_state[..., None] + jnp.einsum("bshk,bsh->bhk", kc, kw)
        return (C_new, n_new, m_end), h

    inputs = (
        jnp.moveaxis(qf, 1, 0), jnp.moveaxis(kf, 1, 0), jnp.moveaxis(vf, 1, 0),
        jnp.moveaxis(li, 1, 0), jnp.moveaxis(csum_f, 1, 0), jnp.moveaxis(total_f, 1, 0),
    )
    (Cf, nf, mf), hs = jax.lax.scan(chunk_step, (C0, n0, m0), inputs)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, inner).astype(dt_)
    h = rmsnorm(h, params["norm_scale"], cfg.norm_eps)
    out = (h * jax.nn.silu(z)) @ params["w_down"].astype(dt_)
    if return_state:
        return out, {"C": Cf, "n": nf, "m": mf}
    return out


def mlstm_decode(cfg: ModelConfig, params: dict, u: jax.Array, state: dict):
    """O(1) recurrent step. u: (B,1,D); state {C (B,H,hd,hd), n, m}."""
    dt_ = u.dtype
    B = u.shape[0]
    inner, H, hd = mlstm_dims(cfg)
    up = u @ params["w_up"].astype(dt_)
    xm, z = up[..., :inner], up[..., inner:]
    q = (xm @ params["wq"].astype(dt_)).reshape(B, H, hd).astype(jnp.float32)
    k = (xm @ params["wk"].astype(dt_)).reshape(B, H, hd).astype(jnp.float32)
    v = (xm @ params["wv"].astype(dt_)).reshape(B, H, hd).astype(jnp.float32)
    q = q / jnp.sqrt(float(hd))
    log_i, log_f = _mlstm_gates(params, xm[:, 0], H)           # (B,H)
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(log_f + m, log_i)
    fs = jnp.exp(log_f + m - m_new)
    is_ = jnp.exp(log_i - m_new)
    C = C * fs[..., None, None] + is_[..., None, None] * (k[..., :, None] * v[..., None, :])
    n = n * fs[..., None] + is_[..., None] * k
    h_num = jnp.einsum("bhk,bhkv->bhv", q, C)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n)), jnp.exp(-m_new))
    h = (h_num / denom[..., None]).reshape(B, 1, inner).astype(dt_)
    h = rmsnorm(h, params["norm_scale"], cfg.norm_eps)
    out = (h * jax.nn.silu(z)) @ params["w_down"].astype(dt_)
    return out, {"C": C, "n": n, "m": m_new}


def init_mlstm_state(cfg: ModelConfig, batch: int) -> dict:
    inner, H, hd = mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(cfg: ModelConfig, key) -> dict:
    pd = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    ff = max(1, (4 * d) // 3)
    ks = jax.random.split(key, 6)
    return {
        "w_gates": dense_init(ks[0], (d, 4 * d), dtype=pd),        # i,f,z,o
        "r_gates": dense_init(ks[1], (d, 4 * d), dtype=pd),        # recurrent
        "b_gates": jnp.concatenate([
            jnp.zeros((d,)), jnp.full((d,), 3.0), jnp.zeros((2 * d,))]).astype(pd),
        "norm_scale": jnp.ones((d,), pd),
        "w_ff_gate": dense_init(ks[2], (d, ff), dtype=pd),
        "w_ff_up": dense_init(ks[3], (d, ff), dtype=pd),
        "w_ff_down": dense_init(ks[4], (ff, d), dtype=pd),
    }


def init_slstm_state(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"h": z, "c": z, "n": z + 1e-6, "m": z - 1e30}


def _slstm_step(cfg: ModelConfig, params: dict, x_t: jax.Array, st: dict):
    """x_t: (B, 4*d) pre-projected input gates. Returns (new_state, h_out)."""
    d = cfg.d_model
    rec = (st["h"].astype(jnp.float32) @ params["r_gates"].astype(jnp.float32))
    g = x_t.astype(jnp.float32) + rec + params["b_gates"].astype(jnp.float32)
    gi, gf, gz, go = g[:, :d], g[:, d:2 * d], g[:, 2 * d:3 * d], g[:, 3 * d:]
    log_i = gi
    log_f = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(log_f + st["m"], log_i)
    i_ = jnp.exp(log_i - m_new)
    f_ = jnp.exp(log_f + st["m"] - m_new)
    c = f_ * st["c"] + i_ * jnp.tanh(gz)
    n = f_ * st["n"] + i_
    h = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1e-6)
    return {"h": h, "c": c, "n": n, "m": m_new}, h


def slstm_fwd(cfg: ModelConfig, params: dict, u: jax.Array,
              state: Optional[dict] = None, return_state: bool = False):
    """u: (B,S,D). Scans over time (sLSTM is inherently sequential)."""
    dt_ = u.dtype
    B, S, d = u.shape
    if state is None:
        state = init_slstm_state(cfg, B)
    xg = u @ params["w_gates"].astype(dt_)                     # (B,S,4d)

    def step(st, x_t):
        st2, h = _slstm_step(cfg, params, x_t, st)
        return st2, h

    final, hs = jax.lax.scan(step, state, jnp.moveaxis(xg, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(dt_)                     # (B,S,d)
    h = rmsnorm(h, params["norm_scale"], cfg.norm_eps)
    # gated FFN (proj factor 4/3 per xLSTM)
    gate = h @ params["w_ff_gate"].astype(dt_)
    upv = h @ params["w_ff_up"].astype(dt_)
    out = (jax.nn.gelu(gate) * upv) @ params["w_ff_down"].astype(dt_)
    if return_state:
        return out, final
    return out


def slstm_decode(cfg: ModelConfig, params: dict, u: jax.Array, state: dict):
    """u: (B,1,D)."""
    dt_ = u.dtype
    xg = (u[:, 0] @ params["w_gates"].astype(dt_))
    st2, h = _slstm_step(cfg, params, xg, state)
    h = rmsnorm(h.astype(dt_)[:, None], params["norm_scale"], cfg.norm_eps)
    gate = h @ params["w_ff_gate"].astype(dt_)
    upv = h @ params["w_ff_up"].astype(dt_)
    out = (jax.nn.gelu(gate) * upv) @ params["w_ff_down"].astype(dt_)
    return out, st2
