"""Paged KV cache (vLLM PagedAttention analogue, pure JAX).

Physical storage is a page pool per layer; sequences map to pages through a
block table, so slot memory is allocated on demand and freed on completion —
no per-slot max_len reservation. The decode read path is keyed on
cfg.use_pallas: kernels/paged_decode_attention streams mapped pages
HBM->VMEM directly through the block table (no contiguous copy); the
`gather_sequence` formulation below is its jnp oracle and the non-TPU
fallback. Callers should trim the table they read through to the live
width (ceil(max(lengths)/page_size) columns) so even the gather stops
paying for `max_pages_per_seq`.

Layout:
  pages:       (L, n_pages, page_size, n_kv, hd)
  block_table: (B, max_pages_per_seq) int32  (-1 = unmapped)
  lengths:     (B,)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Quantized KV pages (cfg.kv_dtype in {"int8", "fp8"}).
#
# Storage is the quantized pool plus a per-(page, kv-head) f32 scale tensor
# (n_pages, n_kv). Writes requantize whole pages: dequantize the touched
# page, overlay the new tokens in f32, recompute abs-max over the valid
# positions, rescale, and scatter page + scale together. Earlier tokens in
# a page are therefore re-rounded at most page_size times — a bounded error
# the tolerance contract in docs/serving.md covers. Reads dequantize either
# in-VMEM right after the page DMA (Pallas kernels) or via
# `gather_sequence_dequant` (oracle / non-TPU fallback).
# ---------------------------------------------------------------------------

KV_QMAX = {"int8": 127.0, "fp8": 448.0}


def kv_storage_dtype(kv_dtype: str):
    """jnp dtype a paged pool stores for a resolved kv_dtype string."""
    if kv_dtype == "int8":
        return jnp.int8
    if kv_dtype == "fp8":
        return jnp.float8_e4m3fn
    return jnp.dtype(kv_dtype)


def quant_scale(amax: jax.Array, kv_dtype: str) -> jax.Array:
    """Per-(page, kv-head) scale from the abs-max of its valid positions."""
    return jnp.where(amax > 0, amax / KV_QMAX[kv_dtype], 1.0)


def _quantize(x: jax.Array, scale: jax.Array, kv_dtype: str) -> jax.Array:
    """x: f32 (..., page, kv, hd); scale: (..., kv) -> storage dtype."""
    y = x / scale[..., None, :, None]
    if kv_dtype == "int8":
        return jnp.clip(jnp.round(y), -127.0, 127.0).astype(jnp.int8)
    return y.astype(jnp.float8_e4m3fn)


def init_paged_kv(n_layers: int, n_pages: int, page_size: int, n_kv: int,
                  head_dim: int, batch: int, max_pages_per_seq: int,
                  dtype=jnp.bfloat16) -> dict:
    return {
        "k_pages": jnp.zeros((n_layers, n_pages, page_size, n_kv, head_dim),
                             dtype),
        "v_pages": jnp.zeros((n_layers, n_pages, page_size, n_kv, head_dim),
                             dtype),
        "block_table": jnp.full((batch, max_pages_per_seq), -1, jnp.int32),
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def gather_sequence(pages: jax.Array, block_table: jax.Array) -> jax.Array:
    """pages: (n_pages, page, n_kv, hd); block_table: (B, P) ->
    contiguous (B, P*page, n_kv, hd). Unmapped (-1) pages read page 0 and
    must be masked by `lengths` downstream."""
    idx = jnp.maximum(block_table, 0)
    g = pages[idx]                                   # (B, P, page, kv, hd)
    B, P, page, kv, hd = g.shape
    return g.reshape(B, P * page, kv, hd)


def gather_sequence_dequant(pages: jax.Array, scales: jax.Array,
                            block_table: jax.Array) -> jax.Array:
    """`gather_sequence` for a quantized pool: dequantize per-(page, head)
    on read, returning contiguous f32 (B, P*page, n_kv, hd). scales:
    (n_pages, n_kv) f32."""
    idx = jnp.maximum(block_table, 0)
    g = pages[idx].astype(jnp.float32)               # (B, P, page, kv, hd)
    g = g * scales[idx][:, :, None, :, None]
    B, P, page, kv, hd = g.shape
    return g.reshape(B, P * page, kv, hd)


def write_token(pages_k: jax.Array, pages_v: jax.Array, block_table: jax.Array,
                lengths: jax.Array, new_k: jax.Array, new_v: jax.Array,
                active: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Write one token per slot at its current length.

    pages_*: (n_pages, page, kv, hd); new_*: (B, 1, kv, hd). `active` (B,)
    bool, when given, drops inactive rows' writes entirely — the engine's
    plan/run loop pushes freed rows' block-table clears lazily (at most one
    table transfer per step), so a freed slot's stale row may still map
    pages a COW sibling owns; masking here keeps those pages untouched."""
    n_pages, page_size = pages_k.shape[0], pages_k.shape[1]
    pos = lengths
    page_of = jnp.take_along_axis(block_table, (pos // page_size)[:, None],
                                  axis=1, mode="clip")[:, 0]    # (B,)
    off = pos % page_size
    # unmapped (-1) rows route to index n_pages, which mode="drop" discards —
    # crucial for freed slots whose pages may now belong to another request
    safe_page = jnp.where(page_of < 0, n_pages, page_of)
    if active is not None:
        safe_page = jnp.where(active, safe_page, n_pages)
    # cast-to-pool is a no-op at the default kv_dtype; kv_dtype="bfloat16"
    # stores a narrower non-quantized pool than the compute dtype
    pages_k = pages_k.at[safe_page, off].set(
        new_k[:, 0].astype(pages_k.dtype), mode="drop")
    pages_v = pages_v.at[safe_page, off].set(
        new_v[:, 0].astype(pages_v.dtype), mode="drop")
    return pages_k, pages_v


def write_prompt(pages_k: jax.Array, pages_v: jax.Array, block_row: jax.Array,
                 new_k: jax.Array, new_v: jax.Array, prompt_len: jax.Array,
                 offset=0) -> Tuple[jax.Array, jax.Array]:
    """Scatter a prefilled prompt (or prompt chunk) K/V into one sequence's
    pages.

    pages_*: (n_pages, page, kv, hd); block_row: (P,) this sequence's block-
    table row; new_*: (1, S, kv, hd) right-padded; prompt_len: () valid count
    in new_*; offset: () logical position of new_*[0, 0] — chunked prefill
    writes chunk i at offset i * chunk, spanning page boundaries freely.
    """
    n_pages, page_size = pages_k.shape[0], pages_k.shape[1]
    S = new_k.shape[1]
    pos = jnp.asarray(offset, jnp.int32) + jnp.arange(S)
    page_of = jnp.take(block_row, pos // page_size, mode="clip")
    valid = (jnp.arange(S) < prompt_len) & (page_of >= 0)
    safe_page = jnp.where(valid, page_of, n_pages)       # OOB rows dropped
    off = pos % page_size
    pages_k = pages_k.at[safe_page, off].set(
        new_k[0].astype(pages_k.dtype), mode="drop")
    pages_v = pages_v.at[safe_page, off].set(
        new_v[0].astype(pages_v.dtype), mode="drop")
    return pages_k, pages_v


def write_prompt_ragged(pages_k: jax.Array, pages_v: jax.Array,
                        block_rows: jax.Array, new_k: jax.Array,
                        new_v: jax.Array, lens: jax.Array, offsets: jax.Array
                        ) -> Tuple[jax.Array, jax.Array]:
    """Scatter R slots' prompt chunks into their pages in one shot.

    The batched-ingest analogue of `write_prompt`: row r holds slot r's next
    chunk, right-padded to C with `lens[r]` valid tokens, written at logical
    positions offsets[r]..offsets[r]+lens[r]-1 through that slot's block-table
    row. Distinct slots own distinct pages, so rows never collide and the
    scatter is order-independent — row r's writes are bitwise what a
    `write_prompt` call for that row alone would produce.

    pages_*: (n_pages, page, kv, hd); block_rows: (R, P); new_*: (R, C, kv,
    hd); lens/offsets: (R,). Padding rows (lens == 0) write nothing.
    """
    n_pages, page_size = pages_k.shape[0], pages_k.shape[1]
    R, C = new_k.shape[0], new_k.shape[1]
    pos = offsets[:, None] + jnp.arange(C)[None, :]            # (R, C)
    page_of = jnp.take_along_axis(block_rows, pos // page_size, axis=1,
                                  mode="clip")
    valid = (jnp.arange(C)[None, :] < lens[:, None]) & (page_of >= 0)
    safe_page = jnp.where(valid, page_of, n_pages)             # OOB dropped
    off = pos % page_size
    pages_k = pages_k.at[safe_page, off].set(
        new_k.astype(pages_k.dtype), mode="drop")
    pages_v = pages_v.at[safe_page, off].set(
        new_v.astype(pages_v.dtype), mode="drop")
    return pages_k, pages_v


def write_token_quant(pages_k: jax.Array, pages_v: jax.Array,
                      scales_k: jax.Array, scales_v: jax.Array,
                      block_table: jax.Array, lengths: jax.Array,
                      new_k: jax.Array, new_v: jax.Array, kv_dtype: str,
                      active: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """`write_token` for a quantized pool: requantize each slot's tail page.

    The tail page is always uniquely owned (COW copies partial tails
    eagerly), so rewriting the whole page never clobbers a sibling. Garbage
    positions past the new token are zeroed out of both the abs-max and the
    stored page."""
    n_pages, page_size = pages_k.shape[0], pages_k.shape[1]
    B = lengths.shape[0]
    pos = lengths
    page_of = jnp.take_along_axis(block_table, (pos // page_size)[:, None],
                                  axis=1, mode="clip")[:, 0]    # (B,)
    off = pos % page_size
    safe_page = jnp.where(page_of < 0, n_pages, page_of)
    if active is not None:
        safe_page = jnp.where(active, safe_page, n_pages)
    idx = jnp.minimum(safe_page, n_pages - 1)
    valid = jnp.arange(page_size)[None, :] <= off[:, None]       # (B, page)

    def one(pages, scales, new):
        deq = pages[idx].astype(jnp.float32)                     # (B, pg, kv, hd)
        deq = deq * scales[idx][:, None, :, None]
        deq = deq.at[jnp.arange(B), off].set(new[:, 0].astype(jnp.float32))
        deq = jnp.where(valid[:, :, None, None], deq, 0.0)
        amax = jnp.max(jnp.abs(deq), axis=(1, 3))                # (B, kv)
        scale = quant_scale(amax, kv_dtype)
        q = _quantize(deq, scale, kv_dtype)
        pages = pages.at[safe_page].set(q, mode="drop")
        scales = scales.at[safe_page].set(scale, mode="drop")
        return pages, scales

    pages_k, scales_k = one(pages_k, scales_k, new_k)
    pages_v, scales_v = one(pages_v, scales_v, new_v)
    return pages_k, pages_v, scales_k, scales_v


def _quant_chunk_scatter(pages, scales, page_ids, kpos, newg, inchunk, valid,
                         kv_dtype):
    """Shared tail of the quantized prompt writes: dequantize the touched
    pages, overlay the chunk tokens, requantize over valid positions, and
    scatter pages + scales (rows with nothing to write are dropped).

    pages: (n_pages, pg, kv, hd); scales: (n_pages, kv); page_ids: (T,);
    kpos: (T, pg) logical positions; newg: (T, pg, kv, hd) f32 chunk tokens
    aligned to kpos; inchunk/valid: (T, pg) masks."""
    n_pages = pages.shape[0]
    idx = jnp.maximum(page_ids, 0)
    deq = pages[idx].astype(jnp.float32) * scales[idx][:, None, :, None]
    deq = jnp.where(inchunk[:, :, None, None], newg, deq)
    deq = jnp.where(valid[:, :, None, None], deq, 0.0)
    amax = jnp.max(jnp.abs(deq), axis=(1, 3))                    # (T, kv)
    scale = quant_scale(amax, kv_dtype)
    q = _quantize(deq, scale, kv_dtype)
    writes = jnp.any(inchunk, axis=1) & (page_ids >= 0)
    safe = jnp.where(writes, page_ids, n_pages)
    pages = pages.at[safe].set(q, mode="drop")
    scales = scales.at[safe].set(scale, mode="drop")
    return pages, scales


def write_prompt_quant(pages_k: jax.Array, pages_v: jax.Array,
                       scales_k: jax.Array, scales_v: jax.Array,
                       block_row: jax.Array, new_k: jax.Array,
                       new_v: jax.Array, prompt_len: jax.Array, kv_dtype: str,
                       offset=0
                       ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """`write_prompt` for a quantized pool.

    Touched pages are rewritten whole: tokens earlier chunks already placed
    on the first touched page are dequantized, merged with the new chunk,
    and requantized under the page's fresh scale."""
    n_pages, page_size = pages_k.shape[0], pages_k.shape[1]
    S = new_k.shape[1]
    n_touch = S // page_size + 2          # static page-span upper bound
    first = jnp.asarray(offset, jnp.int32) // page_size
    logical = first + jnp.arange(n_touch)                        # (T,)
    page_ids = jnp.take(block_row, logical, mode="clip")
    kpos = logical[:, None] * page_size + jnp.arange(page_size)[None, :]
    chunk_idx = kpos - jnp.asarray(offset, jnp.int32)            # (T, pg)
    inchunk = (chunk_idx >= 0) & (chunk_idx < prompt_len)
    valid = (kpos < jnp.asarray(offset, jnp.int32) + prompt_len) \
        & (page_ids >= 0)[:, None]
    cc = jnp.clip(chunk_idx, 0, S - 1)

    def one(pages, scales, new):
        newg = new[0].astype(jnp.float32)[cc]                    # (T, pg, kv, hd)
        return _quant_chunk_scatter(pages, scales, page_ids, kpos, newg,
                                    inchunk, valid, kv_dtype)

    pages_k, scales_k = one(pages_k, scales_k, new_k)
    pages_v, scales_v = one(pages_v, scales_v, new_v)
    return pages_k, pages_v, scales_k, scales_v


def write_prompt_ragged_quant(pages_k: jax.Array, pages_v: jax.Array,
                              scales_k: jax.Array, scales_v: jax.Array,
                              block_rows: jax.Array, new_k: jax.Array,
                              new_v: jax.Array, lens: jax.Array,
                              offsets: jax.Array, kv_dtype: str
                              ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                         jax.Array]:
    """`write_prompt_ragged` for a quantized pool: R slots' chunks in one
    shot. Distinct slots own distinct pages, so the flattened (R * touched)
    page rewrite never collides across rows and stays order-independent."""
    n_pages, page_size = pages_k.shape[0], pages_k.shape[1]
    R, C = new_k.shape[0], new_k.shape[1]
    n_touch = C // page_size + 2
    first = offsets // page_size                                  # (R,)
    logical = first[:, None] + jnp.arange(n_touch)[None, :]       # (R, T)
    page_ids = jnp.take_along_axis(block_rows, jnp.minimum(
        logical, block_rows.shape[1] - 1), axis=1)
    page_ids = jnp.where(logical < block_rows.shape[1], page_ids, -1)
    kpos = logical[:, :, None] * page_size \
        + jnp.arange(page_size)[None, None, :]                    # (R, T, pg)
    chunk_idx = kpos - offsets[:, None, None]
    inchunk = (chunk_idx >= 0) & (chunk_idx < lens[:, None, None])
    valid = (kpos < (offsets + lens)[:, None, None]) \
        & (page_ids >= 0)[:, :, None]
    cc = jnp.clip(chunk_idx, 0, C - 1).reshape(R, n_touch * page_size)

    def one(pages, scales, new):
        newg = jnp.take_along_axis(new.astype(jnp.float32),
                                   cc[:, :, None, None], axis=1)
        newg = newg.reshape(R * n_touch, page_size, *new.shape[2:])
        return _quant_chunk_scatter(
            pages, scales, page_ids.reshape(-1),
            kpos.reshape(R * n_touch, page_size), newg,
            inchunk.reshape(R * n_touch, page_size),
            valid.reshape(R * n_touch, page_size), kv_dtype)

    pages_k, scales_k = one(pages_k, scales_k, new_k)
    pages_v, scales_v = one(pages_v, scales_v, new_v)
    return pages_k, pages_v, scales_k, scales_v


def copy_page(pages: jax.Array, src: int, dst: int) -> jax.Array:
    """Copy one physical page across all layers of a segment's pool.

    pages: (count, n_pages, page, kv, hd). src == dst is a no-op copy, used
    when a fork has no partial tail page to duplicate."""
    return pages.at[:, dst].set(pages[:, src])


@dataclasses.dataclass
class PageAllocator:
    """Host-side page bookkeeping: free list + per-slot page chains, with
    per-page refcounts so forks can share read-only prefix pages
    copy-on-write (`fork` / `cow_page`). A page returns to the free list
    only when its last reference is released."""
    n_pages: int
    page_size: int
    max_pages_per_seq: int

    def __post_init__(self):
        self.free: List[int] = list(range(self.n_pages))
        self.owned: dict = {}
        self.refcount: List[int] = [0] * self.n_pages
        # Host tier: req_id -> {"resident": [(logical_idx, page_id)],
        # "swapped_idx": [logical_idx]}. Demoted requests keep shared pages
        # resident (their reference is held, so siblings can't free them)
        # and surrender uniquely-owned pages to the free list once the
        # engine has snapshotted their bytes to host memory.
        self.hosted: Dict = {}

    def _take(self) -> int:
        p = self.free.pop()
        self.refcount[p] = 1
        return p

    def alloc_for(self, slot: int, n_tokens: int) -> List[int]:
        need = max(1, -(-n_tokens // self.page_size))
        assert need <= self.max_pages_per_seq, "sequence exceeds block table"
        if len(self.free) < need:
            raise MemoryError("page pool exhausted")
        pages = [self._take() for _ in range(need)]
        self.owned[slot] = pages
        return pages

    def extend(self, slot: int, new_len: int) -> Optional[int]:
        """Grow slot to cover new_len tokens; returns new page id if mapped."""
        pages = self.owned.get(slot, [])
        need = max(1, -(-new_len // self.page_size))
        if need <= len(pages):
            return None
        if not self.free:
            raise MemoryError("page pool exhausted")
        p = self._take()
        pages.append(p)
        self.owned[slot] = pages
        return p

    def fork(self, src_slot: int, dst_slot: int, n_tokens: int
             ) -> Tuple[List[int], int, int]:
        """Share src's first `n_tokens` of pages with dst copy-on-write.

        Full pages are shared (refcount++); a partial tail page — the page
        the next token write would land in — is copied into a fresh page so
        the fork can append without touching its siblings. Returns
        (dst_pages, tail_src, tail_dst); tail ids are equal when the prefix
        is page-aligned and nothing needs a device-side copy."""
        src_pages = self.owned[src_slot]
        assert dst_slot not in self.owned, "destination slot still owns pages"
        assert 0 < n_tokens <= len(src_pages) * self.page_size
        full = n_tokens // self.page_size
        shared = src_pages[:full]
        tail_src = tail_dst = 0
        if n_tokens % self.page_size:
            if not self.free:
                raise MemoryError("page pool exhausted")
            tail_src = src_pages[full]
            tail_dst = self._take()
        for p in shared:
            self.refcount[p] += 1
        dst_pages = list(shared)
        if tail_src != tail_dst:
            dst_pages.append(tail_dst)
        self.owned[dst_slot] = dst_pages
        return dst_pages, tail_src, tail_dst

    def fork_cost(self, n_tokens: int) -> int:
        """Free pages a fork of an n_tokens prefix consumes now (0 or 1)."""
        return 1 if n_tokens % self.page_size else 0

    def cow_page(self, slot: int, pos: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write guard before writing token position `pos`: if the
        page holding it is shared, re-point the slot at a private copy.
        Returns (old_page, new_page) for the device-side copy, or None when
        the page is already uniquely owned (the common case — forks copy
        their partial tail eagerly, so this triggers only on exotic chains).
        """
        pages = self.owned.get(slot, [])
        idx = pos // self.page_size
        if idx >= len(pages):
            return None
        p = pages[idx]
        if self.refcount[p] <= 1:
            return None
        if not self.free:
            raise MemoryError("page pool exhausted")
        new = self._take()
        self.refcount[p] -= 1
        pages[idx] = new
        return p, new

    def demote(self, slot: int, req_id) -> List[Tuple[int, int]]:
        """Move a slot's chain to the host tier instead of freeing it.

        Uniquely-owned pages are freed for reuse and listed as swapped —
        the caller must snapshot their bytes from the *current* (immutable)
        cache value before dispatching anything that could rewrite them.
        Shared pages stay resident with this chain's reference held, so COW
        siblings cannot free them and `promote` re-shares them in place.
        Returns [(logical_idx, page_id)] for the swapped pages."""
        pages = self.owned.pop(slot)
        resident: List[Tuple[int, int]] = []
        swapped: List[Tuple[int, int]] = []
        for i, p in enumerate(pages):
            if self.refcount[p] == 1:
                swapped.append((i, p))
                self.refcount[p] = 0
                self.free.append(p)
            else:
                resident.append((i, p))
        self.hosted[req_id] = {"resident": resident,
                               "swapped_idx": [i for i, _ in swapped]}
        return swapped

    def promote(self, req_id, slot: int) -> List[Tuple[int, int]]:
        """Re-admit a demoted request into `slot`: fresh device pages for
        the swapped logical indices (MemoryError when the pool is dry),
        resident shared pages rejoin the chain with their held reference.
        Returns [(logical_idx, new_page_id)] upload targets for the host
        bytes, in logical order."""
        ent = self.hosted[req_id]
        assert slot not in self.owned, "destination slot still owns pages"
        if len(self.free) < len(ent["swapped_idx"]):
            raise MemoryError("page pool exhausted")
        uploads = [(i, self._take()) for i in ent["swapped_idx"]]
        chain = dict(uploads)
        chain.update(ent["resident"])
        self.owned[slot] = [chain[i] for i in sorted(chain)]
        del self.hosted[req_id]
        return uploads

    def drop_hosted(self, req_id) -> None:
        """Abandon a demoted request, releasing its held resident refs."""
        ent = self.hosted.pop(req_id, None)
        if ent is None:
            return
        for _, p in ent["resident"]:
            self.refcount[p] -= 1
            assert self.refcount[p] >= 0, "refcount underflow"
            if self.refcount[p] == 0:
                self.free.append(p)

    def hosted_pages(self, req_id) -> int:
        """Swapped page count a promote of req_id must allocate."""
        return len(self.hosted[req_id]["swapped_idx"])

    def release(self, slot: int) -> None:
        for p in self.owned.pop(slot, []):
            self.refcount[p] -= 1
            assert self.refcount[p] >= 0, "refcount underflow"
            if self.refcount[p] == 0:
                self.free.append(p)

    def unique_pages(self, slot: int) -> int:
        """Pages only this slot references — what releasing it would free."""
        return sum(1 for p in self.owned.get(slot, [])
                   if self.refcount[p] == 1)

    @property
    def pages_in_use(self) -> int:
        return self.n_pages - len(self.free)

    @property
    def pages_shared(self) -> int:
        """Physical pages referenced by more than one slot."""
        return sum(1 for c in self.refcount if c > 1)

    @property
    def logical_pages(self) -> int:
        """Sum of per-slot chain lengths (counts shared pages per reference);
        logical - in_use is the memory COW sharing is saving."""
        return sum(len(v) for v in self.owned.values())

    @property
    def utilization(self) -> float:
        return 1.0 - len(self.free) / self.n_pages
