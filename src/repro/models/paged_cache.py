"""Paged KV cache (vLLM PagedAttention analogue, pure JAX).

Physical storage is a page pool per layer; sequences map to pages through a
block table, so slot memory is allocated on demand and freed on completion —
no per-slot max_len reservation. The TPU-native read path gathers a
sequence's pages into the contiguous layout and reuses the standard decode
attention (on real TPUs the decode_attention Pallas kernel streams pages
HBM->VMEM directly; the gather formulation is its jnp oracle).

Layout:
  pages:       (L, n_pages, page_size, n_kv, hd)
  block_table: (B, max_pages_per_seq) int32  (-1 = unmapped)
  lengths:     (B,)
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp


def init_paged_kv(n_layers: int, n_pages: int, page_size: int, n_kv: int,
                  head_dim: int, batch: int, max_pages_per_seq: int,
                  dtype=jnp.bfloat16) -> dict:
    return {
        "k_pages": jnp.zeros((n_layers, n_pages, page_size, n_kv, head_dim),
                             dtype),
        "v_pages": jnp.zeros((n_layers, n_pages, page_size, n_kv, head_dim),
                             dtype),
        "block_table": jnp.full((batch, max_pages_per_seq), -1, jnp.int32),
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def gather_sequence(pages: jax.Array, block_table: jax.Array) -> jax.Array:
    """pages: (n_pages, page, n_kv, hd); block_table: (B, P) ->
    contiguous (B, P*page, n_kv, hd). Unmapped (-1) pages read page 0 and
    must be masked by `lengths` downstream."""
    idx = jnp.maximum(block_table, 0)
    g = pages[idx]                                   # (B, P, page, kv, hd)
    B, P, page, kv, hd = g.shape
    return g.reshape(B, P * page, kv, hd)


def write_token(pages_k: jax.Array, pages_v: jax.Array, block_table: jax.Array,
                lengths: jax.Array, new_k: jax.Array, new_v: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """Write one token per slot at its current length.

    pages_*: (n_pages, page, kv, hd); new_*: (B, 1, kv, hd)."""
    n_pages, page_size = pages_k.shape[0], pages_k.shape[1]
    pos = lengths
    page_of = jnp.take_along_axis(block_table, (pos // page_size)[:, None],
                                  axis=1, mode="clip")[:, 0]    # (B,)
    off = pos % page_size
    # unmapped (-1) rows route to index n_pages, which mode="drop" discards —
    # crucial for freed slots whose pages may now belong to another request
    safe_page = jnp.where(page_of < 0, n_pages, page_of)
    pages_k = pages_k.at[safe_page, off].set(new_k[:, 0], mode="drop")
    pages_v = pages_v.at[safe_page, off].set(new_v[:, 0], mode="drop")
    return pages_k, pages_v


def write_prompt(pages_k: jax.Array, pages_v: jax.Array, block_row: jax.Array,
                 new_k: jax.Array, new_v: jax.Array, prompt_len: jax.Array
                 ) -> Tuple[jax.Array, jax.Array]:
    """Scatter a prefilled prompt's K/V into one sequence's pages.

    pages_*: (n_pages, page, kv, hd); block_row: (P,) this sequence's block-
    table row; new_*: (1, S, kv, hd) right-padded; prompt_len: () valid count.
    """
    n_pages, page_size = pages_k.shape[0], pages_k.shape[1]
    S = new_k.shape[1]
    pos = jnp.arange(S)
    page_of = jnp.take(block_row, pos // page_size, mode="clip")
    valid = (pos < prompt_len) & (page_of >= 0)
    safe_page = jnp.where(valid, page_of, n_pages)       # OOB rows dropped
    off = pos % page_size
    pages_k = pages_k.at[safe_page, off].set(new_k[0], mode="drop")
    pages_v = pages_v.at[safe_page, off].set(new_v[0], mode="drop")
    return pages_k, pages_v


@dataclasses.dataclass
class PageAllocator:
    """Host-side page bookkeeping (free list + per-slot page chains)."""
    n_pages: int
    page_size: int
    max_pages_per_seq: int

    def __post_init__(self):
        self.free: List[int] = list(range(self.n_pages))
        self.owned: dict = {}

    def alloc_for(self, slot: int, n_tokens: int) -> List[int]:
        need = max(1, -(-n_tokens // self.page_size))
        assert need <= self.max_pages_per_seq, "sequence exceeds block table"
        if len(self.free) < need:
            raise MemoryError("page pool exhausted")
        pages = [self.free.pop() for _ in range(need)]
        self.owned[slot] = pages
        return pages

    def extend(self, slot: int, new_len: int) -> Optional[int]:
        """Grow slot to cover new_len tokens; returns new page id if mapped."""
        pages = self.owned.get(slot, [])
        need = max(1, -(-new_len // self.page_size))
        if need <= len(pages):
            return None
        if not self.free:
            raise MemoryError("page pool exhausted")
        p = self.free.pop()
        pages.append(p)
        self.owned[slot] = pages
        return p

    def release(self, slot: int) -> None:
        self.free.extend(self.owned.pop(slot, []))

    @property
    def pages_in_use(self) -> int:
        return self.n_pages - len(self.free)

    @property
    def utilization(self) -> float:
        return 1.0 - len(self.free) / self.n_pages
