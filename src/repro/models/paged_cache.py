"""Paged KV cache (vLLM PagedAttention analogue, pure JAX).

Physical storage is a page pool per layer; sequences map to pages through a
block table, so slot memory is allocated on demand and freed on completion —
no per-slot max_len reservation. The decode read path is keyed on
cfg.use_pallas: kernels/paged_decode_attention streams mapped pages
HBM->VMEM directly through the block table (no contiguous copy); the
`gather_sequence` formulation below is its jnp oracle and the non-TPU
fallback. Callers should trim the table they read through to the live
width (ceil(max(lengths)/page_size) columns) so even the gather stops
paying for `max_pages_per_seq`.

Layout:
  pages:       (L, n_pages, page_size, n_kv, hd)
  block_table: (B, max_pages_per_seq) int32  (-1 = unmapped)
  lengths:     (B,)
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp


def init_paged_kv(n_layers: int, n_pages: int, page_size: int, n_kv: int,
                  head_dim: int, batch: int, max_pages_per_seq: int,
                  dtype=jnp.bfloat16) -> dict:
    return {
        "k_pages": jnp.zeros((n_layers, n_pages, page_size, n_kv, head_dim),
                             dtype),
        "v_pages": jnp.zeros((n_layers, n_pages, page_size, n_kv, head_dim),
                             dtype),
        "block_table": jnp.full((batch, max_pages_per_seq), -1, jnp.int32),
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def gather_sequence(pages: jax.Array, block_table: jax.Array) -> jax.Array:
    """pages: (n_pages, page, n_kv, hd); block_table: (B, P) ->
    contiguous (B, P*page, n_kv, hd). Unmapped (-1) pages read page 0 and
    must be masked by `lengths` downstream."""
    idx = jnp.maximum(block_table, 0)
    g = pages[idx]                                   # (B, P, page, kv, hd)
    B, P, page, kv, hd = g.shape
    return g.reshape(B, P * page, kv, hd)


def write_token(pages_k: jax.Array, pages_v: jax.Array, block_table: jax.Array,
                lengths: jax.Array, new_k: jax.Array, new_v: jax.Array,
                active: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Write one token per slot at its current length.

    pages_*: (n_pages, page, kv, hd); new_*: (B, 1, kv, hd). `active` (B,)
    bool, when given, drops inactive rows' writes entirely — the engine's
    plan/run loop pushes freed rows' block-table clears lazily (at most one
    table transfer per step), so a freed slot's stale row may still map
    pages a COW sibling owns; masking here keeps those pages untouched."""
    n_pages, page_size = pages_k.shape[0], pages_k.shape[1]
    pos = lengths
    page_of = jnp.take_along_axis(block_table, (pos // page_size)[:, None],
                                  axis=1, mode="clip")[:, 0]    # (B,)
    off = pos % page_size
    # unmapped (-1) rows route to index n_pages, which mode="drop" discards —
    # crucial for freed slots whose pages may now belong to another request
    safe_page = jnp.where(page_of < 0, n_pages, page_of)
    if active is not None:
        safe_page = jnp.where(active, safe_page, n_pages)
    pages_k = pages_k.at[safe_page, off].set(new_k[:, 0], mode="drop")
    pages_v = pages_v.at[safe_page, off].set(new_v[:, 0], mode="drop")
    return pages_k, pages_v


def write_prompt(pages_k: jax.Array, pages_v: jax.Array, block_row: jax.Array,
                 new_k: jax.Array, new_v: jax.Array, prompt_len: jax.Array,
                 offset=0) -> Tuple[jax.Array, jax.Array]:
    """Scatter a prefilled prompt (or prompt chunk) K/V into one sequence's
    pages.

    pages_*: (n_pages, page, kv, hd); block_row: (P,) this sequence's block-
    table row; new_*: (1, S, kv, hd) right-padded; prompt_len: () valid count
    in new_*; offset: () logical position of new_*[0, 0] — chunked prefill
    writes chunk i at offset i * chunk, spanning page boundaries freely.
    """
    n_pages, page_size = pages_k.shape[0], pages_k.shape[1]
    S = new_k.shape[1]
    pos = jnp.asarray(offset, jnp.int32) + jnp.arange(S)
    page_of = jnp.take(block_row, pos // page_size, mode="clip")
    valid = (jnp.arange(S) < prompt_len) & (page_of >= 0)
    safe_page = jnp.where(valid, page_of, n_pages)       # OOB rows dropped
    off = pos % page_size
    pages_k = pages_k.at[safe_page, off].set(new_k[0], mode="drop")
    pages_v = pages_v.at[safe_page, off].set(new_v[0], mode="drop")
    return pages_k, pages_v


def write_prompt_ragged(pages_k: jax.Array, pages_v: jax.Array,
                        block_rows: jax.Array, new_k: jax.Array,
                        new_v: jax.Array, lens: jax.Array, offsets: jax.Array
                        ) -> Tuple[jax.Array, jax.Array]:
    """Scatter R slots' prompt chunks into their pages in one shot.

    The batched-ingest analogue of `write_prompt`: row r holds slot r's next
    chunk, right-padded to C with `lens[r]` valid tokens, written at logical
    positions offsets[r]..offsets[r]+lens[r]-1 through that slot's block-table
    row. Distinct slots own distinct pages, so rows never collide and the
    scatter is order-independent — row r's writes are bitwise what a
    `write_prompt` call for that row alone would produce.

    pages_*: (n_pages, page, kv, hd); block_rows: (R, P); new_*: (R, C, kv,
    hd); lens/offsets: (R,). Padding rows (lens == 0) write nothing.
    """
    n_pages, page_size = pages_k.shape[0], pages_k.shape[1]
    R, C = new_k.shape[0], new_k.shape[1]
    pos = offsets[:, None] + jnp.arange(C)[None, :]            # (R, C)
    page_of = jnp.take_along_axis(block_rows, pos // page_size, axis=1,
                                  mode="clip")
    valid = (jnp.arange(C)[None, :] < lens[:, None]) & (page_of >= 0)
    safe_page = jnp.where(valid, page_of, n_pages)             # OOB dropped
    off = pos % page_size
    pages_k = pages_k.at[safe_page, off].set(new_k, mode="drop")
    pages_v = pages_v.at[safe_page, off].set(new_v, mode="drop")
    return pages_k, pages_v


def copy_page(pages: jax.Array, src: int, dst: int) -> jax.Array:
    """Copy one physical page across all layers of a segment's pool.

    pages: (count, n_pages, page, kv, hd). src == dst is a no-op copy, used
    when a fork has no partial tail page to duplicate."""
    return pages.at[:, dst].set(pages[:, src])


@dataclasses.dataclass
class PageAllocator:
    """Host-side page bookkeeping: free list + per-slot page chains, with
    per-page refcounts so forks can share read-only prefix pages
    copy-on-write (`fork` / `cow_page`). A page returns to the free list
    only when its last reference is released."""
    n_pages: int
    page_size: int
    max_pages_per_seq: int

    def __post_init__(self):
        self.free: List[int] = list(range(self.n_pages))
        self.owned: dict = {}
        self.refcount: List[int] = [0] * self.n_pages

    def _take(self) -> int:
        p = self.free.pop()
        self.refcount[p] = 1
        return p

    def alloc_for(self, slot: int, n_tokens: int) -> List[int]:
        need = max(1, -(-n_tokens // self.page_size))
        assert need <= self.max_pages_per_seq, "sequence exceeds block table"
        if len(self.free) < need:
            raise MemoryError("page pool exhausted")
        pages = [self._take() for _ in range(need)]
        self.owned[slot] = pages
        return pages

    def extend(self, slot: int, new_len: int) -> Optional[int]:
        """Grow slot to cover new_len tokens; returns new page id if mapped."""
        pages = self.owned.get(slot, [])
        need = max(1, -(-new_len // self.page_size))
        if need <= len(pages):
            return None
        if not self.free:
            raise MemoryError("page pool exhausted")
        p = self._take()
        pages.append(p)
        self.owned[slot] = pages
        return p

    def fork(self, src_slot: int, dst_slot: int, n_tokens: int
             ) -> Tuple[List[int], int, int]:
        """Share src's first `n_tokens` of pages with dst copy-on-write.

        Full pages are shared (refcount++); a partial tail page — the page
        the next token write would land in — is copied into a fresh page so
        the fork can append without touching its siblings. Returns
        (dst_pages, tail_src, tail_dst); tail ids are equal when the prefix
        is page-aligned and nothing needs a device-side copy."""
        src_pages = self.owned[src_slot]
        assert dst_slot not in self.owned, "destination slot still owns pages"
        assert 0 < n_tokens <= len(src_pages) * self.page_size
        full = n_tokens // self.page_size
        shared = src_pages[:full]
        tail_src = tail_dst = 0
        if n_tokens % self.page_size:
            if not self.free:
                raise MemoryError("page pool exhausted")
            tail_src = src_pages[full]
            tail_dst = self._take()
        for p in shared:
            self.refcount[p] += 1
        dst_pages = list(shared)
        if tail_src != tail_dst:
            dst_pages.append(tail_dst)
        self.owned[dst_slot] = dst_pages
        return dst_pages, tail_src, tail_dst

    def fork_cost(self, n_tokens: int) -> int:
        """Free pages a fork of an n_tokens prefix consumes now (0 or 1)."""
        return 1 if n_tokens % self.page_size else 0

    def cow_page(self, slot: int, pos: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write guard before writing token position `pos`: if the
        page holding it is shared, re-point the slot at a private copy.
        Returns (old_page, new_page) for the device-side copy, or None when
        the page is already uniquely owned (the common case — forks copy
        their partial tail eagerly, so this triggers only on exotic chains).
        """
        pages = self.owned.get(slot, [])
        idx = pos // self.page_size
        if idx >= len(pages):
            return None
        p = pages[idx]
        if self.refcount[p] <= 1:
            return None
        if not self.free:
            raise MemoryError("page pool exhausted")
        new = self._take()
        self.refcount[p] -= 1
        pages[idx] = new
        return p, new

    def release(self, slot: int) -> None:
        for p in self.owned.pop(slot, []):
            self.refcount[p] -= 1
            assert self.refcount[p] >= 0, "refcount underflow"
            if self.refcount[p] == 0:
                self.free.append(p)

    def unique_pages(self, slot: int) -> int:
        """Pages only this slot references — what releasing it would free."""
        return sum(1 for p in self.owned.get(slot, [])
                   if self.refcount[p] == 1)

    @property
    def pages_in_use(self) -> int:
        return self.n_pages - len(self.free)

    @property
    def pages_shared(self) -> int:
        """Physical pages referenced by more than one slot."""
        return sum(1 for c in self.refcount if c > 1)

    @property
    def logical_pages(self) -> int:
        """Sum of per-slot chain lengths (counts shared pages per reference);
        logical - in_use is the memory COW sharing is saving."""
        return sum(len(v) for v in self.owned.values())

    @property
    def utilization(self) -> float:
        return 1.0 - len(self.free) / self.n_pages
