"""Model stacks: decoder-only / MoE / SSM / xLSTM / hybrid / enc-dec / VLM.

Layers are grouped into homogeneous *segments* (contiguous runs of the same
block kind). Each segment's params are stacked on a leading layer axis and
executed with lax.scan (cfg.scan_layers=False unrolls — used by the dry-run
so XLA cost analysis sees every layer's FLOPs).

Public entry points:
  init_params(cfg, key)
  forward(cfg, params, tokens, ...)         -> logits (train / scoring)
  prefill(cfg, params, tokens, cache, ...)  -> (logits, cache)
  decode_step(cfg, params, tokens, cache)   -> (logits, cache)
  init_cache(cfg, batch, max_len) / cache_specs(...)
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.config import (ATTN,
                                 MAMBA2,
                                 MLSTM,
                                 MOE,
                                 SHARED_ATTN,
                                 SLSTM,
                                 ModelConfig)
from repro.models.layers import (dense_init, embed, embed_init, init_embedding,
                                 init_mlp, init_norm, mlp, norm, unembed)


# ---------------------------------------------------------------------------
# Segments
# ---------------------------------------------------------------------------

def segments_of(cfg: ModelConfig) -> List[Tuple[str, int]]:
    pat = cfg.block_pattern()
    segs: List[Tuple[str, int]] = []
    for kind in pat:
        if segs and segs[-1][0] == kind:
            segs[-1] = (kind, segs[-1][1] + 1)
        else:
            segs.append((kind, 1))
    return segs


# ---------------------------------------------------------------------------
# Per-block init
# ---------------------------------------------------------------------------

def _init_block(cfg: ModelConfig, key, kind: str, cross: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if kind == ATTN or kind == SHARED_ATTN:
        p = {
            "norm1": init_norm(cfg, d),
            "attn": attn_lib.init_attention(cfg, ks[0]),
            "norm2": init_norm(cfg, d),
            "mlp": init_mlp(cfg, ks[1], d, cfg.d_ff, gated=not cfg.use_layernorm),
        }
        if cross:
            p["norm_x"] = init_norm(cfg, d)
            p["xattn"] = attn_lib.init_attention(
                cfg, ks[2], cross=True, kv_d_model=cfg.encoder.d_model)
        return p
    if kind == MOE:
        return {
            "norm1": init_norm(cfg, d),
            "attn": attn_lib.init_attention(cfg, ks[0]),
            "norm2": init_norm(cfg, d),
            "moe": moe_lib.init_moe(cfg, ks[1]),
        }
    if kind == MAMBA2:
        return {"norm1": init_norm(cfg, d), "mamba": ssm_lib.init_mamba2(cfg, ks[0])}
    if kind == MLSTM:
        return {"norm1": init_norm(cfg, d), "mlstm": xlstm_lib.init_mlstm(cfg, ks[0])}
    if kind == SLSTM:
        return {"norm1": init_norm(cfg, d), "slstm": xlstm_lib.init_slstm(cfg, ks[0])}
    raise ValueError(kind)


def _stack_init(cfg: ModelConfig, key, kind: str, count: int, cross: bool) -> dict:
    keys = jax.random.split(key, count)
    return jax.vmap(lambda k: _init_block(cfg, k, kind, cross))(keys)


# ---------------------------------------------------------------------------
# Whisper-style encoder (over stubbed frame/patch embeddings)
# ---------------------------------------------------------------------------

def _enc_cfg_as_model(cfg: ModelConfig) -> ModelConfig:
    e = cfg.encoder
    return cfg.with_(d_model=e.d_model, n_heads=e.n_heads, n_kv_heads=e.n_kv_heads,
                     d_ff=e.d_ff, n_layers=e.n_layers, use_rope=False,
                     sliding_window=0, qk_norm=False, qkv_bias=cfg.qkv_bias)


def _init_encoder(cfg: ModelConfig, key) -> dict:
    ecfg = _enc_cfg_as_model(cfg)
    e = cfg.encoder
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "pos": embed_init(k1, (e.n_ctx, e.d_model), jnp.dtype(cfg.param_dtype)),
        "blocks": _stack_init(ecfg, k2, ATTN, e.n_layers, cross=False),
        "final_norm": init_norm(cfg, e.d_model),
    }


def encode(cfg: ModelConfig, params: dict, frames: jax.Array,
           mesh=None) -> jax.Array:
    """frames: (B, n_ctx, d_enc) stub embeddings -> encoder output."""
    ecfg = _enc_cfg_as_model(cfg)
    x = frames + params["pos"].astype(frames.dtype)[None, : frames.shape[1]]
    positions = jnp.arange(frames.shape[1])[None]

    def body(x, blk):
        h = attn_lib.attention_fwd(ecfg, blk["attn"],
                                   norm(ecfg, blk["norm1"], x), positions,
                                   causal=False)
        x = x + h
        x = x + mlp(ecfg, blk["mlp"], norm(ecfg, blk["norm2"], x))
        return x, None

    x, _ = _run_segment(ecfg, params["blocks"], x, body, mesh)
    return norm(ecfg, params["final_norm"], x)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> dict:
    cfg.validate()
    segs = segments_of(cfg)
    keys = jax.random.split(key, len(segs) + 5)
    cross = cfg.family == "encdec"
    p: Dict[str, Any] = {"embed": init_embedding(cfg, keys[0])}
    seg_params = []
    shared_done = False
    for i, (kind, count) in enumerate(segs):
        if kind == SHARED_ATTN:
            if not shared_done:
                p["shared"] = _init_block(cfg, keys[i + 1], SHARED_ATTN)
                shared_done = True
            seg_params.append({})  # weights live in p["shared"]
        else:
            seg_params.append(_stack_init(cfg, keys[i + 1], kind, count, cross))
    p["segments"] = seg_params
    p["final_norm"] = init_norm(cfg, cfg.d_model)
    if cfg.family == "encdec":
        p["encoder"] = _init_encoder(cfg, keys[-1])
        p["dec_pos"] = embed_init(keys[-2], (cfg.max_seq_len, cfg.d_model),
                                  jnp.dtype(cfg.param_dtype))
    if cfg.length_buckets:
        p["length_head"] = dense_init(keys[-3], (cfg.d_model, cfg.length_buckets))
    return p


# ---------------------------------------------------------------------------
# Segment runner (scan or unroll, with optional remat + sharding constraint)
# ---------------------------------------------------------------------------

def _act_axes(cfg: ModelConfig, mesh):
    if mesh is None:
        return None
    b = shd.batch_axes(mesh)
    if cfg.act_shard == "batch_seq":
        return (b, "model", None)
    if cfg.act_shard == "batch_model":
        return (b, None, "model")
    return (b, None, None)


def _constrain(cfg: ModelConfig, mesh, x):
    axes = _act_axes(cfg, mesh)
    if axes is None or mesh is None:
        return x
    return shd.constraint(x, mesh, axes)


def _scan_or_unroll(cfg: ModelConfig, fn, init, xs):
    """lax.scan over stacked layers, or a python unroll (cfg.scan_layers=False,
    used by the dry-run so XLA cost analysis sees every layer)."""
    if cfg.scan_layers:
        return jax.lax.scan(fn, init, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    carry, ys = init, []
    for i in range(n):
        sl = jax.tree.map(lambda a: a[i], xs)
        carry, y = fn(carry, sl)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def _run_segment(cfg: ModelConfig, stacked: dict, x: jax.Array, body, mesh):
    """Run body over stacked layer params. body: (x, blk) -> (x, aux|None)."""
    def fn(x, blk):
        x = _constrain(cfg, mesh, x)
        return body(x, blk)

    if cfg.remat:
        fn = jax.checkpoint(fn)
    return _scan_or_unroll(cfg, fn, x, stacked)


# ---------------------------------------------------------------------------
# Block bodies (full-sequence)
# ---------------------------------------------------------------------------

def _block_fwd_full(cfg: ModelConfig, kind: str, blk: dict, x, positions,
                    enc_out=None, mesh=None):
    """Returns (x, aux) for one block over a full sequence."""
    aux = jnp.zeros((), jnp.float32)
    if kind in (ATTN, SHARED_ATTN, MOE):
        h = attn_lib.attention_fwd(cfg, blk["attn"], norm(cfg, blk["norm1"], x),
                                   positions, causal=True)
        x = x + h
        if enc_out is not None and "xattn" in blk:
            x = x + attn_lib.cross_attention_fwd(
                cfg, blk["xattn"], norm(cfg, blk["norm_x"], x), enc_out)
        if kind == MOE:
            h, aux = moe_lib.moe_fwd(cfg, blk["moe"], norm(cfg, blk["norm2"], x),
                                     mesh=mesh)
        else:
            h = mlp(cfg, blk["mlp"], norm(cfg, blk["norm2"], x))
        return x + h, aux
    if kind == MAMBA2:
        return x + ssm_lib.mamba2_fwd(cfg, blk["mamba"],
                                      norm(cfg, blk["norm1"], x)), aux
    if kind == MLSTM:
        return x + xlstm_lib.mlstm_fwd(cfg, blk["mlstm"],
                                       norm(cfg, blk["norm1"], x)), aux
    if kind == SLSTM:
        return x + xlstm_lib.slstm_fwd(cfg, blk["slstm"],
                                       norm(cfg, blk["norm1"], x)), aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Forward (train / full-sequence scoring)
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: dict, tokens: jax.Array,
            prefix_embeds: Optional[jax.Array] = None,
            enc_frames: Optional[jax.Array] = None,
            mesh=None, return_hidden: bool = False):
    """tokens: (B, S) int32.

    prefix_embeds: VLM stub patch embeddings (B, n_prefix, D) prepended.
    enc_frames: whisper stub frame embeddings (B, n_ctx, d_enc).
    Returns (logits, aux_loss[, hidden]).
    """
    x = embed(cfg, params["embed"], tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None]
    enc_out = None
    if cfg.family == "encdec":
        enc_out = encode(cfg, params["encoder"], enc_frames, mesh)
        x = x + params["dec_pos"].astype(x.dtype)[None, :S]
    x = _constrain(cfg, mesh, x)

    aux_total = jnp.zeros((), jnp.float32)
    for (kind, count), seg in zip(segments_of(cfg), params["segments"]):
        if kind == SHARED_ATTN:
            x, aux = _block_fwd_full(cfg, kind, params["shared"], x, positions,
                                     enc_out, mesh)
            aux_total = aux_total + aux
            continue

        def body(x, blk, kind=kind):
            return _block_fwd_full(cfg, kind, blk, x, positions, enc_out, mesh)

        x, auxs = _run_segment(cfg, seg, x, body, mesh)
        if auxs is not None:
            aux_total = aux_total + jnp.sum(auxs)

    x = norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params["embed"], x)
    if mesh is not None:
        logits = shd.constraint(logits, mesh,
                                (shd.batch_axes(mesh), None, "model"))
    if return_hidden:
        return logits, aux_total, x
    return logits, aux_total


def predict_length(cfg: ModelConfig, params: dict, hidden: jax.Array) -> jax.Array:
    """PICE response-length head: mean-pooled hidden -> bucket logits."""
    pooled = jnp.mean(hidden.astype(jnp.float32), axis=1)
    return pooled @ params["length_head"].astype(jnp.float32)


# ---------------------------------------------------------------------------
# Cache init / specs
# ---------------------------------------------------------------------------

def _seg_cache(cfg: ModelConfig, kind: str, count: int, batch: int,
               max_len: int, spec: bool):
    hd = cfg.resolved_head_dim
    adt = jnp.dtype(cfg.dtype)
    mk = (lambda sh, dt: jax.ShapeDtypeStruct(sh, dt)) if spec else (
        lambda sh, dt: jnp.zeros(sh, dt))
    if kind in (ATTN, MOE, SHARED_ATTN):
        w = cfg.sliding_window
        size = w if w else max_len
        c = {"k": mk((count, batch, size, cfg.n_kv_heads, hd), adt),
             "v": mk((count, batch, size, cfg.n_kv_heads, hd), adt)}
        if cfg.family == "encdec":
            c["cross_k"] = mk((count, batch, cfg.encoder.n_ctx, cfg.n_kv_heads, hd), adt)
            c["cross_v"] = mk((count, batch, cfg.encoder.n_ctx, cfg.n_kv_heads, hd), adt)
        return c
    if kind == MAMBA2:
        inner, H, P, N = ssm_lib.ssm_dims(cfg)
        return {"conv": mk((count, batch, cfg.ssm_conv - 1, inner), adt),
                "ssd": mk((count, batch, H, P, N), jnp.float32)}
    if kind == MLSTM:
        inner, H, hdm = xlstm_lib.mlstm_dims(cfg)
        return {"C": mk((count, batch, H, hdm, hdm), jnp.float32),
                "n": mk((count, batch, H, hdm), jnp.float32),
                "m": mk((count, batch, H), jnp.float32)}
    if kind == SLSTM:
        d = cfg.d_model
        return {k: mk((count, batch, d), jnp.float32) for k in ("h", "c", "n", "m")}
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, spec: bool = False) -> dict:
    mk = (lambda sh, dt: jax.ShapeDtypeStruct(sh, dt)) if spec else (
        lambda sh, dt: jnp.zeros(sh, dt))
    return {
        "lengths": mk((batch,), jnp.int32),
        "segments": [
            _seg_cache(cfg, kind, count, batch, max_len, spec)
            for kind, count in segments_of(cfg)
        ],
    }


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array, cache: dict,
            prefix_embeds: Optional[jax.Array] = None,
            enc_frames: Optional[jax.Array] = None,
            prompt_lengths: Optional[jax.Array] = None,
            mesh=None) -> Tuple[jax.Array, dict]:
    """Process the prompt, fill the cache, return last-position logits.

    tokens: (B, S) right-padded to S; prompt_lengths: (B,) actual lengths
    (defaults to S).
    """
    x = embed(cfg, params["embed"], tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    if prompt_lengths is None:
        prompt_lengths = jnp.full((B,), S, jnp.int32)
    elif prefix_embeds is not None:
        prompt_lengths = prompt_lengths + prefix_embeds.shape[1]
    positions = jnp.arange(S)[None]
    enc_out = None
    if cfg.family == "encdec":
        enc_out = encode(cfg, params["encoder"], enc_frames, mesh)
        x = x + params["dec_pos"].astype(x.dtype)[None, :S]
    x = _constrain(cfg, mesh, x)

    new_segs = []
    for (kind, count), seg, segc in zip(segments_of(cfg), params["segments"],
                                        cache["segments"]):
        if kind == SHARED_ATTN:
            x, newc = _prefill_block(cfg, kind, params["shared"],
                                     jax.tree.map(lambda a: a[0], segc), x,
                                     positions, prompt_lengths, enc_out, mesh)
            newc = jax.tree.map(lambda a: a[None], newc)
        else:
            def scan_body(x, inp, kind=kind):
                blk, c = inp
                x = _constrain(cfg, mesh, x)
                x, newc = _prefill_block(cfg, kind, blk, c, x, positions,
                                         prompt_lengths, enc_out, mesh)
                return x, newc
            x, newc = _scan_or_unroll(cfg, scan_body, x, (seg, segc))
        new_segs.append(newc)

    x = norm(cfg, params["final_norm"], x)
    # logits at the last real token of each prompt
    idx = jnp.clip(prompt_lengths - 1, 0, S - 1)
    last_h = jax.vmap(lambda h, i: h[i])(x, idx)
    logits = unembed(cfg, params["embed"], last_h[:, None])[:, 0]
    new_cache = {"lengths": prompt_lengths, "segments": new_segs}
    return logits, new_cache


def _prefill_block(cfg: ModelConfig, kind: str, blk: dict, c: dict, x,
                   positions, prompt_lengths, enc_out, mesh=None,
                   kv_writer=None):
    """Full-sequence pass that also produces the cache entry for this layer.

    kv_writer: optional (c, k, v) -> newc override for the attention-KV cache
    entry (the paged backend scatters into its page pool here); the compute
    path is shared so dense and paged prefill produce identical activations.
    """
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    if kind in (ATTN, MOE, SHARED_ATTN):
        xin = norm(cfg, blk["norm1"], x)
        q, k, v = attn_lib._project_qkv(cfg, blk["attn"], xin)
        if cfg.use_rope:
            q = attn_lib.apply_rope(q, positions, cfg.rope_theta)
            k = attn_lib.apply_rope(k, positions, cfg.rope_theta)
        kf = attn_lib._repeat_kv(k, cfg.q_per_kv)
        vf = attn_lib._repeat_kv(v, cfg.q_per_kv)
        h = attn_lib.full_or_chunked_sdpa(
            q, kf, vf, causal=True, window=cfg.sliding_window,
            kv_lengths=prompt_lengths, softcap=cfg.attn_logit_softcap)
        h = jnp.einsum("bsnh,nhd->bsd", h, blk["attn"]["wo"].astype(x.dtype))
        x = x + h
        if kv_writer is not None:
            newc = kv_writer(c, k, v)
            return _prefill_block_tail(cfg, kind, blk, x, newc, enc_out, mesh)
        newc = dict(c)
        if cfg.sliding_window:
            w = cfg.sliding_window
            # keep the last `w` positions (assumes S >= w or pads zeros)
            if S >= w:
                newc["k"], newc["v"] = k[:, S - w:], v[:, S - w:]
                # ring layout: slot = pos % w
                roll = (-(S % w)) % w
                newc["k"] = jnp.roll(newc["k"], -roll, axis=1)
                newc["v"] = jnp.roll(newc["v"], -roll, axis=1)
            else:
                pad_k = jnp.zeros((B, w - S, cfg.n_kv_heads, hd), k.dtype)
                newc["k"] = jnp.concatenate([k, pad_k], axis=1)
                newc["v"] = jnp.concatenate([v, pad_k], axis=1)
        else:
            newc["k"] = jnp.zeros_like(c["k"]).at[:, :S].set(k)
            newc["v"] = jnp.zeros_like(c["v"]).at[:, :S].set(v)
        return _prefill_block_tail(cfg, kind, blk, x, newc, enc_out, mesh)
    if kind == MAMBA2:
        out, conv_s, ssd_s = ssm_lib.mamba2_fwd(
            cfg, blk["mamba"], norm(cfg, blk["norm1"], x), return_state=True)
        return x + out, {"conv": conv_s.astype(c["conv"].dtype), "ssd": ssd_s}
    if kind == MLSTM:
        out, st = xlstm_lib.mlstm_fwd(cfg, blk["mlstm"],
                                      norm(cfg, blk["norm1"], x),
                                      return_state=True)
        return x + out, st
    if kind == SLSTM:
        out, st = xlstm_lib.slstm_fwd(cfg, blk["slstm"],
                                      norm(cfg, blk["norm1"], x),
                                      return_state=True)
        return x + out, st
    raise ValueError(kind)


def _prefill_block_tail(cfg: ModelConfig, kind: str, blk: dict, x, newc,
                        enc_out, mesh=None):
    """Post-attention prefill tail shared by the dense and paged KV writers:
    optional cross-attention cache, then the MoE/MLP block."""
    if enc_out is not None and "xattn" in blk:
        xin2 = norm(cfg, blk["norm_x"], x)
        _, ck, cv = attn_lib._project_qkv(cfg, blk["xattn"], xin2,
                                          kv_x=enc_out)
        newc["cross_k"], newc["cross_v"] = ck, cv
        x = x + attn_lib.cross_attention_cached(cfg, blk["xattn"], xin2, ck, cv)
    if kind == MOE:
        h, _ = moe_lib.moe_fwd(cfg, blk["moe"], norm(cfg, blk["norm2"], x),
                               mesh=mesh)
    else:
        h = mlp(cfg, blk["mlp"], norm(cfg, blk["norm2"], x))
    return x + h, newc


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def _advance_lengths(lengths: jax.Array,
                     active: Optional[jax.Array]) -> jax.Array:
    """Post-decode length update: only active rows consumed a token. Without
    the mask, freed slots' lengths drift past max_len between requests and
    keep issuing clipped cache writes."""
    if active is None:
        return lengths + 1
    return lengths + active.astype(lengths.dtype)


def decode_step(cfg: ModelConfig, params: dict, tokens: jax.Array, cache: dict,
                mesh=None, active: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, dict]:
    """tokens: (B, 1) -> (logits (B, vocab), updated cache).

    active: optional (B,) bool mask of live slots; inactive rows keep their
    cached length (their writes land in freed space and are overwritten on
    slot reuse)."""
    x = embed(cfg, params["embed"], tokens)
    lengths = cache["lengths"]
    if cfg.family == "encdec":
        pos = jnp.clip(lengths, 0, cfg.max_seq_len - 1)
        x = x + params["dec_pos"].astype(x.dtype)[pos][:, None]
    x = _constrain(cfg, mesh, x)

    new_segs = []
    for (kind, count), seg, segc in zip(segments_of(cfg), params["segments"],
                                        cache["segments"]):
        if kind == SHARED_ATTN:
            x, newc = _decode_block(cfg, kind, params["shared"],
                                    jax.tree.map(lambda a: a[0], segc), x,
                                    lengths, mesh)
            newc = jax.tree.map(lambda a: a[None], newc)
        else:
            def scan_body(x, inp, kind=kind):
                blk, c = inp
                x = _constrain(cfg, mesh, x)
                x, newc = _decode_block(cfg, kind, blk, c, x, lengths, mesh)
                return x, newc
            x, newc = _scan_or_unroll(cfg, scan_body, x, (seg, segc))
        new_segs.append(newc)

    x = norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params["embed"], x)[:, 0]
    if mesh is not None:
        logits = shd.constraint(logits, mesh, (shd.batch_axes(mesh), "model"))
    new_cache = {"lengths": _advance_lengths(lengths, active),
                 "segments": new_segs}
    return logits, new_cache


# ---------------------------------------------------------------------------
# Paged KV cache (vLLM-style): init / prefill / decode
# ---------------------------------------------------------------------------

def _check_paged_support(cfg: ModelConfig) -> None:
    assert cfg.sliding_window == 0, \
        "paged KV cache supports full attention only (sliding_window=0)"
    assert cfg.family != "encdec", \
        "paged KV cache does not support cross-attention caches"


def init_paged_cache(cfg: ModelConfig, batch: int, n_pages: int,
                     page_size: int, max_pages_per_seq: int,
                     spec: bool = False) -> dict:
    """Paged cache pytree: attention segments store per-layer page pools
    addressed through one shared block table; recurrent segments (SSM/xLSTM)
    keep their O(1) per-slot dense states.

      k_pages/v_pages: (count, n_pages, page_size, n_kv, hd)
      block_table:     (batch, max_pages_per_seq) int32, -1 = unmapped
      lengths:         (batch,)

    cfg.kv_quantized stores the pools at the int8/fp8 storage dtype and adds
    per-(page, kv-head) f32 scale leaves k_scale/v_scale: (count, n_pages,
    n_kv), initialized to ones so unwritten pages dequantize to zeros.
    """
    _check_paged_support(cfg)
    from repro.models import paged_cache as pc
    hd = cfg.resolved_head_dim
    adt = pc.kv_storage_dtype(cfg.resolved_kv_dtype)
    mk = (lambda sh, dt: jax.ShapeDtypeStruct(sh, dt)) if spec else (
        lambda sh, dt: jnp.zeros(sh, dt))
    segs = []
    for kind, count in segments_of(cfg):
        if kind in (ATTN, MOE, SHARED_ATTN):
            seg = {
                "k_pages": mk((count, n_pages, page_size, cfg.n_kv_heads, hd),
                              adt),
                "v_pages": mk((count, n_pages, page_size, cfg.n_kv_heads, hd),
                              adt),
            }
            if cfg.kv_quantized:
                sh = (count, n_pages, cfg.n_kv_heads)
                seg["k_scale"] = (mk(sh, jnp.float32) if spec
                                  else jnp.ones(sh, jnp.float32))
                seg["v_scale"] = (mk(sh, jnp.float32) if spec
                                  else jnp.ones(sh, jnp.float32))
            segs.append(seg)
        else:
            segs.append(_seg_cache(cfg, kind, count, batch, 0, spec))
    table = (jax.ShapeDtypeStruct((batch, max_pages_per_seq), jnp.int32)
             if spec else jnp.full((batch, max_pages_per_seq), -1, jnp.int32))
    return {"lengths": mk((batch,), jnp.int32), "block_table": table,
            "segments": segs}


def prefill_paged(cfg: ModelConfig, params: dict, tokens: jax.Array,
                  cache: dict, slot, prompt_len, mesh=None
                  ) -> Tuple[jax.Array, dict]:
    """Prefill one request (tokens: (1, S) right-padded) directly into the
    shared paged cache at batch row `slot`, whose block-table row must already
    map enough pages for `prompt_len` tokens. Returns (logits (1, V), cache).
    """
    _check_paged_support(cfg)
    from repro.models import paged_cache as pc
    x = embed(cfg, params["embed"], tokens)
    B, S, _ = x.shape
    plen = jnp.asarray(prompt_len, jnp.int32).reshape(())
    plens = plen[None]
    positions = jnp.arange(S)[None]
    block_row = cache["block_table"][slot]
    x = _constrain(cfg, mesh, x)

    def paged_writer(c, k, v):
        if cfg.kv_quantized:
            pk, pv, ks, vs = pc.write_prompt_quant(
                c["k_pages"], c["v_pages"], c["k_scale"], c["v_scale"],
                block_row, k, v, plen, cfg.kv_dtype)
            return {"k_pages": pk, "v_pages": pv, "k_scale": ks,
                    "v_scale": vs}
        pk, pv = pc.write_prompt(c["k_pages"], c["v_pages"], block_row,
                                 k, v, plen)
        return {"k_pages": pk, "v_pages": pv}

    def insert_slot(big, one):
        return jax.tree.map(
            lambda bg, on: jax.lax.dynamic_update_slice(
                bg, on.astype(bg.dtype), (slot,) + (0,) * (bg.ndim - 1)),
            big, one)

    def block(x, blk, c, kind):
        if kind in (ATTN, MOE, SHARED_ATTN):
            return _prefill_block(cfg, kind, blk, c, x, positions, plens,
                                  None, mesh, kv_writer=paged_writer)
        x, one = _prefill_block(cfg, kind, blk, c, x, positions, plens,
                                None, mesh)
        return x, insert_slot(c, one)

    new_segs = []
    for (kind, count), seg, segc in zip(segments_of(cfg), params["segments"],
                                        cache["segments"]):
        if kind == SHARED_ATTN:
            x, newc = block(x, params["shared"],
                            jax.tree.map(lambda a: a[0], segc), kind)
            newc = jax.tree.map(lambda a: a[None], newc)
        else:
            def scan_body(x, inp, kind=kind):
                blk, c = inp
                x = _constrain(cfg, mesh, x)
                return block(x, blk, c, kind)
            x, newc = _scan_or_unroll(cfg, scan_body, x, (seg, segc))
        new_segs.append(newc)

    x = norm(cfg, params["final_norm"], x)
    idx = jnp.clip(plens - 1, 0, S - 1)
    last_h = jax.vmap(lambda h, i: h[i])(x, idx)
    logits = unembed(cfg, params["embed"], last_h[:, None])[:, 0]
    new_cache = {"lengths": cache["lengths"].at[slot].set(plen),
                 "block_table": cache["block_table"], "segments": new_segs}
    return logits, new_cache


def prefill_chunk_paged(cfg: ModelConfig, params: dict, tokens: jax.Array,
                        cache: dict, slot, offset, chunk_len,
                        live_pages: Optional[int] = None, mesh=None
                        ) -> Tuple[jax.Array, dict]:
    """Ingest one prompt chunk (tokens: (1, C) right-padded to `chunk_len`
    valid) into the shared paged cache at batch row `slot`, whose block-table
    row must already map pages through offset + chunk_len tokens. Chunk
    queries attend causally within the chunk and against the slot's
    already-written context (ragged cross-chunk read); `live_pages` (static)
    trims the read to the covering block-table columns exactly like the
    decode step. Returns (logits (1, V) at the last valid chunk token,
    cache) — only the final chunk's logits seed sampling.

    Chunked ingestion requires an attention-only stack: recurrent segments
    (SSM / xLSTM) would need their scan state carried across chunks, which
    their fwd paths do not expose — the engine falls back to monolithic
    prefill for those families.
    """
    _check_paged_support(cfg)
    assert all(kind in (ATTN, MOE, SHARED_ATTN)
               for kind, _ in segments_of(cfg)), \
        "chunked prefill supports attention-only stacks"
    x = embed(cfg, params["embed"], tokens)
    B, C, _ = x.shape
    clen = jnp.asarray(chunk_len, jnp.int32).reshape(())
    off = jnp.asarray(offset, jnp.int32).reshape(())
    block_row = cache["block_table"][slot]
    x = _constrain(cfg, mesh, x)

    def block(x, blk, c, kind):
        xin = norm(cfg, blk["norm1"], x)
        h, nk, nv, nks, nvs = attn_lib.attention_prefill_chunk_paged(
            cfg, blk["attn"], xin, c["k_pages"], c["v_pages"], block_row,
            off, clen, live_pages=live_pages,
            k_scales=c.get("k_scale"), v_scales=c.get("v_scale"))
        x = x + h
        newc = {"k_pages": nk, "v_pages": nv}
        if nks is not None:
            newc["k_scale"], newc["v_scale"] = nks, nvs
        return _prefill_block_tail(cfg, kind, blk, x, newc, None, mesh)

    new_segs = []
    for (kind, count), seg, segc in zip(segments_of(cfg), params["segments"],
                                        cache["segments"]):
        if kind == SHARED_ATTN:
            x, newc = block(x, params["shared"],
                            jax.tree.map(lambda a: a[0], segc), kind)
            newc = jax.tree.map(lambda a: a[None], newc)
        else:
            def scan_body(x, inp, kind=kind):
                blk, c = inp
                x = _constrain(cfg, mesh, x)
                return block(x, blk, c, kind)
            x, newc = _scan_or_unroll(cfg, scan_body, x, (seg, segc))
        new_segs.append(newc)

    x = norm(cfg, params["final_norm"], x)
    idx = jnp.clip(clen - 1, 0, C - 1)
    last_h = x[:, idx]
    logits = unembed(cfg, params["embed"], last_h[:, None])[:, 0]
    new_cache = {"lengths": cache["lengths"].at[slot].set(off + clen),
                 "block_table": cache["block_table"], "segments": new_segs}
    return logits, new_cache


def prefill_ragged_paged(cfg: ModelConfig, params: dict, tokens: jax.Array,
                         cache: dict, slots, offsets, lens,
                         live_pages: Optional[int] = None, mesh=None
                         ) -> Tuple[jax.Array, dict]:
    """Batched ragged chunk ingest: R slots' next prompt chunks in ONE call.

    tokens: (R, C) — row r is slot `slots[r]`'s next chunk, right-padded to
    `lens[r]` valid tokens starting at logical position `offsets[r]`; slots/
    offsets/lens: (R,) int32. Padding rows (the engine buckets R) carry
    slots[r] == batch (out of range): their cache scatters drop and their
    block-table gathers clip to a live row whose results are discarded.
    Each row's block-table entry must already map pages through
    offsets[r] + lens[r] tokens. Returns (logits (R, V) at each row's last
    valid chunk token, cache); padding rows' logits are unspecified.

    This is the plan/run engine's one-device-call-per-step ingest
    (flashinfer's BatchPrefillWithPagedKVCacheWrapper layout): the scheduler
    plans (slot, offset, len) rows on the host, then every ingesting slot
    advances together. Per-row numerics are bitwise the one-chunk-per-step
    `prefill_chunk_paged` path — batching adds rows, never changes a row's
    reduction order — which keeps chunked ingest bit-identical to monolithic
    prefill. Same attention-only restriction as `prefill_chunk_paged`.
    """
    _check_paged_support(cfg)
    assert all(kind in (ATTN, MOE, SHARED_ATTN)
               for kind, _ in segments_of(cfg)), \
        "chunked prefill supports attention-only stacks"
    x = embed(cfg, params["embed"], tokens)
    R, C, _ = x.shape
    slots = jnp.asarray(slots, jnp.int32)
    offsets = jnp.asarray(offsets, jnp.int32)
    lens = jnp.asarray(lens, jnp.int32)
    block_rows = jnp.take(cache["block_table"], slots, axis=0, mode="clip")
    x = _constrain(cfg, mesh, x)

    def block(x, blk, c, kind):
        xin = norm(cfg, blk["norm1"], x)
        h, nk, nv, nks, nvs = attn_lib.attention_prefill_ragged_paged(
            cfg, blk["attn"], xin, c["k_pages"], c["v_pages"], block_rows,
            offsets, lens, live_pages=live_pages,
            k_scales=c.get("k_scale"), v_scales=c.get("v_scale"))
        x = x + h
        newc = {"k_pages": nk, "v_pages": nv}
        if nks is not None:
            newc["k_scale"], newc["v_scale"] = nks, nvs
        return _prefill_block_tail(cfg, kind, blk, x, newc, None, mesh)

    new_segs = []
    for (kind, count), seg, segc in zip(segments_of(cfg), params["segments"],
                                        cache["segments"]):
        if kind == SHARED_ATTN:
            x, newc = block(x, params["shared"],
                            jax.tree.map(lambda a: a[0], segc), kind)
            newc = jax.tree.map(lambda a: a[None], newc)
        else:
            def scan_body(x, inp, kind=kind):
                blk, c = inp
                x = _constrain(cfg, mesh, x)
                return block(x, blk, c, kind)
            x, newc = _scan_or_unroll(cfg, scan_body, x, (seg, segc))
        new_segs.append(newc)

    x = norm(cfg, params["final_norm"], x)
    idx = jnp.clip(lens - 1, 0, C - 1)
    last_h = jax.vmap(lambda h, i: h[i])(x, idx)               # (R, D)
    # per-row unembed via lax.map, NOT one (R, 1, D) einsum: XLA collapses
    # the latter into an M=R GEMM whose accumulation can differ from the
    # serial path's M=1 matvec by an ulp (opt-level dependent); mapping
    # keeps every row the exact (1, 1, D) shape `prefill_chunk_paged`
    # lowers, preserving the bitwise row-identity contract. R is small
    # (bucketed batch rows) and only one row per request ever seeds a
    # sample, so the serialization is negligible.
    logits = jax.lax.map(
        lambda h: unembed(cfg, params["embed"], h[None, None])[0, 0], last_h)
    # padding rows target index `batch` and are dropped
    lengths = cache["lengths"].at[slots].set(offsets + lens, mode="drop")
    new_cache = {"lengths": lengths,
                 "block_table": cache["block_table"], "segments": new_segs}
    return logits, new_cache


def decode_step_paged(cfg: ModelConfig, params: dict, tokens: jax.Array,
                      cache: dict, mesh=None,
                      active: Optional[jax.Array] = None,
                      live_pages: Optional[int] = None
                      ) -> Tuple[jax.Array, dict]:
    """tokens: (B, 1) -> (logits (B, vocab), updated paged cache).

    Attention layers append the new token into their page pools through the
    block table and read either the Pallas paged flash-decode kernel
    (cfg.use_pallas) or the gather oracle; recurrent layers are identical
    to the dense decode. `active` masks freed rows' length advance AND their
    K/V writes — the plan/run engine pushes block-table clears lazily, so a
    freed row's stale table entry may still map a COW sibling's pages.
    `live_pages` (static) bounds the attention READ to the first live
    block-table columns — see attention_decode_paged.
    """
    _check_paged_support(cfg)
    x = embed(cfg, params["embed"], tokens)
    lengths = cache["lengths"]
    table = cache["block_table"]
    x = _constrain(cfg, mesh, x)

    def block(x, blk, c, kind):
        if kind in (ATTN, MOE, SHARED_ATTN):
            return _decode_block_paged(cfg, kind, blk, c, x, lengths, table,
                                       mesh, live_pages=live_pages,
                                       active=active)
        return _decode_block(cfg, kind, blk, c, x, lengths, mesh)

    new_segs = []
    for (kind, count), seg, segc in zip(segments_of(cfg), params["segments"],
                                        cache["segments"]):
        if kind == SHARED_ATTN:
            x, newc = block(x, params["shared"],
                            jax.tree.map(lambda a: a[0], segc), kind)
            newc = jax.tree.map(lambda a: a[None], newc)
        else:
            def scan_body(x, inp, kind=kind):
                blk, c = inp
                x = _constrain(cfg, mesh, x)
                return block(x, blk, c, kind)
            x, newc = _scan_or_unroll(cfg, scan_body, x, (seg, segc))
        new_segs.append(newc)

    x = norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params["embed"], x)[:, 0]
    if mesh is not None:
        logits = shd.constraint(logits, mesh, (shd.batch_axes(mesh), "model"))
    new_cache = {"lengths": _advance_lengths(lengths, active),
                 "block_table": table, "segments": new_segs}
    return logits, new_cache


def fork_slot_paged(cfg: ModelConfig, cache: dict, src_slot, dst_slot,
                    tail_src_page, tail_dst_page) -> dict:
    """Device-side state duplication behind copy-on-write prefix sharing.

    Full prefix pages are shared through the block table (host side, see
    `PageAllocator.fork`); this op copies only what cannot be shared — the
    partial tail page of every attention layer (pass tail_src_page ==
    tail_dst_page for a no-op when the prefix is page-aligned) and the O(1)
    per-slot recurrent states — then mirrors the source row's cached length.
    Also serves plain COW page copies: call with src_slot == dst_slot and
    the (old, new) page pair from `PageAllocator.cow_page`.
    """
    _check_paged_support(cfg)
    from repro.models import paged_cache as pc
    new_segs = []
    for (kind, count), segc in zip(segments_of(cfg), cache["segments"]):
        if kind in (ATTN, MOE, SHARED_ATTN):
            seg = {
                "k_pages": pc.copy_page(segc["k_pages"], tail_src_page,
                                        tail_dst_page),
                "v_pages": pc.copy_page(segc["v_pages"], tail_src_page,
                                        tail_dst_page),
            }
            if "k_scale" in segc:
                # copy_page is generic over (count, n_pages, ...) leaves, so
                # the tail page's dequant scales ride the same op
                seg["k_scale"] = pc.copy_page(segc["k_scale"], tail_src_page,
                                              tail_dst_page)
                seg["v_scale"] = pc.copy_page(segc["v_scale"], tail_src_page,
                                              tail_dst_page)
            new_segs.append(seg)
        else:
            new_segs.append(jax.tree.map(
                lambda a: a.at[:, dst_slot].set(a[:, src_slot]), segc))
    lengths = cache["lengths"].at[dst_slot].set(cache["lengths"][src_slot])
    return {"lengths": lengths, "block_table": cache["block_table"],
            "segments": new_segs}


def promote_slot_paged(cfg: ModelConfig, cache: dict, upload_ids,
                       payloads, slot, ctx_len) -> dict:
    """Swap-in (host-tier promote): scatter a demoted request's snapshotted
    pages back into every attention segment's pool and restore its cached
    length, so decode re-enters directly — no replay.

    upload_ids: (U,) int32 physical page targets, padded with n_pages
    (dropped); payloads: one dict per attention segment holding k_pages/
    v_pages (count, U, page, n_kv, hd) at the pool's storage dtype (plus
    k_scale/v_scale (count, U, n_kv) for quantized pools); slot/ctx_len:
    traced scalars. The block table is pushed separately by the engine's
    host mirror. Swap is gated to attention-only stacks (recurrent segments
    would need their dense states snapshotted too), so non-attention
    segments pass through untouched."""
    _check_paged_support(cfg)
    new_segs = []
    pi = 0
    for (kind, count), segc in zip(segments_of(cfg), cache["segments"]):
        if kind in (ATTN, MOE, SHARED_ATTN):
            pay = payloads[pi]
            pi += 1
            new_segs.append({
                key: segc[key].at[:, upload_ids].set(
                    pay[key].astype(segc[key].dtype), mode="drop")
                for key in segc
            })
        else:
            new_segs.append(segc)
    lengths = cache["lengths"].at[slot].set(
        jnp.asarray(ctx_len, jnp.int32))
    return {"lengths": lengths, "block_table": cache["block_table"],
            "segments": new_segs}


def _decode_block_paged(cfg: ModelConfig, kind: str, blk: dict, c: dict, x,
                        lengths, table, mesh=None,
                        live_pages: Optional[int] = None, active=None):
    xin = norm(cfg, blk["norm1"], x)
    h, nk, nv, nks, nvs = attn_lib.attention_decode_paged(
        cfg, blk["attn"], xin, c["k_pages"], c["v_pages"], table, lengths,
        live_pages=live_pages, active=active,
        k_scales=c.get("k_scale"), v_scales=c.get("v_scale"))
    x = x + h
    newc = {"k_pages": nk, "v_pages": nv}
    if nks is not None:
        newc["k_scale"], newc["v_scale"] = nks, nvs
    if kind == MOE:
        h, _ = moe_lib.moe_fwd(cfg, blk["moe"], norm(cfg, blk["norm2"], x),
                               mesh=mesh)
    else:
        h = mlp(cfg, blk["mlp"], norm(cfg, blk["norm2"], x))
    return x + h, newc


def _decode_block(cfg: ModelConfig, kind: str, blk: dict, c: dict, x, lengths,
                  mesh=None):
    if kind in (ATTN, MOE, SHARED_ATTN):
        xin = norm(cfg, blk["norm1"], x)
        h, nk, nv = attn_lib.attention_decode(cfg, blk["attn"], xin, c["k"],
                                              c["v"], lengths,
                                              window=cfg.sliding_window)
        x = x + h
        newc = dict(c)
        newc["k"], newc["v"] = nk, nv
        if "cross_k" in c and "xattn" in blk:
            xin2 = norm(cfg, blk["norm_x"], x)
            x = x + attn_lib.cross_attention_cached(cfg, blk["xattn"], xin2,
                                                    c["cross_k"], c["cross_v"])
        if kind == MOE:
            h, _ = moe_lib.moe_fwd(cfg, blk["moe"], norm(cfg, blk["norm2"], x),
                                   mesh=mesh)
        else:
            h = mlp(cfg, blk["mlp"], norm(cfg, blk["norm2"], x))
        return x + h, newc
    if kind == MAMBA2:
        out, conv_s, ssd_s = ssm_lib.mamba2_decode(
            cfg, blk["mamba"], norm(cfg, blk["norm1"], x),
            c["conv"], c["ssd"])
        return x + out, {"conv": conv_s.astype(c["conv"].dtype), "ssd": ssd_s}
    if kind == MLSTM:
        out, st = xlstm_lib.mlstm_decode(cfg, blk["mlstm"],
                                         norm(cfg, blk["norm1"], x), c)
        return x + out, st
    if kind == SLSTM:
        out, st = xlstm_lib.slstm_decode(cfg, blk["slstm"],
                                         norm(cfg, blk["norm1"], x), c)
        return x + out, st
    raise ValueError(kind)
