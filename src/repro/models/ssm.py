"""Mamba2 (SSD) block in pure JAX: chunked parallel scan for train/prefill,
O(1) recurrent update for decode.

Structure follows arXiv:2405.21060 (Mamba2) as used by Zamba2 (arXiv:2411.15242):
  in_proj -> [z | x | B | C | dt], short causal conv on x, SSD recurrence
  h_t = exp(A*dt_t) h_{t-1} + dt_t * B_t x_t ;  y_t = C_t^T h_t + D x_t
with scalar A per head (SSD restriction), multi-head x (H heads of P dims),
shared B/C across heads (n_groups=1), gated output y * silu(z).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, rmsnorm


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    inner = cfg.ssm_expand * cfg.d_model
    n_heads = cfg.resolved_ssm_heads
    head_dim = inner // n_heads
    return inner, n_heads, head_dim, cfg.ssm_state


def init_mamba2(cfg: ModelConfig, key) -> dict:
    pd = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    inner, H, P, N = ssm_dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], (d, 2 * inner + 2 * N + H), dtype=pd),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, inner)) * 0.1).astype(pd),
        "conv_b": jnp.zeros((inner,), pd),
        "A_log": jnp.log(jnp.linspace(1.0, float(H), H)).astype(pd),
        "D": jnp.ones((H,), pd),
        "dt_bias": jnp.zeros((H,), pd),
        "norm_scale": jnp.ones((inner,), pd),
        "w_out": dense_init(ks[2], (inner, d), dtype=pd),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    inner, H, P, N = ssm_dims(cfg)
    z, xbc = proj[..., :inner], proj[..., inner:]
    x = xbc[..., :inner]
    B = xbc[..., inner:inner + N]
    C = xbc[..., inner + N:inner + 2 * N]
    dt = xbc[..., inner + 2 * N:]
    return z, x, B, C, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv. x: (B,S,inner), w: (K,inner). Returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[-1]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                  # (B, S+K-1, inner)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else xp[:, :0]
    return y + b.astype(x.dtype), new_state


def ssd_chunked(x, dt, A, B, C, chunk: int, initial_state=None, use_pallas: bool = False):
    """Chunked SSD scan.

    x: (Bb,S,H,P), dt: (Bb,S,H) (already softplus'ed), A: (H,) negative,
    B/C: (Bb,S,N). Returns (y (Bb,S,H,P), final_state (Bb,H,P,N)).
    """
    if use_pallas:
        from repro.kernels.ssm_scan import ops as ssd_ops
        return ssd_ops.ssm_scan(x, dt, A, B, C, chunk=chunk,
                                initial_state=initial_state)
    from repro.kernels.ssm_scan import ref as ssd_ref
    return ssd_ref.ssd_chunked_ref(x, dt, A, B, C, chunk=chunk,
                                   initial_state=initial_state)


def mamba2_fwd(cfg: ModelConfig, params: dict, u: jax.Array,
               conv_state: Optional[jax.Array] = None,
               ssd_state: Optional[jax.Array] = None,
               return_state: bool = False):
    """u: (Bb, S, D). Full-sequence path (train/prefill)."""
    dt_ = u.dtype
    Bb, S, _ = u.shape
    inner, H, P, N = ssm_dims(cfg)
    proj = u @ params["w_in"].astype(dt_)
    z, x, Bm, Cm, dt = _split_proj(cfg, proj)
    x, new_conv = _causal_conv(x, params["conv_w"], params["conv_b"], conv_state)
    x = jax.nn.silu(x)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = x.reshape(Bb, S, H, P)
    y, final_state = ssd_chunked(xh.astype(jnp.float32), dt, A,
                                 Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                                 cfg.ssm_chunk, initial_state=ssd_state,
                                 use_pallas=cfg.use_pallas)
    y = y + xh.astype(jnp.float32) * params["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(Bb, S, inner).astype(dt_)
    y = rmsnorm(y * jax.nn.silu(z), params["norm_scale"], cfg.norm_eps)
    out = y @ params["w_out"].astype(dt_)
    if return_state:
        return out, new_conv, final_state
    return out


def mamba2_decode(cfg: ModelConfig, params: dict, u: jax.Array,
                  conv_state: jax.Array, ssd_state: jax.Array):
    """Single-token recurrent step. u: (Bb, 1, D).

    conv_state: (Bb, K-1, inner); ssd_state: (Bb, H, P, N) float32.
    """
    dt_ = u.dtype
    Bb = u.shape[0]
    inner, H, P, N = ssm_dims(cfg)
    proj = u @ params["w_in"].astype(dt_)
    z, x, Bm, Cm, dt = _split_proj(cfg, proj)
    x, new_conv = _causal_conv(x, params["conv_w"], params["conv_b"], conv_state)
    x = jax.nn.silu(x)[:, 0]                                   # (Bb, inner)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))   # (Bb,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))          # (H,)
    xh = x.reshape(Bb, H, P).astype(jnp.float32)
    Bv = Bm[:, 0].astype(jnp.float32)                          # (Bb,N)
    Cv = Cm[:, 0].astype(jnp.float32)
    decay = jnp.exp(dt * A[None, :])                           # (Bb,H)
    upd = (dt[:, :, None] * xh)[..., None] * Bv[:, None, None, :]  # (Bb,H,P,N)
    new_state = ssd_state * decay[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cv)
    y = y + xh * params["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(Bb, 1, inner).astype(dt_)
    y = rmsnorm(y * jax.nn.silu(z), params["norm_scale"], cfg.norm_eps)
    out = y @ params["w_out"].astype(dt_)
    return out, new_conv, new_state
