"""Mixture-of-Experts FFN with capacity-based dispatch (drop-on-overflow).

Scatter/gather dispatch (no (T,E,C) one-hot einsum) so it scales to
128-expert x 1M-token training batches:

  1. router top-k -> (expert_id, weight) per assignment, T*k assignments
  2. position-in-expert via cumsum over a (T*k, E) one-hot
  3. scatter tokens into an (E, C, D) buffer (overflow drops)
  4. per-expert SwiGLU: (E,C,D) x (E,D,F)
  5. gather + weighted combine back to (T, D)

The router load-balance auxiliary loss follows Switch/Mixtral:
  aux = E * sum_e( frac_tokens_e * mean_router_prob_e ).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init


def init_moe(cfg: ModelConfig, key) -> dict:
    pd = jnp.dtype(cfg.param_dtype)
    d, f, e = cfg.d_model, cfg.expert_d_ff, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": dense_init(k1, (d, e), dtype=pd),
        "w_gate": dense_init(k2, (e, d, f), in_axis=1, dtype=pd),
        "w_up": dense_init(k3, (e, d, f), in_axis=1, dtype=pd),
        "w_down": dense_init(k4, (e, f, d), in_axis=1, dtype=pd),
    }


def moe_capacity(n_tokens: int, cfg: ModelConfig) -> int:
    per = n_tokens * cfg.experts_per_token / cfg.n_experts
    cap = int(math.ceil(per * cfg.capacity_factor))
    return max(cap, cfg.experts_per_token, 4)


def _ep_constraint(mesh, arr, expert_axis_ok: bool):
    """Shard dim 0 (experts) over `model` when divisible (expert parallel)."""
    if mesh is None or not expert_axis_ok:
        return arr
    from repro.distributed import sharding as shd
    return shd.constraint(arr, mesh, ["model"] + [None] * (arr.ndim - 1))


def moe_fwd(cfg: ModelConfig, params: dict, x: jax.Array, mesh=None
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out (B,S,D), aux_loss scalar)."""
    B, S, D = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.experts_per_token
    C = moe_capacity(T, cfg)
    dt = x.dtype
    xf = x.reshape(T, D)

    logits = (xf @ params["router"].astype(dt)).astype(jnp.float32)   # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)                             # (T,K)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)             # renorm over top-k

    # load-balance aux loss
    frac = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    for j in range(1, K):
        frac = frac + jnp.mean(jax.nn.one_hot(top_e[:, j], E, dtype=jnp.float32), axis=0)
    frac = frac / K
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=0))

    # --- dispatch ---------------------------------------------------------
    flat_e = top_e.reshape(T * K)                                      # (A,)
    flat_w = top_w.reshape(T * K).astype(dt)
    if cfg.moe_sort_dispatch:
        # position-in-expert via a stable argsort over expert ids: O(A log A)
        # instead of the (A, E) one-hot cumsum, which XLA lowers to a
        # quadratic reduce-window (dominates HLO FLOPs at 128 experts).
        A = T * K
        order = jnp.argsort(flat_e, stable=True)                       # (A,)
        sorted_e = flat_e[order]
        run_start = jnp.searchsorted(sorted_e, jnp.arange(E),
                                     side="left")                      # (E,)
        pos_sorted = jnp.arange(A) - run_start[sorted_e]
        flat_pos = jnp.zeros((A,), jnp.int32).at[order].set(
            pos_sorted.astype(jnp.int32))
    else:
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)            # (A,E)
        pos = jnp.cumsum(onehot, axis=0) - onehot                      # rank in expert
        flat_pos = jnp.sum(pos * onehot, axis=-1)                      # (A,)
    keep = flat_pos < C
    # scatter tokens into (E, C, D); dropped assignments go to a trash row
    safe_e = jnp.where(keep, flat_e, E)
    safe_p = jnp.where(keep, flat_pos, 0)
    buf = jnp.zeros((E + 1, C, D), dt)
    token_idx = jnp.repeat(jnp.arange(T), K)
    buf = buf.at[safe_e, safe_p].set(xf[token_idx], mode="drop")
    buf = buf[:E]                                                      # (E,C,D)

    # --- expert compute ----------------------------------------------------
    ep = cfg.moe_ep and mesh is not None and E % mesh.shape.get("model", 1) == 0
    buf = _ep_constraint(mesh, buf, ep)
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(dt))
    h = jax.nn.silu(_ep_constraint(mesh, g, ep)) * _ep_constraint(mesh, u, ep)
    y = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(dt))     # (E,C,D)
    y = _ep_constraint(mesh, y, ep)

    # --- combine ------------------------------------------------------------
    gathered = y[safe_e, safe_p]                                       # (A,D)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    out = jnp.zeros((T, D), dt).at[token_idx].add(gathered * flat_w[:, None])
    return out.reshape(B, S, D), aux
