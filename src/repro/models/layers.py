"""Common neural-net layers (pure JAX): norms, RoPE, MLPs, embeddings."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    scale = 1.0 / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def norm(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    if cfg.use_layernorm:
        return layernorm(x, params["scale"], params["bias"], cfg.norm_eps)
    return rmsnorm(x, params["scale"], cfg.norm_eps)


def init_norm(cfg: ModelConfig, d: int) -> dict:
    p = {"scale": jnp.ones((d,), jnp.dtype(cfg.param_dtype))}
    if cfg.use_layernorm:
        p["bias"] = jnp.zeros((d,), jnp.dtype(cfg.param_dtype))
    return p


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                        # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                              # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key, d_model: int, d_ff: int, gated: bool = True) -> dict:
    pd = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    if gated:
        return {
            "w_gate": dense_init(k1, (d_model, d_ff), dtype=pd),
            "w_up": dense_init(k2, (d_model, d_ff), dtype=pd),
            "w_down": dense_init(k3, (d_ff, d_model), dtype=pd),
        }
    return {
        "w_up": dense_init(k1, (d_model, d_ff), dtype=pd),
        "b_up": jnp.zeros((d_ff,), pd),
        "w_down": dense_init(k2, (d_ff, d_model), dtype=pd),
        "b_down": jnp.zeros((d_model,), pd),
    }


def mlp(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    dt = x.dtype
    if "w_gate" in params:  # SwiGLU
        g = x @ params["w_gate"].astype(dt)
        u = x @ params["w_up"].astype(dt)
        h = jax.nn.silu(g) * u
        return h @ params["w_down"].astype(dt)
    h = x @ params["w_up"].astype(dt) + params["b_up"].astype(dt)
    h = jax.nn.gelu(h)
    return h @ params["w_down"].astype(dt) + params["b_down"].astype(dt)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(cfg: ModelConfig, key) -> dict:
    pd = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    p = {"tok": embed_init(k1, (cfg.vocab_size, cfg.d_model), pd)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(k2, (cfg.d_model, cfg.vocab_size), dtype=pd)
    return p


def embed(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    return params["tok"].astype(_dt(cfg))[tokens]


def unembed(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        w = params["tok"].astype(x.dtype).T
    else:
        w = params["unembed"].astype(x.dtype)
    return x @ w
