"""Model configuration for the repro model zoo.

A single ModelConfig covers every assigned architecture family:
dense GQA decoders, MoE, SSM (Mamba2), xLSTM (sLSTM/mLSTM), hybrid
(Mamba2 + shared attention), encoder-decoder (whisper) and VLM
(decoder-only LM consuming stubbed patch embeddings).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


# Block kinds used in `block_pattern`.
ATTN = "attn"          # self-attention + MLP (standard decoder block)
MOE = "moe"            # self-attention + MoE FFN
MAMBA2 = "mamba2"      # Mamba2 SSD block
SLSTM = "slstm"        # xLSTM scalar-memory block
MLSTM = "mlstm"        # xLSTM matrix-memory block
SHARED_ATTN = "shared_attn"  # zamba2-style shared transformer block (tied weights)


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Config for the (stubbed-frontend) encoder of enc-dec / VLM models.

    The modality frontend itself (mel conv codec / ViT) is a stub:
    ``input_specs`` provides precomputed frame or patch embeddings with shape
    (batch, n_ctx, d_model_enc). The transformer encoder over those embeddings
    IS implemented (it is a normal transformer stack).
    """
    n_layers: int = 4
    d_model: int = 384
    n_heads: int = 6
    n_kv_heads: int = 6
    d_ff: int = 1536
    n_ctx: int = 1500           # number of frames / patches after the stub frontend


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"       # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int = 4
    d_model: int = 512
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 0           # 0 -> d_model // n_heads
    d_ff: int = 2048
    vocab_size: int = 32000
    max_seq_len: int = 32768

    # attention options
    qk_norm: bool = False       # qwen3-style per-head q/k RMSNorm
    qkv_bias: bool = False      # qwen2-style bias on qkv projections
    sliding_window: int = 0     # 0 = full attention; >0 = SWA window
    rope_theta: float = 1e6
    use_rope: bool = True       # whisper uses learned positions instead
    attn_logit_softcap: float = 0.0

    # norm / activation
    norm_eps: float = 1e-6
    use_layernorm: bool = False  # whisper uses LayerNorm; others RMSNorm
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0           # expert hidden dim (if != d_ff)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # position-in-expert via argsort instead of the (A,E) one-hot cumsum
    # (beyond-paper §Perf optimization; see EXPERIMENTS.md)
    moe_sort_dispatch: bool = False
    # expert-parallel: shard the dispatch buffer + expert weights over the
    # `model` axis on the expert dim (all-to-all dispatch; §Perf)
    moe_ep: bool = False

    # SSM (Mamba2)
    ssm_state: int = 0          # state dim per head
    ssm_heads: int = 0          # number of SSM heads (0 -> derived)
    ssm_expand: int = 2
    ssm_chunk: int = 256        # chunked-scan block size
    ssm_conv: int = 4           # short conv width

    # xLSTM
    slstm_at: Tuple[int, ...] = ()   # layer indices that are sLSTM (rest mLSTM)

    # hybrid (zamba2): one shared attn block applied every `shared_attn_every`
    # mamba layers, with tied weights across applications.
    shared_attn_every: int = 0

    # encoder (whisper / vlm frontend stub)
    encoder: Optional[EncoderConfig] = None
    n_prefix_tokens: int = 0    # VLM: number of stub patch-embedding prefix tokens

    # numerics
    dtype: str = "bfloat16"     # activation/compute dtype
    param_dtype: str = "float32"
    # Paged KV pool storage dtype: "" follows `dtype` (status quo, bit-exact
    # paths), "int8"/"fp8" store quantized pages with a per-(page, kv-head)
    # f32 scale tensor alongside each pool — dequantized on read under a
    # documented tolerance contract (docs/serving.md).
    kv_dtype: str = ""

    # runtime switches
    use_pallas: bool = False    # use Pallas kernels for attention/norm/scan
    remat: bool = True          # rematerialize the layer scan in training
    act_shard: str = "batch"    # residual-stream sharding: batch|batch_seq|batch_model
    scan_layers: bool = True    # lax.scan over stacked layers (False = unroll)
    # cast f32 params to the compute dtype ONCE per step (outside remat),
    # instead of per-use inside every layer (§Perf: kills the repeated
    # f32<->bf16 weight conversions that remat re-executes)
    cast_params_once: bool = False

    # PICE: response-length prediction head (0 = disabled)
    length_buckets: int = 0

    # Paged-backend chunked prefill: ingest prompts in fixed chunks of this
    # many tokens, one chunk per engine step interleaved with the decode
    # batch (0 = monolithic prefill). Bounds decode head-of-line blocking by
    # one chunk and collapses prefill jit variants from log2(max_len)
    # bucket shapes to the single chunk shape.
    prefill_chunk: int = 0

    # citation for the config (paper / model card)
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def resolved_kv_dtype(self) -> str:
        """Storage dtype of the paged KV pool ('' tracks the compute dtype)."""
        return self.kv_dtype if self.kv_dtype else self.dtype

    @property
    def kv_quantized(self) -> bool:
        return self.kv_dtype in ("int8", "fp8")

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff else self.d_ff

    @property
    def resolved_ssm_heads(self) -> int:
        if self.ssm_heads:
            return self.ssm_heads
        # mamba2 default: inner dim / 64-wide heads
        inner = self.ssm_expand * self.d_model
        return max(1, inner // 64)

    def block_pattern(self) -> Tuple[str, ...]:
        """The per-layer block kinds for this architecture."""
        if self.family == "ssm" and self.slstm_at:
            return tuple(
                SLSTM if i in set(self.slstm_at) else MLSTM
                for i in range(self.n_layers)
            )
        if self.family == "ssm":
            return tuple([MAMBA2] * self.n_layers)
        if self.family == "hybrid":
            assert self.shared_attn_every > 0
            pat = []
            for i in range(self.n_layers):
                pat.append(MAMBA2)
                if (i + 1) % self.shared_attn_every == 0:
                    pat.append(SHARED_ATTN)
            return tuple(pat)
        if self.is_moe:
            return tuple([MOE] * self.n_layers)
        return tuple([ATTN] * self.n_layers)

    def validate(self) -> None:
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, "GQA requires n_heads % n_kv_heads == 0"
        if self.is_moe:
            assert 0 < self.experts_per_token <= self.n_experts
        if self.family == "hybrid":
            assert self.shared_attn_every > 0
        if self.family in ("encdec",):
            assert self.encoder is not None

    def validate_paged(self, page_size: int, max_len: int) -> None:
        """Page/block alignment contract for the paged KV backend.

        Each page is one (page_size, head_dim) K/V tile streamed per grid
        step by the Pallas paged flash-decode kernel, so under use_pallas
        page_size must be sublane-aligned; head_dim alignment is shared with
        the dense kernels. The multiple comes from
        `repro.analysis.rules.SUBLANE_MULTIPLE` — the same constant the
        static pallas-spec pass applies to literal BlockSpec dims, so the
        runtime check and the CI gate cannot disagree.
        """
        from repro.analysis.rules import SUBLANE_MULTIPLE
        assert page_size > 0, "page_size must be positive"
        assert max_len % page_size == 0, "max_len must be page-aligned"
        assert self.kv_dtype in ("", "float32", "bfloat16", "int8", "fp8"), (
            f"unsupported kv_dtype {self.kv_dtype!r}; expected one of "
            "'', 'float32', 'bfloat16', 'int8', 'fp8'")
        if self.use_pallas:
            assert page_size % SUBLANE_MULTIPLE == 0, (
                "use_pallas streams (page_size, head_dim) page tiles; "
                f"page_size must be a multiple of {SUBLANE_MULTIPLE} "
                "(TPU sublane alignment)")
        if self.prefill_chunk:
            assert self.prefill_chunk > 0, "prefill_chunk must be positive"
            assert self.prefill_chunk <= max_len, (
                "prefill_chunk larger than max_len never splits a prompt")
            if self.use_pallas:
                assert self.prefill_chunk % SUBLANE_MULTIPLE == 0, (
                    "use_pallas tiles the chunk as the kernel's Q block; "
                    "prefill_chunk must be a multiple of "
                    f"{SUBLANE_MULTIPLE} (TPU sublane alignment)")

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test-sized variant of the same family (<=2 layers, d<=512)."""
        kw = dict(
            n_layers=2,
            d_model=min(self.d_model, 256),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=64,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            max_seq_len=512,
            name=self.name + "-reduced",
        )
        if self.is_moe:
            kw["n_experts"] = min(self.n_experts, 4)
            kw["experts_per_token"] = min(self.experts_per_token, 2)
            kw["moe_d_ff"] = min(self.expert_d_ff, 256)
        if self.family == "ssm" and self.slstm_at:
            kw["slstm_at"] = (0,)
        if self.family == "hybrid":
            kw["shared_attn_every"] = 2
            kw["ssm_state"] = min(self.ssm_state or 16, 16)
        if self.family == "ssm" and not self.slstm_at:
            kw["ssm_state"] = min(self.ssm_state or 16, 16)
        if self.encoder is not None:
            kw["encoder"] = EncoderConfig(
                n_layers=2, d_model=kw["d_model"], n_heads=kw["n_heads"],
                n_kv_heads=kw["n_heads"], d_ff=kw["d_ff"], n_ctx=64)
        if self.n_prefix_tokens:
            kw["n_prefix_tokens"] = 16
        if self.sliding_window:
            kw["sliding_window"] = 128
        kw.update(overrides)
        cfg = dataclasses.replace(self, **kw)
        cfg.validate()
        return cfg

    def with_(self, **overrides) -> "ModelConfig":
        return dataclasses.replace(self, **overrides)

    # ------------------------------------------------------------------
    # Parameter accounting (used for roofline MODEL_FLOPS = 6*N*D).
    # ------------------------------------------------------------------
    def param_count(self, active_only: bool = False) -> int:
        """Approximate parameter count (active_only: MoE counts top-k experts)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        attn = d * hd * n_q + 2 * d * hd * n_kv + hd * n_q * d
        mlp_dense = 3 * d * self.d_ff if self.d_ff else 0
        total = 0
        pat = self.block_pattern()
        shared_counted = False
        for kind in pat:
            if kind == ATTN:
                total += attn + mlp_dense
            elif kind == MOE:
                n_e = self.experts_per_token if active_only else self.n_experts
                total += attn + 3 * d * self.expert_d_ff * n_e + d * self.n_experts
            elif kind == MAMBA2:
                inner = self.ssm_expand * d
                nh = self.resolved_ssm_heads
                total += d * (2 * inner + 2 * nh * self.ssm_state + nh) + inner * d
            elif kind in (SLSTM, MLSTM):
                inner = 2 * d
                total += 4 * d * inner + inner * d + 2 * d * (4 * d // 3)
            elif kind == SHARED_ATTN:
                if not shared_counted or not active_only:
                    # tied weights: count once for totals
                    if not shared_counted:
                        total += attn + mlp_dense
                        shared_counted = True
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.encoder is not None:
            e = self.encoder
            e_attn = 4 * e.d_model * e.d_model
            total += e.n_layers * (e_attn + 2 * e.d_model * e.d_ff)
            # cross-attention in decoder layers
            total += self.n_layers * (2 * e.d_model * hd * n_kv + 2 * d * hd * n_q)
        return int(total)
