"""Decode-time state: KV caches (full, sliding-window, paged) and SSM states.

Caches are plain pytrees (dicts of arrays) so they thread through jit/scan and
shard with NamedSharding like any other value. Layout conventions:

  full KV      : k/v (L, B, S_max, n_kv, hd), lengths (B,)
  windowed KV  : k/v (L, B, W, n_kv, hd) ring buffer, lengths (B,)
  ssm state    : conv (L, B, conv_w-1, inner), ssd (L, B, H, hd, N)
  xlstm state  : per-kind stacked states (see xlstm.py)

`lengths` is per-slot so continuous batching can mix requests at different
decode offsets in one batch.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


def init_kv_cache(n_layers: int, batch: int, max_len: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16, window: int = 0) -> dict:
    size = window if window else max_len
    return {
        "k": jnp.zeros((n_layers, batch, size, n_kv, head_dim), dtype),
        "v": jnp.zeros((n_layers, batch, size, n_kv, head_dim), dtype),
        "lengths": jnp.zeros((batch,), jnp.int32),
        "window": window,  # static python int (0 = full)
    }


def kv_cache_spec(n_layers: int, batch: int, max_len: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16, window: int = 0) -> dict:
    """ShapeDtypeStruct stand-ins (for dry-run lowering, no allocation)."""
    size = window if window else max_len
    return {
        "k": jax.ShapeDtypeStruct((n_layers, batch, size, n_kv, head_dim), dtype),
        "v": jax.ShapeDtypeStruct((n_layers, batch, size, n_kv, head_dim), dtype),
        "lengths": jax.ShapeDtypeStruct((batch,), jnp.int32),
        "window": window,
    }


def update_layer_kv(layer_k: jax.Array, layer_v: jax.Array, lengths: jax.Array,
                    new_k: jax.Array, new_v: jax.Array, window: int = 0):
    """Write new_k/new_v (B, T, n_kv, hd) at per-slot offsets `lengths`.

    Returns updated (k, v). For windowed caches the write index wraps (ring
    buffer). T is usually 1 (decode) but prefill-into-cache works too.
    """
    B, T = new_k.shape[0], new_k.shape[1]
    size = layer_k.shape[1]

    def write_one(k_b, v_b, len_b, nk_b, nv_b):
        if window:
            idx = (len_b + jnp.arange(T)) % window
            k_b = k_b.at[idx].set(nk_b)
            v_b = v_b.at[idx].set(nv_b)
        else:
            k_b = jax.lax.dynamic_update_slice(k_b, nk_b, (len_b, 0, 0))
            v_b = jax.lax.dynamic_update_slice(v_b, nv_b, (len_b, 0, 0))
        return k_b, v_b

    k, v = jax.vmap(write_one)(layer_k, layer_v, lengths, new_k, new_v)
    return k, v


def init_ssm_state(n_layers: int, batch: int, n_heads: int, head_dim: int,
                   state: int, conv_width: int, inner: int, dtype=jnp.float32) -> dict:
    return {
        "conv": jnp.zeros((n_layers, batch, conv_width - 1, inner), dtype),
        "ssd": jnp.zeros((n_layers, batch, n_heads, head_dim, state), dtype),
    }


def ssm_state_spec(n_layers: int, batch: int, n_heads: int, head_dim: int,
                   state: int, conv_width: int, inner: int, dtype=jnp.float32) -> dict:
    return {
        "conv": jax.ShapeDtypeStruct((n_layers, batch, conv_width - 1, inner), dtype),
        "ssd": jax.ShapeDtypeStruct((n_layers, batch, n_heads, head_dim, state), dtype),
    }
