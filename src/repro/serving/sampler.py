"""Token samplers: greedy / temperature / top-k / top-p."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0      # 0 = greedy
    top_k: int = 0
    top_p: float = 1.0


def sample(logits: jax.Array, key: jax.Array, cfg: SamplerConfig) -> jax.Array:
    """logits: (B, V) -> (B,) int32 tokens."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / cfg.temperature
    if cfg.top_k:
        # clamp: top_k >= vocab means no truncation (and sorted[:, -k] would
        # index out of bounds)
        k = min(cfg.top_k, logits.shape[-1])
        kth = jnp.sort(logits, axis=-1)[:, -k][:, None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if cfg.top_p < 1.0:
        # Rank-based nucleus: keep exactly the first k sorted tokens, where
        # k is the smallest count whose cumulative mass reaches top_p. A
        # value-based cutoff (`logits < cutoff`) keeps EVERY token tied with
        # the boundary logit, silently widening the nucleus — with a
        # many-way tie that degenerates toward full-vocab sampling. Ranks
        # come from inverting the descending sort permutation; `flip` of the
        # ascending argsort (not argsort of the negation) keeps masked -inf
        # entries ranked last.
        order = jnp.flip(jnp.argsort(logits, axis=-1), axis=-1)
        ranks = jnp.argsort(order, axis=-1)
        sorted_logits = jnp.take_along_axis(logits, order, axis=-1)
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        k = jnp.sum(cum < cfg.top_p, axis=-1) + 1
        logits = jnp.where(ranks < k[:, None], logits, -jnp.inf)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def token_logprob(logits: jax.Array, tokens: jax.Array) -> jax.Array:
    """log p(token) under logits. logits: (B,V), tokens: (B,) -> (B,)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.take_along_axis(logp, tokens[:, None].astype(jnp.int32),
                               axis=-1)[:, 0]
