"""Trace-driven load generator for the serving front-end.

Two trace sources, one replay path:

  * `synthesize_trace(...)` — seeded Poisson process: exponential
    inter-arrival gaps at `rate_rps`, per-request prompt length / decode
    budget / SLA tier drawn from the same seeded stream, so a (seed, rate)
    pair names ONE reproducible workload.
  * `load_trace(path)` / `save_trace(path, trace)` — JSONL, one
    `{"arrival_s": ..., "prompt_len": ..., "max_new": ..., "tier": ...}`
    object per line, for replaying captured or hand-built workloads.

`replay(frontend, trace, ...)` submits each entry at its arrival offset
(real `asyncio.sleep` between arrivals — the engine keeps stepping
concurrently on the driver coroutine) with a tier-derived deadline, awaits
every handle without raising, and folds the outcomes into a `LoadReport`:
goodput (tokens/s from requests that finished within their SLA), total
throughput, SLA attainment per tier, and arrival-relative TTFT/latency
percentiles. `sweep(...)` replays the same seeded workload shape at several
offered loads — the goodput-vs-offered-load and SLA-attainment curves the
serving benchmark writes to BENCH_serving.json.

Token content is bit-reproducible (prompts derive from (seed, index)
alone); timing metrics are wall-clock and therefore host-dependent.
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
import random
import time
from typing import Dict, List, Optional, Sequence

from repro.serving.frontend import CompletionRequest, EngineFrontend
from repro.serving.requests import SLA_TIERS

# default tier mix for synthetic traces (weights, not probabilities)
DEFAULT_TIER_MIX = {"interactive": 0.25, "standard": 0.5, "batch": 0.25}


@dataclasses.dataclass
class TraceEntry:
    """One request in a workload trace: WHEN it arrives (seconds from trace
    start), its shape, and which SLA tier it bought."""
    arrival_s: float
    prompt_len: int
    max_new: int
    tier: str = "standard"


def synthesize_trace(rate_rps: float, n: int, seed: int = 0,
                     prompt_len: tuple = (4, 24),
                     max_new: tuple = (8, 48),
                     tier_mix: Optional[Dict[str, float]] = None
                     ) -> List[TraceEntry]:
    """Seeded Poisson workload: `n` requests at offered load `rate_rps`."""
    rng = random.Random(seed)
    mix = tier_mix or DEFAULT_TIER_MIX
    tiers = list(mix)
    weights = [mix[t] for t in tiers]
    t = 0.0
    out = []
    for _ in range(n):
        t += rng.expovariate(rate_rps)
        out.append(TraceEntry(
            arrival_s=t,
            prompt_len=rng.randint(*prompt_len),
            max_new=rng.randint(*max_new),
            tier=rng.choices(tiers, weights=weights)[0]))
    return out


def save_trace(path: str, trace: Sequence[TraceEntry]) -> None:
    with open(path, "w") as f:
        for e in trace:
            f.write(json.dumps(dataclasses.asdict(e)) + "\n")


def load_trace(path: str) -> List[TraceEntry]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(TraceEntry(**json.loads(line)))
    return out


def trace_prompt(seed: int, index: int, prompt_len: int,
                 vocab_size: int) -> List[int]:
    """The bit-reproducible prompt for trace entry `index`: a function of
    (seed, index) only, so isolated-vs-multiplexed comparisons can rebuild
    the exact token stream."""
    rng = random.Random(seed * 1000003 + index)
    return [rng.randrange(1, max(vocab_size - 1, 2))
            for _ in range(max(prompt_len, 1))]


@dataclasses.dataclass
class LoadReport:
    """Outcome of one trace replay at one offered load."""
    offered_rps: float
    n_requests: int
    elapsed_s: float
    completed: int = 0
    shed: int = 0
    deadline_cancelled: int = 0
    failed: int = 0
    good_tokens: int = 0          # tokens from requests that met their SLA
    total_tokens: int = 0
    sla_met: int = 0
    sla_eligible: int = 0         # completed-or-cancelled, i.e. not shed/failed
    per_tier_met: Dict[str, int] = dataclasses.field(default_factory=dict)
    per_tier_total: Dict[str, int] = dataclasses.field(default_factory=dict)
    ttft_p50_s: float = 0.0
    ttft_p95_s: float = 0.0
    latency_p50_s: float = 0.0
    latency_p95_s: float = 0.0

    @property
    def goodput_tps(self) -> float:
        """Tokens/s from requests that finished within their SLA — the
        paper-facing serving metric (shed/deadline-blown work produces
        tokens but no goodput)."""
        return self.good_tokens / max(self.elapsed_s, 1e-9)

    @property
    def throughput_tps(self) -> float:
        return self.total_tokens / max(self.elapsed_s, 1e-9)

    @property
    def sla_attainment(self) -> float:
        """Fraction of non-shed requests that met their tier's deadline
        (batch tier: completing at all meets it)."""
        if self.sla_eligible <= 0:
            return 0.0
        return self.sla_met / self.sla_eligible

    def summary(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["goodput_tps"] = self.goodput_tps
        d["throughput_tps"] = self.throughput_tps
        d["sla_attainment"] = self.sla_attainment
        return d


async def replay(frontend: EngineFrontend, trace: Sequence[TraceEntry],
                 seed: int = 0, time_scale: float = 1.0,
                 tier_budget_s: float = 1.0,
                 offered_rps: float = 0.0) -> LoadReport:
    """Replay `trace` against `frontend` in (scaled) real time.

    `time_scale` compresses arrival gaps (0.5 = twice the offered load of
    the recorded trace); `tier_budget_s` converts the relative SLA tier
    budgets (requests.SLA_TIERS) into seconds of end-to-end deadline,
    measured from arrival. Requests are submitted sheddable — backpressure
    sheds exactly as the MultiListQueue policy dictates."""
    vocab = frontend.engine.cfg.vocab_size
    t0 = time.perf_counter()
    handles = []
    for i, e in enumerate(trace):
        target = t0 + e.arrival_s * time_scale
        delay = target - time.perf_counter()
        if delay > 0:
            await asyncio.sleep(delay)
        now = time.perf_counter()
        budget = SLA_TIERS.get(e.tier)
        deadline = None if budget is None else now + budget * tier_budget_s
        req = CompletionRequest(
            prompt=trace_prompt(seed, i, e.prompt_len, vocab),
            max_tokens=e.max_new, tier=e.tier,
            arrival_time_s=now, deadline_s=deadline)
        handles.append((e, frontend.submit(req)))
    for _, h in handles:
        await h.wait()
    report = LoadReport(offered_rps=offered_rps, n_requests=len(trace),
                        elapsed_s=time.perf_counter() - t0)
    for e, h in handles:
        report.per_tier_total[e.tier] = report.per_tier_total.get(e.tier,
                                                                  0) + 1
        n_toks = len(h.tokens)
        report.total_tokens += n_toks
        if h.state == "shed":
            report.shed += 1
            continue
        if h.state == "failed":
            report.failed += 1
            continue
        report.sla_eligible += 1
        if h.finish_reason == "deadline":
            report.deadline_cancelled += 1
            continue                      # blew its budget: no goodput
        report.completed += 1
        report.sla_met += 1
        report.good_tokens += n_toks
        report.per_tier_met[e.tier] = report.per_tier_met.get(e.tier, 0) + 1
    mon = frontend.monitor
    if mon is not None:
        report.ttft_p50_s = mon.ttft_percentile(50)
        report.ttft_p95_s = mon.ttft_percentile(95)
        report.latency_p50_s = mon.latency_percentile(50)
        report.latency_p95_s = mon.latency_percentile(95)
    return report


def replay_sync(frontend: EngineFrontend, trace: Sequence[TraceEntry],
                **kw) -> LoadReport:
    """Sync wrapper: drive the replay to completion on a fresh loop."""
    return asyncio.run(replay(frontend, trace, **kw))


def sweep(frontend_factory, base_rate_rps: float, n_requests: int,
          load_multipliers: Sequence[float] = (1.0, 2.0, 4.0),
          seed: int = 0, tier_budget_s: float = 1.0,
          prompt_len: tuple = (4, 24), max_new: tuple = (8, 48)
          ) -> List[LoadReport]:
    """Replay the SAME seeded workload shape at several offered loads (a
    fresh front-end per point, from `frontend_factory()`), yielding the
    goodput-vs-offered-load / SLA-attainment curves."""
    reports = []
    for m in load_multipliers:
        rate = base_rate_rps * m
        trace = synthesize_trace(rate, n_requests, seed=seed,
                                 prompt_len=prompt_len, max_new=max_new)
        fe = frontend_factory()
        reports.append(replay_sync(fe, trace, seed=seed,
                                   tier_budget_s=tier_budget_s,
                                   offered_rps=rate))
    return reports
