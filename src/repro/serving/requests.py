"""Request/response types and SLA specs for the serving layer."""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional

_ids = itertools.count()


class BoundedRecord(dict):
    """Insertion-ordered mapping with a hard size cap: inserting a NEW key
    past `cap` evicts the oldest entries first (bounded-deque semantics over
    a dict API). This is the single bounding convention for per-request
    telemetry — the engine's `ttft`/`truncations`, the RuntimeMonitor's
    TTFT/latency windows, and the front-end's per-request records all use it,
    so none of them can grow without bound in a long-running fleet.

    `append(value)` supports window-style usage (samples keyed by an
    internal monotone counter); `percentile(q)` reads the kept window.
    """

    def __init__(self, cap: int = 4096):
        super().__init__()
        self.cap = max(int(cap), 1)
        self._seq = 0

    def __setitem__(self, key, value):
        if key not in self:
            while len(self) >= self.cap:
                super().pop(next(iter(self)))
        super().__setitem__(key, value)

    def append(self, value) -> None:
        """Record a sample in arrival order (window usage)."""
        self[("seq", self._seq)] = value
        self._seq += 1

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile over the kept values (0 when empty)."""
        vals = sorted(float(v) for v in self.values())
        if not vals:
            return 0.0
        idx = int(round(q / 100.0 * (len(vals) - 1)))
        return vals[min(max(idx, 0), len(vals) - 1)]


@dataclasses.dataclass(frozen=True)
class SLA:
    """Multi-criteria service-level agreement (paper §IV-A-1).

    `metric_order` ranks the soft objectives for the lexicographic
    formulation; `max_latency_s` is the hard constraint (Eq. 2 RHS uses the
    cloud-only latency when None).
    """
    max_latency_s: Optional[float] = None
    metric_order: tuple = ("error", "throughput", "latency",
                           "server_cost", "edge_cost")


# SLA tiers for the serving front-end / load generator: a tier names a hard
# latency budget measured FROM ARRIVAL (queue wait included) and an engine
# priority (higher = evicted last, admitted first). Budgets are relative
# units — the load generator scales them by the measured service time of the
# workload it replays (`sla_for_tier(tier, scale=...)`).
SLA_TIERS: Dict[str, Optional[float]] = {
    "interactive": 1.0,
    "standard": 4.0,
    "batch": None,                 # no hard deadline
}
TIER_PRIORITY: Dict[str, int] = {"interactive": 2, "standard": 1, "batch": 0}


def sla_for_tier(tier: str, scale: float = 1.0) -> SLA:
    """The SLA a tier implies, with its latency budget scaled by `scale`
    (seconds per budget unit — workload-calibrated by the load generator)."""
    budget = SLA_TIERS.get(tier)
    if budget is None:
        return SLA()
    return SLA(max_latency_s=budget * scale)


@dataclasses.dataclass
class Request:
    query: str
    req_id: int = dataclasses.field(default_factory=lambda: next(_ids))
    arrival_s: float = 0.0
    category: str = "generic"
    sla: SLA = dataclasses.field(default_factory=SLA)
    max_new_tokens: int = 512
    # wall-clock arrival stamp (time.perf_counter): when set, latency and
    # queue-wait accounting measure from ARRIVAL — queue wait included — not
    # from when a handler picked the request up. None preserves the
    # handler-relative accounting of callers that never queue.
    arrival_time_s: Optional[float] = None
    # SLA tier name (SLA_TIERS): maps to an engine priority and, through the
    # load generator, to an arrival-relative deadline
    tier: str = "standard"


@dataclasses.dataclass
class SketchTask:
    """An expansion task queued for the edge fleet (paper's job queue Q)."""
    req_id: int
    query: str
    sketch: str
    sentences: List[str]
    expected_length: int          # l_i — LLM-predicted response length
    sketch_tokens: int            # |r_i|
    created_s: float = 0.0


@dataclasses.dataclass
class Response:
    req_id: int
    text: str
    mode: str                     # "cloud_full" | "progressive"
    cloud_tokens: int = 0
    edge_tokens: int = 0
    latency_s: float = 0.0
    queue_wait_s: float = 0.0
    network_s: float = 0.0
    confidence: float = 0.0
    model_used: str = ""
    quality: Optional[float] = None
    # fault/degradation telemetry (PICE fault model, docs/serving.md):
    # `degraded` names the rung the request landed on — "" (none),
    # "ensemble_partial" (some members faulted, quorum-1 select),
    # "sketch_groups" (a group fell back to its sketch sentences),
    # "cloud_full_fallback" (edge path abandoned, cloud re-answered), or
    # "sketch_passthrough" (deadline blown: the sketch IS the answer)
    degraded: str = ""
    retries: int = 0              # network transfer retry attempts
    hedges: int = 0               # extra ensemble members launched
    deadline_s: float = 0.0       # per-request budget (0 = none)
    faults: Dict[str, int] = dataclasses.field(default_factory=dict)
