"""Request/response types and SLA specs for the serving layer."""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional

_ids = itertools.count()


@dataclasses.dataclass(frozen=True)
class SLA:
    """Multi-criteria service-level agreement (paper §IV-A-1).

    `metric_order` ranks the soft objectives for the lexicographic
    formulation; `max_latency_s` is the hard constraint (Eq. 2 RHS uses the
    cloud-only latency when None).
    """
    max_latency_s: Optional[float] = None
    metric_order: tuple = ("error", "throughput", "latency",
                           "server_cost", "edge_cost")


@dataclasses.dataclass
class Request:
    query: str
    req_id: int = dataclasses.field(default_factory=lambda: next(_ids))
    arrival_s: float = 0.0
    category: str = "generic"
    sla: SLA = dataclasses.field(default_factory=SLA)
    max_new_tokens: int = 512


@dataclasses.dataclass
class SketchTask:
    """An expansion task queued for the edge fleet (paper's job queue Q)."""
    req_id: int
    query: str
    sketch: str
    sentences: List[str]
    expected_length: int          # l_i — LLM-predicted response length
    sketch_tokens: int            # |r_i|
    created_s: float = 0.0


@dataclasses.dataclass
class Response:
    req_id: int
    text: str
    mode: str                     # "cloud_full" | "progressive"
    cloud_tokens: int = 0
    edge_tokens: int = 0
    latency_s: float = 0.0
    queue_wait_s: float = 0.0
    network_s: float = 0.0
    confidence: float = 0.0
    model_used: str = ""
    quality: Optional[float] = None
    # fault/degradation telemetry (PICE fault model, docs/serving.md):
    # `degraded` names the rung the request landed on — "" (none),
    # "ensemble_partial" (some members faulted, quorum-1 select),
    # "sketch_groups" (a group fell back to its sketch sentences),
    # "cloud_full_fallback" (edge path abandoned, cloud re-answered), or
    # "sketch_passthrough" (deadline blown: the sketch IS the answer)
    degraded: str = ""
    retries: int = 0              # network transfer retry attempts
    hedges: int = 0               # extra ensemble members launched
    deadline_s: float = 0.0       # per-request budget (0 = none)
    faults: Dict[str, int] = dataclasses.field(default_factory=dict)
