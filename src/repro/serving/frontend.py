"""Async multiplexed serving front-end: one shared engine, many streams.

`EngineFrontend` puts a request-handle API on top of ONE `InferenceEngine`
(or one per simulated device tier — the PICE pipeline builds a front-end for
the cloud engine and each edge engine), so every PICE role — cloud sketch,
full cloud answers, N parallel edge expansions, extra ensemble members —
contends for the same slots, pages, priority eviction, and continuous batch
instead of owning an engine:

  submit(CompletionRequest) -> RequestHandle     (stream / await result)
  generate_async / generate_fanout_async         (pipeline facades)
  generate / generate_fanout                     (sync facades, same API as
                                                  the engine they wrap)

Concurrency model — single-threaded asyncio, no threads touch JAX:

  * exactly ONE driver coroutine per front-end calls `engine.step()`; it is
    spawned lazily on the running loop and exits when the engine drains
    (a later submit restarts it). All other coroutines only enqueue work
    and await handles.
  * each driver iteration: sweep deadlines -> admit (engine.try_admit, the
    same admission path the synchronous `_run` loop uses) -> step ->
    publish new tokens + settle finished slots -> collect preempted work
    (engine.drain_resumes) -> yield to the loop.
  * the ONLY blocking calls in the async paths are the engine's own step /
    prefill entry points; `time.sleep` and bare device syncs are forbidden
    here and enforced by the RA6xx static pass (repro.analysis).

Backpressure rides the paper's own shedding policy: fresh external
submissions wait in a `MultiListQueue` (core/dispatch.py) and a full queue
sheds the longest-expected work; pipeline-internal work (sketch/expansion
facades) and eviction resumes are not sheddable — the PICE layer already
applied its shedding policy before handing them down.

Per-request deadlines ride the PR-9 cancel machinery: an overdue request is
cancelled through `engine.cancel` (pending-decode commits pruned, survivor
streams bit-identical) and its handle finishes with reason "deadline" and
whatever tokens it produced. TTFT/TPOT/latency are recorded per request
FROM ARRIVAL — queue wait included.
"""
from __future__ import annotations

import asyncio
import dataclasses
import itertools
import time
from typing import AsyncIterator, Dict, List, Optional, Tuple

from repro.core.dispatch import MultiListQueue
from repro.serving.engine import EngineRequest, InferenceEngine
from repro.serving.requests import TIER_PRIORITY

# PICE role -> engine priority (eviction order, admission order): the cloud
# sketch is the critical path of every progressive request and full cloud
# answers are the degradation ladder's safety net, so both outrank edge
# expansions; the primary member's expansion outranks opportunistic extra
# ensemble members (see engine._evict_victim).
ROLE_PRIORITY = {
    "sketch": 2,
    "cloud_full": 2,
    "expansion_primary": 1,
    "expansion_extra": 0,
    "generic": 0,
}

_req_ids = itertools.count(1)

# terminal handle states, keyed by finish reason
_REASON_STATE = {
    "stop": "done", "length": "done",
    "cancelled": "cancelled", "deadline": "cancelled",
    "shed": "shed", "error": "failed",
}


@dataclasses.dataclass
class CompletionRequest:
    """OpenAI-style completion request against the front-end, token-level
    (the repo's tokenizer lives a layer above). `deadline_s` is an absolute
    `time.perf_counter` stamp; `arrival_time_s` defaults to submit time and
    anchors TTFT/latency accounting (queue wait included)."""
    prompt: List[int]
    max_tokens: int = 64
    priority: Optional[int] = None       # None: derived from role/tier
    role: str = "generic"                # ROLE_PRIORITY key
    tier: str = "batch"                  # SLA tier name (requests.SLA_TIERS)
    arrival_time_s: Optional[float] = None
    deadline_s: Optional[float] = None
    req_id: int = dataclasses.field(default_factory=lambda: next(_req_ids))


@dataclasses.dataclass
class TokenDelta:
    """One streamed token (or the terminal marker when `finish_reason` is
    set — its `token` is -1 and carries no content)."""
    req_id: int
    index: int
    token: int
    logprob: float
    finish_reason: str = ""   # "" mid-stream; "stop"|"length"|"cancelled"|
    #                           "deadline"|"shed"|"error" on the final delta


class RequestHandle:
    """Live view of one submitted request: accumulated tokens, stream of
    `TokenDelta`s, terminal state, and arrival-relative timing."""

    def __init__(self, req: CompletionRequest, frontend: "EngineFrontend"):
        self.req = req
        self.state = "queued"   # queued|running|evicted|done|cancelled|shed|failed
        self.tokens: List[int] = []
        self.logprobs: List[float] = []
        self.finish_reason = ""
        self.error: Optional[BaseException] = None
        self.first_token_s: Optional[float] = None
        self.finish_s: Optional[float] = None
        self._frontend = frontend
        self._queued = None               # the _Queued entry while waiting
        self._deltas: asyncio.Queue = asyncio.Queue()
        self._done = asyncio.Event()

    # -- arrival-relative timing (queue wait included) -------------------
    @property
    def done(self) -> bool:
        return self._done.is_set()

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.req.arrival_time_s

    @property
    def latency_s(self) -> Optional[float]:
        if self.finish_s is None:
            return None
        return self.finish_s - self.req.arrival_time_s

    @property
    def tpot_s(self) -> Optional[float]:
        """Mean time per output token after the first."""
        if (self.finish_s is None or self.first_token_s is None
                or len(self.tokens) < 2):
            return None
        return (self.finish_s - self.first_token_s) / (len(self.tokens) - 1)

    def cancel(self) -> bool:
        return self._frontend.cancel(self)

    async def stream(self) -> AsyncIterator[TokenDelta]:
        """Yield `TokenDelta`s as the engine commits them; the final delta
        carries `finish_reason` and ends the iterator."""
        self._frontend._ensure_driver()
        while True:
            d = await self._deltas.get()
            yield d
            if d.finish_reason:
                return

    async def wait(self) -> "RequestHandle":
        """Await completion WITHOUT raising — callers inspect `state`,
        `finish_reason`, and `error` (the load generator's path, where a
        failed request is a data point, not an exception)."""
        self._frontend._ensure_driver()
        await self._done.wait()
        return self

    async def result(self) -> Tuple[List[int], List[float]]:
        """Await completion; returns (tokens, logprobs) — partial when the
        request was cancelled/deadlined, raising the failure (EngineCrash,
        MemoryError) when it errored, so facade callers see exactly the
        exceptions `InferenceEngine.generate` raises."""
        self._frontend._ensure_driver()
        await self._done.wait()
        if self.error is not None:
            raise self.error
        return list(self.tokens), list(self.logprobs)


class _Queued:
    """A waiting-room entry: the handle plus the `EngineRequest` admission
    will hand to `engine.try_admit`. `expected_length` is what the
    MultiListQueue buckets/sheds on."""

    def __init__(self, handle: RequestHandle, work: EngineRequest):
        self.handle = handle
        self.work = work
        self.expected_length = handle.req.max_tokens


class EngineFrontend:
    """One multiplexed `InferenceEngine` behind an async streaming API.

    Engine attributes (telemetry, fault hooks) forward transparently:
    `RuntimeMonitor.observe_engines`, `FaultInjector.attach`, and the chaos
    bench address a front-end exactly like the engine it wraps — in
    particular a `FaultPlan`'s `step_hook`/`swap_fault_hook` assignments
    land on the engine, so chaos plans keep working unchanged."""

    def __init__(self, engine: InferenceEngine, monitor=None,
                 queue_max: int = 64,
                 queue_boundaries=(64, 128, 256, 512, 1024)):
        self.engine = engine
        self.monitor = monitor
        self.queue = MultiListQueue(boundaries=queue_boundaries,
                                    max_size=queue_max, monitor=monitor,
                                    on_shed_task=self._on_shed)
        self._lane: List[_Queued] = []          # non-sheddable submissions
        self._resumes: List[EngineRequest] = []  # preempted, awaiting re-admit
        self._live: Dict[int, RequestHandle] = {}
        self._slot_of: Dict[int, int] = {}
        self._driver: Optional[asyncio.Task] = None
        # request-outcome telemetry
        self.completed = 0
        self.shed = 0
        self.cancelled = 0
        self.failed = 0
        self.admit_failures = 0
        self.dropped_resumes = 0

    # -- engine forwarding ------------------------------------------------
    @property
    def step_hook(self):
        return self.engine.step_hook

    @step_hook.setter
    def step_hook(self, fn):
        self.engine.step_hook = fn

    @property
    def swap_fault_hook(self):
        return self.engine.swap_fault_hook

    @swap_fault_hook.setter
    def swap_fault_hook(self, fn):
        self.engine.swap_fault_hook = fn

    def __getattr__(self, item):
        # telemetry/config reads (name, ttft, memory_stats, consume_window,
        # page_size, eos_id, ...) resolve on the wrapped engine
        return getattr(self.engine, item)

    def abort_all(self) -> int:
        """Scrub the engine AND settle every live handle as cancelled (the
        crash-recovery contract `PICEPipeline` relies on)."""
        n = self.engine.abort_all()
        for rid, h in list(self._live.items()):
            self._detach(rid)
            self._finish(h, "cancelled")
        for r in list(self._resumes):
            if r.swap is not None:
                self.engine.alloc.drop_hosted(r.req_id)
        self._resumes.clear()
        return n

    # -- submission -------------------------------------------------------
    def submit(self, req: CompletionRequest,
               sheddable: bool = True) -> RequestHandle:
        """Enqueue a request; returns immediately with its handle. With
        `sheddable` (external ingress — the load generator path) the request
        waits in the MultiListQueue and may be shed under backpressure;
        pipeline-internal facades submit non-sheddable."""
        if req.priority is None:
            req.priority = max(ROLE_PRIORITY.get(req.role, 0),
                               TIER_PRIORITY.get(req.tier, 0))
        work = EngineRequest(req_id=req.req_id, prompt=list(req.prompt),
                             max_new=req.max_tokens, carry_tokens=[],
                             carry_lps=[], priority=req.priority)
        return self._enqueue(req, work, sheddable)

    def stream(self, req: CompletionRequest,
               sheddable: bool = True) -> AsyncIterator[TokenDelta]:
        """submit() and stream the deltas (`submit(request) ->
        AsyncIterator[token_delta]` in one call)."""
        return self.submit(req, sheddable=sheddable).stream()

    def _enqueue(self, req: CompletionRequest, work: EngineRequest,
                 sheddable: bool) -> RequestHandle:
        if req.arrival_time_s is None:   # fanout forks enqueue directly
            req.arrival_time_s = time.perf_counter()
        h = RequestHandle(req, self)
        q = _Queued(h, work)
        h._queued = q
        if sheddable:
            if not self.queue.push(q):
                self._finish(h, "shed")
                return h
        else:
            self._lane.append(q)
        self._ensure_driver()
        return h

    def _on_shed(self, q: "_Queued") -> None:
        """MultiListQueue displaced a queued request to admit a shorter one."""
        self._finish(q.handle, "shed")

    # -- cancellation / deadlines ----------------------------------------
    def cancel(self, handle: RequestHandle, reason: str = "cancelled") -> bool:
        """Cancel a request in any live state: still queued, running,
        evicted-and-waiting, or demoted to the host tier. The handle
        finishes with `reason` and every token committed so far."""
        if handle.state in ("done", "cancelled", "shed", "failed"):
            return False
        rid = handle.req.req_id
        if reason == "deadline":
            self.engine.deadline_cancels += 1
        if handle.state == "queued":
            q = handle._queued
            if q in self._lane:
                self._lane.remove(q)
            else:
                self.queue.remove(q)
            self._finish(handle, reason)
            return True
        # running / evicted: engine.cancel prunes the slot, the engine's
        # resume queue, any pending-decode commit, and host-tier snapshots
        self.engine.cancel(rid)
        slot = self._slot_of.pop(rid, None)
        if slot is not None:
            s = self.engine.slots[slot]
            self._emit_new(handle, s.tokens, s.logprobs)
            s.req_id = -1
        self._drop_resume(rid, handle)
        self._live.pop(rid, None)
        self.engine._inflight.discard(rid)
        self._finish(handle, reason)
        return True

    def _sweep_deadlines(self, now: float) -> None:
        waiting = list(self._lane) + [t for lst in self.queue.lists
                                      for t in lst]
        for q in waiting:
            dl = q.handle.req.deadline_s
            if dl is not None and now > dl:
                self.cancel(q.handle, reason="deadline")
        for h in list(self._live.values()):
            dl = h.req.deadline_s
            if dl is not None and now > dl:
                self.cancel(h, reason="deadline")

    def _drop_resume(self, rid: int,
                     handle: Optional[RequestHandle] = None) -> None:
        r = next((x for x in self._resumes if x.req_id == rid), None)
        if r is None:
            return
        self._resumes.remove(r)
        if r.swap is not None:
            self.engine.alloc.drop_hosted(rid)
        if handle is not None:
            # a token committed at the pre-eviction harvest may not have
            # been published yet: the carried prefix is the source of truth
            self._emit_new(handle, r.carry_tokens, r.carry_lps)

    # -- driver -----------------------------------------------------------
    def _ensure_driver(self) -> None:
        try:
            loop = asyncio.get_running_loop()
        except RuntimeError:  # repro-analysis: disable=RA501 reason=no running loop is the sync-facade path, not a fault; the facade drives via asyncio.run
            return
        if self._driver is None or self._driver.done():
            self._driver = loop.create_task(self._drive())

    def _has_work(self) -> bool:
        return bool(self._slot_of or self._resumes or self._lane
                    or len(self.queue))

    async def _drive(self) -> None:
        """THE step loop: the only coroutine that touches the engine's
        device state. Exits when the front-end drains (a later submit
        re-spawns it)."""
        engine = self.engine
        try:
            while True:
                self._sweep_deadlines(time.perf_counter())
                try:
                    self._admit()
                    if any(s.active for s in engine.slots):
                        engine.step()
                except Exception as exc:   # EngineCrash, or any step fault
                    self.on_crash(exc)
                self._publish_and_settle()
                for r in engine.drain_resumes():
                    if r.req_id in self._live:
                        self._live[r.req_id].state = "evicted"
                        self._resumes.append(r)
                    else:
                        # not ours (cancelled in the same step): drop
                        if r.swap is not None:
                            engine.alloc.drop_hosted(r.req_id)
                        self.dropped_resumes += 1
                if not self._has_work():
                    return
                await asyncio.sleep(0)
        finally:
            self._driver = None

    def on_crash(self, exc: BaseException) -> None:
        """An injected (or real) engine crash mid-step: scrub the engine,
        fail every live handle with the crash so awaiting facade callers
        see the same EngineCrash `engine.generate` would raise, and keep
        serving the still-queued work on the scrubbed engine."""
        self.engine.abort_all()
        for rid, h in list(self._live.items()):
            self._detach(rid)
            self._finish(h, "error", error=exc)
        for r in list(self._resumes):
            if r.swap is not None:
                self.engine.alloc.drop_hosted(r.req_id)
        self._resumes.clear()

    def _detach(self, rid: int) -> None:
        slot = self._slot_of.pop(rid, None)
        if slot is not None:
            self.engine.slots[slot].req_id = -1
        self._live.pop(rid, None)
        self.engine._inflight.discard(rid)

    # -- admission --------------------------------------------------------
    def _admission_key(self, q: "_Queued"):
        # higher priority first; FIFO (req_id order) within a priority
        return (-q.work.priority, q.work.req_id)

    def _next_candidate(self) -> Optional["_Queued"]:
        lane = min(self._lane, key=self._admission_key) if self._lane else None
        shed = self.queue.peek_best(self._admission_key)
        if lane is None or shed is None:
            return lane or shed
        return lane if self._admission_key(lane) <= \
            self._admission_key(shed) else shed

    def _admit(self) -> None:
        """Admit work while slots are free: eviction resumes first (FIFO,
        head-of-line blocking — exactly `_run_inner`'s order, so preempted
        work cannot be starved by fresh arrivals), then queued requests in
        (priority, arrival) order through the same `try_admit` path."""
        engine = self.engine
        while engine.free_slots():
            if self._resumes:
                r = self._resumes[0]
                h = self._live.get(r.req_id)
                if h is None:
                    self._resumes.pop(0)
                    self.dropped_resumes += 1
                    continue
                try:
                    slot = engine.try_admit(r)
                except MemoryError as exc:
                    self.admit_failures += 1
                    self._resumes.pop(0)
                    self._detach(r.req_id)
                    self._finish(h, "error", error=exc)
                    continue
                if slot is None:
                    return               # head-of-line waits for pages
                self._resumes.pop(0)
                self._slot_of[r.req_id] = slot
                h.state = "running"
                continue
            q = self._next_candidate()
            if q is None:
                return
            try:
                slot = engine.try_admit(q.work)
            except MemoryError as exc:
                self.admit_failures += 1
                self._remove_queued(q)
                self._finish(q.handle, "error", error=exc)
                continue
            if slot is None:
                return
            self._remove_queued(q)
            rid = q.work.req_id
            self._live[rid] = q.handle
            self._slot_of[rid] = slot
            engine._inflight.add(rid)
            q.handle.state = "running"

    def _remove_queued(self, q: "_Queued") -> None:
        if q in self._lane:
            self._lane.remove(q)
        else:
            self.queue.remove(q)

    # -- publish / settle -------------------------------------------------
    def _emit_new(self, h: RequestHandle, tokens: List[int],
                  lps: List[float]) -> None:
        for i in range(len(h.tokens), len(tokens)):
            self._emit(h, tokens[i], lps[i])

    def _emit(self, h: RequestHandle, tok: int, lp: float) -> None:
        idx = len(h.tokens)
        h.tokens.append(tok)
        h.logprobs.append(lp)
        if h.first_token_s is None:
            h.first_token_s = time.perf_counter()
            if self.monitor is not None:
                self.monitor.record_ttft(h.ttft_s)
        h._deltas.put_nowait(TokenDelta(h.req.req_id, idx, tok, lp))

    def _publish_and_settle(self) -> None:
        """Publish newly committed tokens as deltas and settle released
        slots. Runs right after step() in the same iteration, before any
        other coroutine can run, so a slot the engine released cannot be
        reused (admission and prefix parking happen at later yield points)
        before its final tokens are published."""
        engine = self.engine
        for rid, slot in list(self._slot_of.items()):
            h = self._live[rid]
            s = engine.slots[slot]
            self._emit_new(h, s.tokens, s.logprobs)
            if s.active:
                continue
            del self._slot_of[rid]
            if s.evicted:
                s.evicted = False
                h.state = "evicted"   # its resume is drained right after
                continue
            s.req_id = -1
            del self._live[rid]
            engine._inflight.discard(rid)
            if h.tokens and h.tokens[-1] == engine.eos_id:
                reason = "stop"
            elif s.generated >= s.max_new or s.ctx_len >= engine.max_len:
                reason = "length"
            else:
                # cancelled out from under us (e.g. an injected fault's
                # cancel mode): partial tokens, like engine._run returns
                reason = "cancelled"
            self._finish(h, reason)

    def _finish(self, h: RequestHandle, reason: str,
                error: Optional[BaseException] = None) -> None:
        h.state = _REASON_STATE[reason]
        h.finish_reason = reason
        h.error = error
        h.finish_s = time.perf_counter()
        if reason in ("stop", "length"):
            self.completed += 1
        elif reason == "shed":
            self.shed += 1
        elif reason == "error":
            self.failed += 1
        else:
            self.cancelled += 1
        if self.monitor is not None and reason not in ("shed", "error"):
            self.monitor.record_latency(h.latency_s)
        h._deltas.put_nowait(TokenDelta(h.req.req_id, len(h.tokens), -1, 0.0,
                                        finish_reason=reason))
        h._done.set()

    # -- pipeline facades -------------------------------------------------
    async def generate_async(self, prompts: List[List[int]],
                             max_new: int = 128,
                             priorities: Optional[List[int]] = None,
                             deadline_s: Optional[float] = None,
                             role: str = "generic"
                             ) -> List[Tuple[List[int], List[float]]]:
        """`InferenceEngine.generate` semantics over the multiplexed
        front-end: same results/ordering, same MemoryError/EngineCrash
        behavior, deadline-cancelled requests return partials."""
        if priorities is None:
            priorities = [None] * len(prompts)   # derive from role/tier
        assert len(priorities) == len(prompts), \
            "priorities must match prompts one-to-one"
        handles = [self.submit(
            CompletionRequest(prompt=list(p), max_tokens=max_new,
                              priority=pr, role=role, deadline_s=deadline_s),
            sheddable=False)
            for p, pr in zip(prompts, priorities)]
        return await self._gather(handles)

    async def _gather(self, handles: List[RequestHandle]
                      ) -> List[Tuple[List[int], List[float]]]:
        """Await a facade call's own handles; on failure cancel THIS call's
        surviving siblings (scoped cleanup — co-tenants multiplexed on the
        same engine are untouched) and re-raise."""
        try:
            return [await h.result() for h in handles]
        except Exception:
            for h in handles:
                self.cancel(h)
            raise

    async def generate_fanout_async(self, prefix: List[int],
                                    suffixes: List[List[int]],
                                    max_new: int = 128, priority: int = 0,
                                    deadline_s: Optional[float] = None,
                                    role: str = "expansion_primary"
                                    ) -> List[Tuple[List[int], List[float]]]:
        """`InferenceEngine.generate_fanout` over the front-end: park the
        shared prefix once, submit each suffix as a COW fork request, and
        await all members. Falls back to independent submissions exactly
        where the engine does."""
        engine = self.engine
        if (engine.kv_backend != "paged" or engine.max_batch < 2
                or not engine.prefix_sharing):
            return await self.generate_async(
                [list(prefix) + list(s) for s in suffixes], max_new=max_new,
                priorities=[priority] * len(suffixes), deadline_s=deadline_s,
                role=role)

        def can_park() -> bool:
            # keep >=1 non-parked slot so concurrent fan-outs cannot park
            # the whole batch and deadlock their own forks
            return bool(engine.free_slots()) and sum(
                1 for s in engine.slots if s.parked) < engine.max_batch - 1

        while not can_park():
            self._ensure_driver()
            await asyncio.sleep(0)
        p_slot = engine.prefill_prefix(prefix)
        handles = []
        try:
            for sfx in suffixes:
                req = CompletionRequest(prompt=list(prefix) + list(sfx),
                                        max_tokens=max_new, priority=priority,
                                        role=role, deadline_s=deadline_s)
                work = EngineRequest(
                    req_id=req.req_id, prompt=list(req.prompt),
                    max_new=max_new, carry_tokens=[], carry_lps=[],
                    share_from=p_slot, suffix=list(sfx), priority=priority)
                handles.append(self._enqueue(req, work, sheddable=False))
            return await self._gather(handles)
        finally:
            engine.release_prefix(p_slot)

    def generate(self, prompts: List[List[int]], max_new: int = 128,
                 priorities: Optional[List[int]] = None,
                 deadline_s: Optional[float] = None
                 ) -> List[Tuple[List[int], List[float]]]:
        """Sync facade (drop-in for `InferenceEngine.generate`): runs the
        event loop to completion. Not callable from inside a running loop —
        use `generate_async` there."""
        return asyncio.run(self.generate_async(
            prompts, max_new=max_new, priorities=priorities,
            deadline_s=deadline_s))

    def generate_fanout(self, prefix: List[int], suffixes: List[List[int]],
                        max_new: int = 128, priority: int = 0,
                        deadline_s: Optional[float] = None
                        ) -> List[Tuple[List[int], List[float]]]:
        """Sync facade for `generate_fanout_async`."""
        return asyncio.run(self.generate_fanout_async(
            prefix, suffixes, max_new=max_new, priority=priority,
            deadline_s=deadline_s))

    async def drain(self) -> None:
        """Wait until every submitted request has settled."""
        while self._has_work():
            self._ensure_driver()
            await asyncio.sleep(0)


def as_frontend(engine, monitor=None, queue_max: int = 64
                ) -> Optional[EngineFrontend]:
    """Wrap a raw `InferenceEngine` in an `EngineFrontend`; `None` and
    already-wrapped engines pass through (the PICE pipeline auto-wraps
    whatever it is constructed with, so callers can hand it raw engines or
    pre-shared front-ends interchangeably)."""
    if engine is None or isinstance(engine, EngineFrontend):
        return engine
    return EngineFrontend(engine, monitor=monitor, queue_max=queue_max)
