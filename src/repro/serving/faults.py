"""Deterministic fault injection for the cloud-edge serving stack.

A seeded `FaultPlan` describes WHAT can go wrong — transfer loss/timeout/
bandwidth collapse/partition windows on the `NetworkModel`, and per-step
straggler delays, mid-decode slot crashes, whole-engine crashes, and page-
pool squeezes on an `InferenceEngine`. A `FaultInjector` turns the plan into
the two hook surfaces the serving layer exposes:

  network.fault_hook(n_bytes)  -> None | (kind, param)   per transfer attempt
  engine.step_hook(engine)                               per engine step
  engine.swap_fault_hook(req_id) -> bool                 per swap promote

Determinism contract: every decision is drawn from one seeded PRNG in event
order (transfer index, per-engine step index), never from wall-clock time —
the same plan against the same request stream injects the same faults, so
chaos tests can assert bit-identical survivor output against a fault-free
run. The one wall-clock effect, the straggler's `time.sleep`, changes WHEN
steps happen, not WHICH faults fire.
"""
from __future__ import annotations

import collections
import dataclasses
import random
import time
from typing import Optional, Tuple


class EngineCrash(RuntimeError):
    """An injected whole-engine failure: the engine raises out of `step()`
    and the caller is expected to `abort_all()` and degrade."""


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded description of a fault scenario (all fields optional)."""
    seed: int = 0
    # -- network transfer faults (per attempt, drawn in transfer order) ----
    transfer_loss_p: float = 0.0          # attempt dropped, pay one RTT
    transfer_timeout_p: float = 0.0       # attempt stalls for timeout_s
    timeout_s: float = 0.25
    bandwidth_collapse_p: float = 0.0     # attempt succeeds at collapsed bw
    bandwidth_collapse_factor: float = 0.1
    # transfer-index windows [(start, end), ...) during which every attempt
    # is lost — a hard network partition
    partition_windows: Tuple[Tuple[int, int], ...] = ()
    # -- engine faults (per-engine step counters) --------------------------
    straggler_steps: Tuple[int, ...] = ()  # steps that stall the engine
    straggler_delay_s: float = 0.0
    crash_steps: Tuple[int, ...] = ()      # steps that crash one active slot
    engine_crash_steps: Tuple[int, ...] = ()   # steps that raise EngineCrash
    pool_squeeze_step: int = -1            # step to steal free pages at
    pool_squeeze_pages: int = 0
    pool_squeeze_duration: int = 4         # steps until pages are returned
    # -- host-tier swap faults ---------------------------------------------
    swap_loss_p: float = 0.0               # promote upload lost -> replay


class FaultInjector:
    """Materializes a `FaultPlan` against network/engine hook points and
    counts every injected event (`events`) for telemetry and assertions."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._rng = random.Random(plan.seed)
        self._transfer_idx = 0
        self._step_idx: dict = {}          # engine name -> steps seen
        self._squeezed: dict = {}          # engine name -> release step
        self.events = collections.Counter()
        self._attached: list = []

    # -- wiring ------------------------------------------------------------
    def attach(self, network=None, engines=()) -> "FaultInjector":
        if network is not None:
            network.fault_hook = self.on_transfer
            self._attached.append(("net", network))
        for eng in engines:
            eng.step_hook = self.on_step
            eng.swap_fault_hook = self.on_swap_upload
            self._attached.append(("eng", eng))
        return self

    def detach(self) -> None:
        for kind, obj in self._attached:
            if kind == "net":
                obj.fault_hook = None
            else:
                obj.step_hook = None
                obj.swap_fault_hook = None
        self._attached.clear()

    # -- network -----------------------------------------------------------
    def on_transfer(self, n_bytes: float) -> Optional[Tuple[str, float]]:
        """Fault verdict for one transfer attempt: None (clean), or
        ("loss"|"timeout"|"collapse", param)."""
        i = self._transfer_idx
        self._transfer_idx += 1
        p = self.plan
        for a, b in p.partition_windows:
            if a <= i < b:
                self.events["partition"] += 1
                return ("loss", 0.0)
        r = self._rng.random()
        if r < p.transfer_loss_p:
            self.events["transfer_loss"] += 1
            return ("loss", 0.0)
        r -= p.transfer_loss_p
        if r < p.transfer_timeout_p:
            self.events["transfer_timeout"] += 1
            return ("timeout", p.timeout_s)
        r -= p.transfer_timeout_p
        if r < p.bandwidth_collapse_p:
            self.events["bandwidth_collapse"] += 1
            return ("collapse", p.bandwidth_collapse_factor)
        return None

    # -- engine ------------------------------------------------------------
    def on_step(self, engine) -> None:
        """Called at the top of `InferenceEngine.step()`."""
        name = engine.name
        i = self._step_idx.get(name, 0)
        self._step_idx[name] = i + 1
        p = self.plan
        if i in p.straggler_steps and p.straggler_delay_s > 0:
            self.events["straggler"] += 1
            time.sleep(p.straggler_delay_s)
        if i == p.pool_squeeze_step and engine.kv_backend == "paged":
            self._squeeze(engine, i)
        rel = self._squeezed.get(name)
        if rel is not None and i >= rel:
            engine.alloc.release(self._hold_key(name))
            del self._squeezed[name]
        if i in p.crash_steps:
            self._crash_slot(engine)
        if i in p.engine_crash_steps:
            self.events["engine_crash"] += 1
            raise EngineCrash(f"injected engine crash on {name} step {i}")

    @staticmethod
    def _hold_key(name: str) -> str:
        return f"__fault_hold__{name}"

    def _squeeze(self, engine, step: int) -> None:
        """Steal free pages (leaving at least one) to simulate pool
        exhaustion; they return to the free list after the squeeze window
        via the allocator's normal release path."""
        alloc = engine.alloc
        n = min(self.plan.pool_squeeze_pages, max(len(alloc.free) - 1, 0))
        if n <= 0:
            return
        held = []
        for _ in range(n):
            p = alloc.free.pop()
            alloc.refcount[p] = 1
            held.append(p)
        alloc.owned[self._hold_key(engine.name)] = held
        self._squeezed[engine.name] = step + self.plan.pool_squeeze_duration
        self.events["pool_squeeze"] += 1

    def _crash_slot(self, engine) -> None:
        """Crash one active slot mid-decode: the lowest-priority, youngest
        request (the same ordering eviction uses) is cancelled."""
        active = [i for i, s in enumerate(engine.slots) if s.active]
        if not active:
            return
        v = min(active, key=lambda i: (engine.slots[i].priority,
                                       -engine.slots[i].arrival))
        engine.cancel(engine.slots[v].req_id)
        self.events["slot_crash"] += 1

    # -- host-tier swap ----------------------------------------------------
    def on_swap_upload(self, req_id) -> bool:
        """True when a swap promote's upload is lost (the engine then drops
        the host snapshot and degrades to evict-and-replay)."""
        if self._rng.random() < self.plan.swap_loss_p:
            self.events["swap_loss"] += 1
            return True
        return False
