"""Cloud <-> edge network transmission model Δ(r).

The paper transmits only queries and sketches ("a few tens of milliseconds
even at lower bandwidths" — Fig. 14); we model Δ(r) = rtt + bytes/bandwidth
with optional jitter, used both by the scheduler's Eq.(2) check and by the
event-driven simulator.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Callable, Optional, Tuple


@dataclasses.dataclass
class TransferResult:
    """Outcome of `transfer_with_retry`: modeled latency includes every
    failed attempt's cost plus the backoff waits between attempts."""
    ok: bool
    attempts: int
    latency_s: float
    failure: str = ""              # last fault kind when not ok / degraded


@dataclasses.dataclass
class NetworkModel:
    bandwidth_mbps: float = 100.0
    rtt_s: float = 0.02
    jitter_frac: float = 0.0
    bytes_per_token: float = 4.0
    # fault injection point (serving/faults.py): called once per transfer
    # attempt with the payload size, returns None (clean) or a
    # ("loss"|"timeout"|"collapse", param) verdict
    fault_hook: Optional[Callable[[float], Optional[Tuple[str, float]]]] = None
    # cumulative accounting across transfer_with_retry calls
    transfers: int = 0
    retries: int = 0
    transfer_failures: int = 0
    retry_latency_s: float = 0.0
    _rng: random.Random = dataclasses.field(
        default_factory=lambda: random.Random(0))

    def delay_s(self, n_tokens: int) -> float:
        return self.transfer_s(n_tokens * self.bytes_per_token)

    def transfer_s(self, n_bytes: float) -> float:
        """Modeled one-way transfer time for a raw byte payload — the KV
        swap path prices a demoted request's page bytes with this (the
        swap-vs-replay crossover in docs/serving.md), the token path above
        derives its bytes from a token count."""
        base = self.rtt_s + n_bytes * 8 / (self.bandwidth_mbps * 1e6)
        if self.jitter_frac:
            base *= 1.0 + self._rng.uniform(-self.jitter_frac, self.jitter_frac)
            # jitter models queueing variance on top of physics: a draw with
            # jitter_frac >= 1 must not undercut (or negate) the light-path RTT
            base = max(base, self.rtt_s)
        return base

    def transfer_with_retry(self, n_bytes: float, max_attempts: int = 4,
                            base_backoff_s: float = 0.05,
                            max_backoff_s: float = 1.0) -> TransferResult:
        """Transfer a payload with capped jittered exponential backoff.

        Each attempt consults `fault_hook` (when set): a "loss" costs one
        RTT, a "timeout" costs the injected stall, a bandwidth "collapse"
        succeeds at the collapsed rate; clean attempts cost `transfer_s`.
        Between failed attempts the caller waits base * 2^k (capped at
        `max_backoff_s`) jittered to [0.5x, 1.5x) — the jitter draw comes
        from the model's seeded PRNG, so retry schedules are reproducible.
        All costs are MODELED seconds (nothing sleeps); attempt counts and
        cumulative retry latency accumulate on the model for telemetry."""
        latency = 0.0
        kind = ""
        for attempt in range(1, max(max_attempts, 1) + 1):
            fault = self.fault_hook(n_bytes) if self.fault_hook else None
            if fault is None:
                latency += self.transfer_s(n_bytes)
                self.transfers += 1
                self.retries += attempt - 1
                self.retry_latency_s += latency
                return TransferResult(True, attempt, latency)
            kind, param = fault
            if kind == "collapse":
                # degraded but delivered: pay the collapsed-bandwidth time
                latency += self.rtt_s + n_bytes * 8 / (
                    self.bandwidth_mbps * max(param, 1e-3) * 1e6)
                self.transfers += 1
                self.retries += attempt - 1
                self.retry_latency_s += latency
                return TransferResult(True, attempt, latency, failure=kind)
            latency += param if kind == "timeout" else self.rtt_s
            if attempt <= max_attempts - 1:
                back = min(base_backoff_s * (2.0 ** (attempt - 1)),
                           max_backoff_s)
                latency += back * (0.5 + self._rng.random())
        self.transfers += 1
        self.retries += max(max_attempts, 1) - 1
        self.transfer_failures += 1
        self.retry_latency_s += latency
        return TransferResult(False, max(max_attempts, 1), latency,
                              failure=kind)
