"""Cloud <-> edge network transmission model Δ(r).

The paper transmits only queries and sketches ("a few tens of milliseconds
even at lower bandwidths" — Fig. 14); we model Δ(r) = rtt + bytes/bandwidth
with optional jitter, used both by the scheduler's Eq.(2) check and by the
event-driven simulator.
"""
from __future__ import annotations

import dataclasses
import random


@dataclasses.dataclass
class NetworkModel:
    bandwidth_mbps: float = 100.0
    rtt_s: float = 0.02
    jitter_frac: float = 0.0
    bytes_per_token: float = 4.0
    _rng: random.Random = dataclasses.field(
        default_factory=lambda: random.Random(0))

    def delay_s(self, n_tokens: int) -> float:
        return self.transfer_s(n_tokens * self.bytes_per_token)

    def transfer_s(self, n_bytes: float) -> float:
        """Modeled one-way transfer time for a raw byte payload — the KV
        swap path prices a demoted request's page bytes with this (the
        swap-vs-replay crossover in docs/serving.md), the token path above
        derives its bytes from a token count."""
        base = self.rtt_s + n_bytes * 8 / (self.bandwidth_mbps * 1e6)
        if self.jitter_frac:
            base *= 1.0 + self._rng.uniform(-self.jitter_frac, self.jitter_frac)
            # jitter models queueing variance on top of physics: a draw with
            # jitter_frac >= 1 must not undercut (or negate) the light-path RTT
            base = max(base, self.rtt_s)
        return base
