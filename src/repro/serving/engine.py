"""Real-compute inference engine: jitted prefill/decode with continuous
batching (Orca-style slot recycling) over a shared KV cache.

Two KV backends (`kv_backend`):
  "dense": one max_batch x max_len reservation per slot (the seed layout,
      kept for A/B equivalence testing).
  "paged": vLLM-style paged cache (models/paged_cache.py) — pages are
      allocated on demand at add_request, appended per decode step, and freed
      on completion; when the pool runs dry the youngest request is evicted
      (preempted) and transparently resubmitted, so a small pool degrades to
      recompute instead of failing. Dense and paged are bit-identical on the
      same request stream (masked page garbage contributes exactly zero).

This is the engine the examples and real-compute benchmarks run on CPU with
tiny models; on TPU the same code serves the full configs (the dry-run proves
the sharded lowering). Prompt lengths are bucketed to powers of two to bound
jit recompilation.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.models.paged_cache import PageAllocator
from repro.serving.sampler import SamplerConfig, sample, token_logprob


def _bucket(n: int, lo: int = 32) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


# ---------------------------------------------------------------------------
# Jitted entry points, shared across engine instances. ModelConfig is a
# frozen dataclass (hashable), so engines with the same config — the edge
# fleet, A/B dense-vs-paged pairs, short-lived benchmark engines — reuse one
# trace cache instead of recompiling per instance.
# ---------------------------------------------------------------------------

def _prefill_dense_fn(cfg, params, tokens, cache, lengths):
    return transformer.prefill(cfg, params, tokens, cache,
                               prompt_lengths=lengths)


def _score_fn(cfg, params, tokens):
    """Teacher-forced mean logprob of tokens[1:] given tokens[:-1]."""
    logits, _ = transformer.forward(cfg, params, tokens[None, :-1])
    logp = jax.nn.log_softmax(logits[0].astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logp, tokens[1:][:, None], axis=-1)[:, 0]
    return jnp.mean(gold), gold


def _insert_fn(big, one, slot):
    """Insert a batch-1 cache into slot `slot` of the big cache.
    Cache layout: lengths (B,); segment leaves (L, B, ...) — batch axis 1."""
    out = {"lengths": jax.lax.dynamic_update_slice(
        big["lengths"], one["lengths"].astype(big["lengths"].dtype), (slot,))}
    segs = []
    for bseg, oseg in zip(big["segments"], one["segments"]):
        seg = {}
        for k in bseg:
            idx = (0, slot) + (0,) * (bseg[k].ndim - 2)
            seg[k] = jax.lax.dynamic_update_slice(
                bseg[k], oseg[k].astype(bseg[k].dtype), idx)
        segs.append(seg)
    out["segments"] = segs
    return out


def _decode_dense_fn(cfg, params, tokens, cache, active):
    return transformer.decode_step(cfg, params, tokens, cache, active=active)


def _decode_paged_fn(cfg, live_pages, params, tokens, cache, active):
    return transformer.decode_step_paged(cfg, params, tokens, cache,
                                         active=active,
                                         live_pages=live_pages)


def _prefill_chunk_fn(cfg, live_pages, params, tokens, cache, slot, offset,
                      chunk_len):
    return transformer.prefill_chunk_paged(cfg, params, tokens, cache, slot,
                                           offset, chunk_len,
                                           live_pages=live_pages)


@functools.lru_cache(maxsize=None)
def _jitted(cfg: ModelConfig, kind: str):
    if kind == "decode":
        return jax.jit(functools.partial(_decode_dense_fn, cfg))
    if kind == "decode_paged":
        # live_pages is static (the read width is a shape); the engine
        # buckets it to powers of two, so recompiles are bounded by
        # log2(max_pages_per_seq) variants per config
        return jax.jit(functools.partial(_decode_paged_fn, cfg),
                       static_argnums=(0,), donate_argnums=(3,))
    if kind == "prefill":
        return jax.jit(functools.partial(_prefill_dense_fn, cfg))
    if kind == "prefill_paged":
        return jax.jit(functools.partial(transformer.prefill_paged, cfg),
                       donate_argnums=(2,))
    if kind == "prefill_chunk":
        # live_pages is static (the read width is a shape), bucketed like
        # the decode step; token shape is always (1, cfg.prefill_chunk), so
        # chunked engines compile one chunk variant per live-width bucket
        # instead of one prefill per prompt-length bucket
        return jax.jit(functools.partial(_prefill_chunk_fn, cfg),
                       static_argnums=(0,), donate_argnums=(3,))
    if kind == "fork":
        return jax.jit(functools.partial(transformer.fork_slot_paged, cfg),
                       donate_argnums=(0,))
    if kind == "insert":
        return jax.jit(_insert_fn, donate_argnums=(0,))
    if kind == "score":
        return jax.jit(functools.partial(_score_fn, cfg))
    raise ValueError(kind)


@dataclasses.dataclass
class Slot:
    req_id: int = -1
    active: bool = False
    tokens: List[int] = dataclasses.field(default_factory=list)
    logprobs: List[float] = dataclasses.field(default_factory=list)
    max_new: int = 0
    generated: int = 0
    prompt: List[int] = dataclasses.field(default_factory=list)
    ctx_len: int = 0        # tokens currently in the KV cache for this slot
    arrival: int = 0        # admission order (eviction picks the youngest)
    evicted: bool = False   # preempted: requeue instead of completing
    parked: bool = False    # holds a shared prefix for forking, not decoding
    # suffix tokens still to be teacher-forced into the cache (fork path):
    # each decode step feeds pending[0] instead of the last sampled token
    pending: List[int] = dataclasses.field(default_factory=list)
    fork_src: int = -1      # parked slot this one was forked from (-1: none)
    suffix: List[int] = dataclasses.field(default_factory=list)
    # prompt tokens not yet ingested (chunked prefill): while non-empty the
    # slot is excluded from the decode batch and step() feeds it one chunk
    # at a time; the first sample comes from the final chunk's logits
    prefill_toks: List[int] = dataclasses.field(default_factory=list)
    # eviction priority (higher = more latency-critical, evicted last);
    # PICE maps cloud-sketch / SLA-bound work above opportunistic
    # ensemble expansions
    priority: int = 0


@dataclasses.dataclass
class _Resume:
    """A queued request: fresh, or preempted with its generated prefix
    carried. share_from >= 0 routes admission through the COW fork path
    (prompt then holds the full prefix+suffix fallback for eviction resume).
    """
    req_id: int
    prompt: List[int]
    max_new: int
    carry_tokens: List[int]
    carry_lps: List[float]
    share_from: int = -1
    suffix: List[int] = dataclasses.field(default_factory=list)
    priority: int = 0


class InferenceEngine:
    """Continuous-batching engine for one model."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_len: int = 1024, sampler: SamplerConfig = SamplerConfig(),
                 eos_id: int = 0, name: str = "engine",
                 kv_backend: str = "dense", page_size: int = 32,
                 n_pages: Optional[int] = None, prefix_sharing: bool = True):
        assert kv_backend in ("dense", "paged"), kv_backend
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.sampler = sampler
        self.eos_id = eos_id
        self.name = name
        self.kv_backend = kv_backend
        # escape hatch: prefix_sharing=False makes generate_fanout submit
        # monolithically, restoring exact dense<->paged A/B at the pipeline
        # level (the fork path's teacher-forced suffixes are a different —
        # equally valid — float reduction order than one monolithic prefill)
        self.prefix_sharing = prefix_sharing
        self.slots = [Slot() for _ in range(max_batch)]
        self.key = jax.random.PRNGKey(0)
        self.tokens_generated = 0
        self.busy_s = 0.0
        self._arrivals = 0
        self.evictions = 0
        self.peak_pages = 0
        self._window_peak = 0
        self._window_shared = 0
        self._window_logical = 0
        self._resume_queue: List[_Resume] = []
        self._prefix_logits: Dict[int, jax.Array] = {}   # parked slot -> (1,V)
        # per-request time-to-first-token telemetry: admission time survives
        # eviction/resume (TTFT spans the preemption), recorded once at the
        # first committed token; benchmarks read + clear `ttft`
        self._t_admit: Dict[int, float] = {}
        self.ttft: Dict[int, float] = {}
        self.prefill_chunk = 0

        if kv_backend == "paged":
            cfg.validate_paged(page_size, max_len)
            self.page_size = page_size
            self.pages_per_seq = max_len // page_size
            self.n_pages = n_pages or max_batch * self.pages_per_seq
            self.alloc = PageAllocator(self.n_pages, page_size,
                                       self.pages_per_seq)
            self.block_table = np.full((max_batch, self.pages_per_seq), -1,
                                       np.int32)
            self.cache = transformer.init_paged_cache(
                cfg, max_batch, self.n_pages, page_size, self.pages_per_seq)
            self._push_table()
            self._decode = _jitted(cfg, "decode_paged")
            self._prefill_paged = _jitted(cfg, "prefill_paged")
            self._fork = _jitted(cfg, "fork")
            # chunked prefill needs an attention-only stack (recurrent
            # segments cannot resume their scan state mid-prompt): other
            # families silently keep the monolithic path
            chunkable = all(
                kind in ("attn", "moe", "shared_attn")
                for kind, _ in transformer.segments_of(cfg))
            self.prefill_chunk = cfg.prefill_chunk if chunkable else 0
            if self.prefill_chunk:
                self._prefill_chunk = _jitted(cfg, "prefill_chunk")
        else:
            self.cache = transformer.init_cache(cfg, max_batch, max_len)
            self._decode = _jitted(cfg, "decode")
            self._prefill = _jitted(cfg, "prefill")
            self._insert = _jitted(cfg, "insert")
        self._score = _jitted(cfg, "score")

    # ------------------------------------------------------------------
    # Paged-backend bookkeeping
    # ------------------------------------------------------------------
    def _push_table(self):
        self.cache["block_table"] = jnp.asarray(self.block_table)

    def _occupancy(self) -> Tuple[int, int, int]:
        """(physical, shared, logical) occupancy right now. Dense slots are
        counted as one "page" each with no sharing."""
        if self.kv_backend == "paged":
            return (self.alloc.pages_in_use, self.alloc.pages_shared,
                    self.alloc.logical_pages)
        used = sum(1 for s in self.slots if s.active)
        return used, 0, used

    def _track_peak(self):
        used, shared, logical = self._occupancy()
        self.peak_pages = max(self.peak_pages, used)
        self._window_peak = max(self._window_peak, used)
        self._window_shared = max(self._window_shared, shared)
        self._window_logical = max(self._window_logical, logical)

    def consume_window(self) -> Dict[str, int]:
        """High-water occupancy since the last call, then reset the window.
        The PICE pipeline is synchronous — pools drain to zero between
        requests — so instantaneous occupancy is always 0 at observation
        time; the windowed peak is the pressure signal that survives. Both
        backends window: a dense fleet otherwise always reports ~0 active
        slots between synchronous requests."""
        self._track_peak()
        out = {"pages": self._window_peak, "shared": self._window_shared,
               "logical": self._window_logical}
        (self._window_peak, self._window_shared,
         self._window_logical) = self._occupancy()
        return out

    def consume_peak(self) -> int:
        """Windowed physical peak (see consume_window)."""
        return self.consume_window()["pages"]

    def _release_slot_pages(self, slot: int):
        self.alloc.release(slot)
        self.block_table[slot, :] = -1
        self._push_table()

    def _evict_victim(self, protect: int) -> bool:
        """Preempt one active slot other than `protect`: the lowest-priority
        one, youngest-first within a priority class. Latency-critical work
        (cloud sketches, SLA-bound requests — higher `priority`) is only
        preempted once every opportunistic expansion is gone, so a parallel
        fan-out can never push a critical slot off the pool. Victims' pages
        return to the pool and the request is queued for resubmission."""
        victims = [i for i, s in enumerate(self.slots)
                   if s.active and i != protect]
        if not victims:
            return False
        v = min(victims,
                key=lambda i: (self.slots[i].priority,
                               -self.slots[i].arrival))
        s = self.slots[v]
        # release only frees the victim's *unique* pages (refcounted), never
        # prefix pages its siblings still read. A fork whose prefix is still
        # parked resumes through the fork path (replaying suffix + generated
        # tokens through decode rebuilds bit-identical KV without a second
        # prefix prefill); otherwise s.prompt holds the full prefix+suffix
        # for a monolithic resume.
        refork = (0 <= s.fork_src < self.max_batch
                  and self.slots[s.fork_src].parked)
        self._resume_queue.append(_Resume(
            req_id=s.req_id, prompt=list(s.prompt),
            max_new=s.max_new, carry_tokens=list(s.tokens),
            carry_lps=list(s.logprobs),
            share_from=s.fork_src if refork else -1,
            suffix=list(s.suffix) if refork else [],
            priority=s.priority))
        self._release_slot_pages(v)
        s.active, s.evicted, s.req_id = False, True, -1
        s.pending, s.fork_src, s.suffix = [], -1, []
        s.prefill_toks = []     # a mid-prefill victim restarts its chunks
        self.evictions += 1
        return True

    def memory_stats(self) -> Dict[str, float]:
        """Engine-level KV memory telemetry (for RuntimeMonitor).

        `pages_shared` counts physical pages referenced by >1 slot;
        `pages_logical` is the sum of per-slot chains (what an unshared
        layout would hold) — logical - in_use is the COW saving."""
        if self.kv_backend == "paged":
            return {"backend": "paged", "pages_total": self.n_pages,
                    "pages_in_use": self.alloc.pages_in_use,
                    "pages_shared": self.alloc.pages_shared,
                    "pages_logical": self.alloc.logical_pages,
                    "peak_pages": self.peak_pages,
                    "utilization": self.alloc.utilization,
                    "evictions": self.evictions}
        used = sum(1 for s in self.slots if s.active)
        return {"backend": "dense", "pages_total": self.max_batch,
                "pages_in_use": used, "pages_shared": 0,
                "pages_logical": used, "peak_pages": self.max_batch,
                "utilization": used / self.max_batch, "evictions": 0}

    def can_admit(self, prompt_len: int) -> bool:
        """Admission check against real memory, not just a fixed max_batch."""
        if not self.free_slots():
            return False
        if self.kv_backend == "paged":
            need = max(1, -(-min(prompt_len, self.max_len) // self.page_size))
            return len(self.alloc.free) >= need
        return True

    def can_admit_fork(self, src_slot: int, extra_tokens: int = 0) -> bool:
        """Admission check for the fork path: a free batch row plus enough
        free pages for the tail copy AND the suffix/carry replay
        (extra_tokens). Gating on the full replay need — like `can_admit`
        gates on the full prompt — prevents admit/evict livelock between
        sibling forks under a tight pool."""
        if not self.free_slots():
            return False
        src = self.slots[src_slot]
        total = min(src.ctx_len + extra_tokens, self.max_len)
        full_shared = src.ctx_len // self.page_size
        need = -(-total // self.page_size) - full_shared
        return len(self.alloc.free) >= need

    def _live_pages(self, active: List[int]) -> int:
        """Static read width for this decode step: enough block-table
        columns to cover every active slot's cache plus the token being
        written, bucketed to the next power of two so jit variants stay
        bounded. Trimmed columns are past every slot's valid positions and
        carry exactly-zero attention weight, so any covering width is
        bit-identical — this only stops the read path from paying for
        `max_pages_per_seq` when the batch is short."""
        return self._chunk_live(max(self.slots[i].ctx_len
                                    for i in active) + 1)

    # ------------------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots)
                if not s.active and not s.parked]

    def _alloc_slot_pages(self, slot: int, n_tokens: int):
        """Map a fresh page chain for `n_tokens` into the slot's table row."""
        pages = self.alloc.alloc_for(slot, n_tokens)    # MemoryError if dry
        self._track_peak()
        self.block_table[slot, :] = -1
        self.block_table[slot, :len(pages)] = pages
        self._push_table()

    def _chunk_live(self, end: int) -> int:
        """Static covering read width through position `end`, bucketed to
        the next power of two (shared by the decode step and chunk ingest
        so both paths honor one recompile contract)."""
        need = -(-min(end, self.max_len) // self.page_size)
        live = 1
        while live < need:
            live *= 2
        return min(live, self.pages_per_seq)

    def _feed_chunk(self, slot: int, chunk: List[int], offset: int):
        """One (1, prefill_chunk)-shaped ingest call: pad, pick the covering
        live width, write+attend the chunk at `offset`. Returns the chunk's
        last-valid-token logits (1, V)."""
        padded = np.zeros((1, self.prefill_chunk), np.int32)
        padded[0, :len(chunk)] = chunk
        live = self._chunk_live(offset + len(chunk))
        logits, self.cache = self._prefill_chunk(
            live, self.params, jnp.asarray(padded), self.cache,
            jnp.asarray(slot, jnp.int32), jnp.asarray(offset, jnp.int32),
            jnp.asarray(len(chunk), jnp.int32))
        return logits

    def _ingest_chunk(self, slot: int):
        """Feed the slot's next prompt chunk into the paged cache. After the
        final chunk, the first token is sampled from the chunk's logits —
        the same (1, V) sample a monolithic `add_request` takes, so the
        engine's PRNG stream (and therefore sampled output) is unchanged."""
        s = self.slots[slot]
        chunk = s.prefill_toks[:self.prefill_chunk]
        s.prefill_toks = s.prefill_toks[self.prefill_chunk:]
        logits = self._feed_chunk(slot, chunk, s.ctx_len)
        s.ctx_len += len(chunk)
        if not s.prefill_toks:
            self.key, sub = jax.random.split(self.key)
            tok = sample(logits, sub, self.sampler)
            lp = token_logprob(logits, tok)
            self._commit(slot, int(tok[0]), float(lp[0]))
        return logits

    def _prefill_into_chunks(self, slot: int, toks: List[int]):
        """Synchronous chunked ingest of a whole prompt (prefill_prefix and
        direct callers outside the step loop); returns final-chunk logits.
        Performs no PRNG splits, matching `_ingest_chunk`'s contract that
        only the first-token sample advances the key stream. An empty
        prompt ingests one zero-length chunk so callers always get logits
        (matching the monolithic path's zero-padded prefill)."""
        C = self.prefill_chunk
        logits = None
        for start in range(0, max(len(toks), 1), C):
            logits = self._feed_chunk(slot, toks[start:start + C], start)
        return logits

    def _prefill_into(self, slot: int, toks: List[int], padded: np.ndarray):
        """Prefill `toks` into batch row `slot` (either backend); returns
        last-token logits (1, V)."""
        if self.kv_backend == "paged":
            self._alloc_slot_pages(slot, len(toks))
            if self.prefill_chunk:
                return self._prefill_into_chunks(slot, toks)
            logits, self.cache = self._prefill_paged(
                self.params, jnp.asarray(padded), self.cache,
                jnp.asarray(slot, jnp.int32),
                jnp.asarray(len(toks), jnp.int32))
        else:
            one_cache = transformer.init_cache(self.cfg, 1, self.max_len)
            logits, one_cache = self._prefill(
                self.params, jnp.asarray(padded), one_cache,
                jnp.asarray([len(toks)], jnp.int32))
            self.cache = self._insert(self.cache, one_cache, slot)
        return logits

    @staticmethod
    def _pad_prompt(full_prompt: List[int], max_len: int):
        S = min(_bucket(len(full_prompt)), max_len)
        padded = np.zeros((1, S), np.int32)
        toks = full_prompt[-S:]
        padded[0, :len(toks)] = toks
        return toks, padded

    # ------------------------------------------------------------------
    # Prefix sharing (PICE sketch fan-out): prefill the shared (query,
    # sketch) prefix ONCE into a parked slot, then fork N copy-on-write
    # block-table rows off it — full prefix pages are shared refcounted,
    # only the partial tail page is copied per fork.
    # ------------------------------------------------------------------
    def prefill_prefix(self, prefix: List[int]) -> int:
        """Prefill a shared prefix into a parked slot and return its id for
        `add_request(..., share_from=slot)`. The slot holds its pages (and
        is excluded from scheduling) until `release_prefix`."""
        assert self.kv_backend == "paged", \
            "prefix sharing needs the paged backend"
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free slot")
        # park in the LAST free slot: forks then land on the same batch rows
        # as independent submissions would, keeping the per-row PRNG draws —
        # and therefore sampled outputs — bit-identical to the unshared path
        slot = free[-1]
        t0 = time.perf_counter()
        toks, padded = self._pad_prompt(list(prefix), self.max_len)
        logits = self._prefill_into(slot, toks, padded)
        s = self.slots[slot]
        s.req_id, s.active, s.parked = -1, False, True
        s.prompt = list(prefix)
        s.tokens, s.logprobs, s.pending = [], [], []
        s.prefill_toks = []
        s.ctx_len = len(toks)
        self._prefix_logits[slot] = logits
        self.busy_s += time.perf_counter() - t0
        return slot

    def release_prefix(self, slot: int) -> None:
        """Free a parked prefix slot; pages shared with live forks survive
        via their refcounts."""
        s = self.slots[slot]
        assert s.parked, "release_prefix on a non-parked slot"
        s.parked = False
        self._prefix_logits.pop(slot, None)
        self._release_slot_pages(slot)

    def add_request(self, req_id: int, prompt: List[int], max_new: int,
                    carry_tokens: Optional[List[int]] = None,
                    carry_lps: Optional[List[float]] = None,
                    share_from: Optional[int] = None,
                    suffix: Optional[List[int]] = None,
                    priority: int = 0) -> int:
        """Admit a request. share_from forks a parked prefix slot
        copy-on-write instead of prefilling; `suffix` tokens (the part of
        the logical prompt beyond the shared prefix) are then ingested into
        the cache before sampling starts — as are any carried tokens when a
        preempted fork resumes. `prompt` must be the full logical prompt
        (prefix + suffix) so eviction can always fall back to a monolithic
        resume. `priority` orders eviction: lower-priority slots are
        preempted first (see `_evict_victim`).

        With `cfg.prefill_chunk` set (paged backend), admission only maps
        the prompt's pages and queues its tokens: `step()` then ingests one
        chunk per call interleaved with the decode batch, so a long prompt
        never stalls running decodes for more than one chunk. Fork suffixes
        and resume carries ride the same chunked path (multi-token ingest)
        instead of token-by-token teacher forcing."""
        suffix = list(suffix or [])
        carry_tokens = carry_tokens or []
        carry_lps = carry_lps or []
        if share_from is not None:
            src = self.slots[share_from]
            assert self.kv_backend == "paged", \
                "prefix sharing needs the paged backend"
            assert src.parked and share_from in self._prefix_logits, \
                "share_from must be a parked prefill_prefix slot"
            if src.ctx_len + len(suffix) + len(carry_tokens) > self.max_len:
                share_from = None       # would overflow: prefill monolithically
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free slot")
        slot = free[0]
        t0 = time.perf_counter()
        self._t_admit.setdefault(req_id, t0)
        while len(self._t_admit) > 4096:     # bound never-committed leftovers
            self._t_admit.pop(next(iter(self._t_admit)))

        ingest: List[int] = []          # chunked path: tokens step() feeds
        logits = None
        if share_from is not None:
            src = self.slots[share_from]
            # MemoryError if the tail copy cannot be allocated
            dst_pages, tail_src, tail_dst = self.alloc.fork(
                share_from, slot, src.ctx_len)
            self._track_peak()
            self.block_table[slot, :] = -1
            self.block_table[slot, :len(dst_pages)] = dst_pages
            self._push_table()
            self.cache = self._fork(
                self.cache, jnp.asarray(share_from, jnp.int32),
                jnp.asarray(slot, jnp.int32),
                jnp.asarray(tail_src, jnp.int32),
                jnp.asarray(tail_dst, jnp.int32))
            logits = self._prefix_logits[share_from]
            ctx = src.ctx_len
            pending = suffix + carry_tokens
            if self.prefill_chunk and pending:
                # the replay goes through multi-token chunks: map the pages
                # it will write up front (can_admit_fork gated on this need)
                target = -(-min(ctx + len(pending), self.max_len)
                           // self.page_size)
                while len(self.alloc.owned[slot]) < target:
                    p = self.alloc.extend(
                        slot, (len(self.alloc.owned[slot]) + 1)
                        * self.page_size)
                    self.block_table[slot,
                                     len(self.alloc.owned[slot]) - 1] = p
                self._push_table()
                self._track_peak()
                ingest, pending = pending, []
        elif self.prefill_chunk:
            full = list(prompt) + carry_tokens
            toks = full[-self.max_len:]
            self._alloc_slot_pages(slot, len(toks))
            ctx, pending, ingest = 0, [], list(toks)
            if not toks:
                # degenerate empty prompt: ingest one zero-length chunk now
                # so the sample below has logits (the monolithic path
                # likewise prefills a zero-padded buffer and samples)
                logits = self._prefill_into_chunks(slot, toks)
        else:
            toks, padded = self._pad_prompt(list(prompt) + carry_tokens,
                                            self.max_len)
            logits = self._prefill_into(slot, toks, padded)
            ctx = len(toks)
            pending = []

        s = self.slots[slot]
        s.req_id, s.active = req_id, True
        s.prompt = list(prompt)
        s.tokens, s.logprobs = list(carry_tokens), list(carry_lps)
        s.max_new, s.generated = max_new, len(carry_tokens)
        s.ctx_len = ctx
        s.pending = list(pending)
        s.prefill_toks = list(ingest)
        s.fork_src = share_from if share_from is not None else -1
        s.suffix = suffix if share_from is not None else []
        s.evicted = False
        s.priority = priority
        s.arrival = self._arrivals
        self._arrivals += 1
        self._track_peak()
        if not s.pending and not s.prefill_toks:
            # sample the first token from (possibly shared) prefill logits
            self.key, sub = jax.random.split(self.key)
            tok = sample(logits, sub, self.sampler)
            lp = token_logprob(logits, tok)
            self._commit(slot, int(tok[0]), float(lp[0]))
        # else: the first sample comes after the last suffix/prompt token
        # is ingested
        self.busy_s += time.perf_counter() - t0
        return slot

    def _commit(self, slot: int, tok: int, lp: float):
        s = self.slots[slot]
        s.tokens.append(tok)
        s.logprobs.append(lp)
        s.generated += 1
        self.tokens_generated += 1
        if s.generated == 1 and s.req_id in self._t_admit:
            self.ttft[s.req_id] = (time.perf_counter()
                                   - self._t_admit.pop(s.req_id))
            # bound the telemetry in long-running fleets: keep the most
            # recent window (dicts preserve insertion order)
            while len(self.ttft) > 4096:
                self.ttft.pop(next(iter(self.ttft)))
        # context capacity counts as completion: decoding past max_len would
        # overwrite live cache positions (in either backend), so both
        # backends stop at the same point and stay bit-identical
        if (tok == self.eos_id or s.generated >= s.max_new
                or s.ctx_len >= self.max_len):
            s.active = False
            if self.kv_backend == "paged":
                self._release_slot_pages(slot)

    def _grow_pages(self):
        """Before a decode step, make every active slot's next write target
        safe: copy-on-write any shared page the write would land in, and map
        a fresh page when the slot crosses a page boundary; evict the
        youngest request when the pool is dry. Raises MemoryError only if a
        lone request cannot grow."""
        changed = False
        for i, s in enumerate(self.slots):
            # slots mid-chunked-prefill hold pages for their whole prompt
            # already and are not in the decode batch — nothing to grow
            if not s.active or s.ctx_len >= self.max_len or s.prefill_toks:
                continue
            cow, cow_done = None, False
            while True:
                try:
                    if not cow_done:
                        cow = self.alloc.cow_page(i, s.ctx_len)
                        cow_done = True
                    newp = self.alloc.extend(i, s.ctx_len + 1)
                    break
                except MemoryError:
                    if not self._evict_victim(protect=i):
                        raise
            if cow is not None:
                old, new = cow
                self.block_table[i, s.ctx_len // self.page_size] = new
                # device-side page copy: fork op with src == dst slot
                self.cache = self._fork(
                    self.cache, jnp.asarray(i, jnp.int32),
                    jnp.asarray(i, jnp.int32), jnp.asarray(old, jnp.int32),
                    jnp.asarray(new, jnp.int32))
                changed = True
                self._track_peak()
            if newp is not None:
                n_owned = len(self.alloc.owned[i])
                self.block_table[i, n_owned - 1] = newp
                changed = True
                self._track_peak()
        if changed:
            self._push_table()

    def step(self) -> bool:
        """One engine step: ingest at most one prompt chunk (chunked
        prefill), then one decode step for every decodable slot. Returns
        True if work was done.

        The chunk goes to the oldest admission still ingesting, so decode
        latency between steps is bounded by one chunk of prefill compute —
        a long prompt no longer head-of-line-blocks the whole batch for its
        full monolithic prefill. Slots finish ingesting and join the decode
        batch in the same step their final chunk lands (mirroring the
        monolithic path, where `add_request` samples and the next `step`
        decodes).

        Slots with a pending suffix (fork path, monolithic engines) are
        teacher-forced: the step feeds `pending[0]` instead of the last
        sampled token and the sampled output is discarded until the suffix
        is exhausted — the logits after the final suffix token seed the
        first real sample."""
        if not any(s.active for s in self.slots):
            return False
        t0 = time.perf_counter()
        worked = False
        if self.prefill_chunk:
            pref = [i for i, s in enumerate(self.slots)
                    if s.active and s.prefill_toks]
            if pref:
                # highest priority first (a latency-critical latecomer's
                # chunks jump the queue of a long opportunistic ingest),
                # oldest admission within a class
                self._ingest_chunk(min(
                    pref, key=lambda j: (-self.slots[j].priority,
                                         self.slots[j].arrival)))
                worked = True
        active = [i for i, s in enumerate(self.slots)
                  if s.active and not s.prefill_toks]
        if not active:
            self.busy_s += time.perf_counter() - t0
            return worked
        if self.kv_backend == "paged":
            self._grow_pages()
            active = [i for i, s in enumerate(self.slots)
                      if s.active and not s.prefill_toks]
            if not active:
                self.busy_s += time.perf_counter() - t0
                return worked
        last = np.zeros((self.max_batch, 1), np.int32)
        mask = np.zeros((self.max_batch,), bool)
        mask[active] = True
        for i in active:
            s = self.slots[i]
            if s.pending:
                last[i, 0] = s.pending[0]
            elif s.tokens:
                last[i, 0] = s.tokens[-1]
        if self.kv_backend == "paged":
            logits, self.cache = self._decode(
                self._live_pages(active), self.params, jnp.asarray(last),
                self.cache, jnp.asarray(mask))
        else:
            logits, self.cache = self._decode(self.params, jnp.asarray(last),
                                              self.cache, jnp.asarray(mask))
        self.key, sub = jax.random.split(self.key)
        toks = np.asarray(sample(logits, sub, self.sampler))
        lps = np.asarray(token_logprob(logits, jnp.asarray(toks)))
        for i in active:
            s = self.slots[i]
            s.ctx_len = min(s.ctx_len + 1, self.max_len)
            if s.pending:
                s.pending.pop(0)
                if s.pending:
                    continue            # still teacher-forcing the suffix
            self._commit(i, int(toks[i]), float(lps[i]))
        self.busy_s += time.perf_counter() - t0
        return True

    # ------------------------------------------------------------------
    def generate(self, prompts: List[List[int]], max_new: int = 128,
                 priorities: Optional[List[int]] = None
                 ) -> List[Tuple[List[int], List[float]]]:
        """Batch-generate; returns (tokens, logprobs) per prompt.
        `priorities` (optional, per prompt) orders preemption under memory
        pressure — higher survives longer."""
        priorities = priorities or [0] * len(prompts)
        assert len(priorities) == len(prompts), \
            "priorities must match prompts one-to-one"
        pending = [_Resume(req_id=i, prompt=p, max_new=max_new,
                           carry_tokens=[], carry_lps=[], priority=pr)
                   for i, (p, pr) in enumerate(zip(prompts, priorities))]
        return self._run(pending)

    def generate_fanout(self, prefix: List[int],
                        suffixes: List[List[int]], max_new: int = 128,
                        priority: int = 0
                        ) -> List[Tuple[List[int], List[float]]]:
        """Expand one shared prefix N ways (the PICE sketch fan-out: every
        ensemble member / parallel expansion segment repeats the same
        (query, sketch) prefix). The prefix is prefilled ONCE and each
        expansion forks a copy-on-write block-table row off it, so the pool
        holds one prefix instead of N; per-group suffixes are teacher-forced
        before sampling. Falls back to independent submissions on the dense
        backend, a 1-slot engine, or prefix_sharing=False."""
        if (self.kv_backend != "paged" or self.max_batch < 2
                or not self.prefix_sharing):
            return self.generate([list(prefix) + list(s) for s in suffixes],
                                 max_new=max_new,
                                 priorities=[priority] * len(suffixes))
        p_slot = self.prefill_prefix(prefix)
        pending = [_Resume(req_id=i, prompt=list(prefix) + list(sfx),
                           max_new=max_new, carry_tokens=[], carry_lps=[],
                           share_from=p_slot, suffix=list(sfx),
                           priority=priority)
                   for i, sfx in enumerate(suffixes)]
        try:
            return self._run(pending)
        finally:
            self.release_prefix(p_slot)

    def _run(self, pending: List[_Resume]
             ) -> List[Tuple[List[int], List[float]]]:
        n = len(pending)
        for r in pending:
            # fresh submissions must not inherit a stale admission stamp
            # from an earlier run that reused the same req_id (eviction
            # resumes within THIS run still keep their original stamp)
            self._t_admit.pop(r.req_id, None)
        results: Dict[int, Tuple[List[int], List[float]]] = {}
        submitted: Dict[int, int] = {}          # req_id -> slot
        while pending or any(s.active for s in self.slots):
            while pending and self.free_slots():
                r = pending[0]
                if r.share_from >= 0 and not self.slots[r.share_from].parked:
                    r.share_from, r.suffix = -1, []   # prefix gone: monolithic
                if r.share_from >= 0:
                    ok = self.can_admit_fork(
                        r.share_from, len(r.suffix) + len(r.carry_tokens))
                else:
                    ok = self.can_admit(len(r.prompt) + len(r.carry_tokens))
                if not ok:
                    if not any(s.active for s in self.slots):
                        raise MemoryError(
                            f"request {r.req_id} cannot fit in the page pool")
                    break                        # wait for pages to free
                pending.pop(0)
                slot = self.add_request(
                    r.req_id, r.prompt, r.max_new,
                    carry_tokens=r.carry_tokens, carry_lps=r.carry_lps,
                    share_from=r.share_from if r.share_from >= 0 else None,
                    suffix=r.suffix, priority=r.priority)
                submitted[r.req_id] = slot
            self.step()
            done = [rid for rid, sl in submitted.items()
                    if not self.slots[sl].active]
            for rid in done:
                sl = submitted.pop(rid)
                s = self.slots[sl]
                s.req_id = -1
                if s.evicted:
                    s.evicted = False
                    continue                     # resubmitted via _resume_queue
                results[rid] = (list(s.tokens), list(s.logprobs))
            if self._resume_queue:
                # preempted work goes to the queue head, oldest first
                # (victims were queued youngest-first as eviction found them)
                pending[:0] = reversed(self._resume_queue)
                self._resume_queue.clear()
        return [results[i] for i in range(n)]

    def score(self, tokens: List[int]) -> Tuple[float, np.ndarray]:
        """Mean token logprob of a sequence under this model (perplexity)."""
        S = _bucket(len(tokens))
        arr = np.full((S,), self.eos_id, np.int32)
        arr[:len(tokens)] = tokens
        mean_lp, gold = self._score(self.params, jnp.asarray(arr))
        gold = np.asarray(gold)[:max(len(tokens) - 1, 1)]
        return float(np.mean(gold)), gold
