"""Real-compute inference engine: jitted prefill/decode with continuous
batching (Orca-style slot recycling) over a shared multi-slot KV cache.

This is the engine the examples and real-compute benchmarks run on CPU with
tiny models; on TPU the same code serves the full configs (the dry-run proves
the sharded lowering). Prompt lengths are bucketed to powers of two to bound
jit recompilation.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.serving.sampler import SamplerConfig, sample, token_logprob


def _bucket(n: int, lo: int = 32) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass
class Slot:
    req_id: int = -1
    active: bool = False
    tokens: List[int] = dataclasses.field(default_factory=list)
    logprobs: List[float] = dataclasses.field(default_factory=list)
    max_new: int = 0
    generated: int = 0


class InferenceEngine:
    """Continuous-batching engine for one model."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_len: int = 1024, sampler: SamplerConfig = SamplerConfig(),
                 eos_id: int = 0, name: str = "engine"):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.sampler = sampler
        self.eos_id = eos_id
        self.name = name
        self.slots = [Slot() for _ in range(max_batch)]
        self.cache = transformer.init_cache(cfg, max_batch, max_len)
        self.key = jax.random.PRNGKey(0)
        self.tokens_generated = 0
        self.busy_s = 0.0

        self._decode = jax.jit(
            lambda p, t, c: transformer.decode_step(cfg, p, t, c))
        self._prefill = jax.jit(
            lambda p, t, c, l: transformer.prefill(cfg, p, t, c,
                                                   prompt_lengths=l))
        self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))
        self._score = jax.jit(self._score_impl)

    # ------------------------------------------------------------------
    @staticmethod
    def _insert_impl(big, one, slot):
        """Insert a batch-1 cache into slot `slot` of the big cache.
        Cache layout: lengths (B,); segment leaves (L, B, ...) — batch axis 1."""
        out = {"lengths": jax.lax.dynamic_update_slice(
            big["lengths"], one["lengths"].astype(big["lengths"].dtype), (slot,))}
        segs = []
        for bseg, oseg in zip(big["segments"], one["segments"]):
            seg = {}
            for k in bseg:
                idx = (0, slot) + (0,) * (bseg[k].ndim - 2)
                seg[k] = jax.lax.dynamic_update_slice(
                    bseg[k], oseg[k].astype(bseg[k].dtype), idx)
            segs.append(seg)
        out["segments"] = segs
        return out

    def _score_impl(self, params, tokens):
        """Teacher-forced mean logprob of tokens[1:] given tokens[:-1]."""
        logits, _ = transformer.forward(self.cfg, params, tokens[None, :-1])
        logp = jax.nn.log_softmax(logits[0].astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(logp, tokens[1:][:, None], axis=-1)[:, 0]
        return jnp.mean(gold), gold

    # ------------------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.active]

    def add_request(self, req_id: int, prompt: List[int], max_new: int) -> int:
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free slot")
        slot = free[0]
        t0 = time.perf_counter()
        S = _bucket(len(prompt))
        S = min(S, self.max_len)
        padded = np.zeros((1, S), np.int32)
        toks = prompt[-S:]
        padded[0, :len(toks)] = toks
        one_cache = transformer.init_cache(self.cfg, 1, self.max_len)
        logits, one_cache = self._prefill(
            self.params, jnp.asarray(padded), one_cache,
            jnp.asarray([len(toks)], jnp.int32))
        self.cache = self._insert(self.cache, one_cache, slot)
        s = self.slots[slot]
        s.req_id, s.active = req_id, True
        s.tokens, s.logprobs = [], []
        s.max_new, s.generated = max_new, 0
        # sample the first token from prefill logits
        self.key, sub = jax.random.split(self.key)
        tok = sample(logits, sub, self.sampler)
        lp = token_logprob(logits, tok)
        self._commit(slot, int(tok[0]), float(lp[0]))
        self.busy_s += time.perf_counter() - t0
        return slot

    def _commit(self, slot: int, tok: int, lp: float):
        s = self.slots[slot]
        s.tokens.append(tok)
        s.logprobs.append(lp)
        s.generated += 1
        self.tokens_generated += 1
        if tok == self.eos_id or s.generated >= s.max_new:
            s.active = False

    def step(self) -> bool:
        """One decode step for all active slots. Returns True if work done."""
        active = [i for i, s in enumerate(self.slots) if s.active]
        if not active:
            return False
        t0 = time.perf_counter()
        last = np.zeros((self.max_batch, 1), np.int32)
        for i, s in enumerate(self.slots):
            last[i, 0] = s.tokens[-1] if s.tokens else 0
        logits, self.cache = self._decode(self.params, jnp.asarray(last),
                                          self.cache)
        self.key, sub = jax.random.split(self.key)
        toks = np.asarray(sample(logits, sub, self.sampler))
        lps = np.asarray(token_logprob(logits, jnp.asarray(toks)))
        for i in active:
            self._commit(i, int(toks[i]), float(lps[i]))
        self.busy_s += time.perf_counter() - t0
        return True

    # ------------------------------------------------------------------
    def generate(self, prompts: List[List[int]], max_new: int = 128
                 ) -> List[Tuple[List[int], List[float]]]:
        """Batch-generate; returns (tokens, logprobs) per prompt."""
        results: Dict[int, Tuple[List[int], List[float]]] = {}
        pending = list(enumerate(prompts))
        submitted: Dict[int, int] = {}          # req_id -> slot
        while pending or any(s.active for s in self.slots):
            while pending and self.free_slots():
                rid, prompt = pending.pop(0)
                slot = self.add_request(rid, prompt, max_new)
                submitted[rid] = slot
            if not self.step():
                pass
            done = [rid for rid, sl in submitted.items()
                    if not self.slots[sl].active]
            for rid in done:
                sl = submitted.pop(rid)
                s = self.slots[sl]
                results[rid] = (list(s.tokens), list(s.logprobs))
                s.req_id = -1
        return [results[i] for i in range(len(prompts))]

    def score(self, tokens: List[int]) -> Tuple[float, np.ndarray]:
        """Mean token logprob of a sequence under this model (perplexity)."""
        S = _bucket(len(tokens))
        arr = np.full((S,), self.eos_id, np.int32)
        arr[:len(tokens)] = tokens
        mean_lp, gold = self._score(self.params, jnp.asarray(arr))
        gold = np.asarray(gold)[:max(len(tokens) - 1, 1)]
        return float(np.mean(gold)), gold
