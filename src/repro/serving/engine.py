"""Real-compute inference engine: jitted prefill/decode with continuous
batching (Orca-style slot recycling) over a shared KV cache.

Two KV backends (`kv_backend`):
  "dense": one max_batch x max_len reservation per slot (the seed layout,
      kept for A/B equivalence testing).
  "paged": vLLM-style paged cache (models/paged_cache.py) — pages are
      allocated on demand at add_request, appended per decode step, and freed
      on completion; when the pool runs dry the youngest request is evicted
      (preempted) and transparently resubmitted, so a small pool degrades to
      recompute instead of failing. Dense and paged are bit-identical on the
      same request stream (masked page garbage contributes exactly zero).

The step loop is structured plan/run (flashinfer's plan/run split and vLLM's
scheduler are the precedents): every host decision — page growth, eviction,
ragged ingest layout, decode inputs — is planned with numpy, the block table
is pushed to the device at most once per step, and the step dispatches at
most one batched ragged chunk-ingest call plus one fused decode call
(model step + sample + logprob in a single jit, cache donated) whose readback
is deferred to the NEXT step's harvest. The host therefore plans step N+1
while the device still runs step N, and per-step sync cost is one
`jax.device_get`.

This is the engine the examples and real-compute benchmarks run on CPU with
tiny models; on TPU the same code serves the full configs (the dry-run proves
the sharded lowering). Prompt lengths are bucketed to powers of two to bound
jit recompilation; `warmup()` precompiles the variants an arrival pattern
will need so the first serving window is not dominated by XLA compiles.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.models.paged_cache import PageAllocator
from repro.serving.requests import BoundedRecord
from repro.serving.sampler import SamplerConfig, sample, token_logprob


def _bucket(n: int, lo: int = 32) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


def _pow2_bucket(n: int, hi: int) -> int:
    """Power-of-two bucket from 1, clamped to `hi` — upload widths (swap
    promote) and other small counts whose jit variants must stay bounded."""
    b = 1
    while b < n:
        b *= 2
    return min(b, hi)


# ---------------------------------------------------------------------------
# Jitted entry points, shared across engine instances. ModelConfig is a
# frozen dataclass (hashable), so engines with the same config — the edge
# fleet, A/B dense-vs-paged pairs, short-lived benchmark engines — reuse one
# trace cache instead of recompiling per instance.
# ---------------------------------------------------------------------------

def _prefill_dense_fn(cfg, params, tokens, cache, lengths):
    return transformer.prefill(cfg, params, tokens, cache,
                               prompt_lengths=lengths)


def _score_fn(cfg, params, tokens):
    """Teacher-forced mean logprob of tokens[1:] given tokens[:-1]."""
    logits, _ = transformer.forward(cfg, params, tokens[None, :-1])
    logp = jax.nn.log_softmax(logits[0].astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logp, tokens[1:][:, None], axis=-1)[:, 0]
    return jnp.mean(gold), gold


def _insert_fn(big, one, slot):
    """Insert a batch-1 cache into slot `slot` of the big cache.
    Cache layout: lengths (B,); segment leaves (L, B, ...) — batch axis 1."""
    out = {"lengths": jax.lax.dynamic_update_slice(
        big["lengths"], one["lengths"].astype(big["lengths"].dtype), (slot,))}
    segs = []
    for bseg, oseg in zip(big["segments"], one["segments"]):
        seg = {}
        for k in bseg:
            idx = (0, slot) + (0,) * (bseg[k].ndim - 2)
            seg[k] = jax.lax.dynamic_update_slice(
                bseg[k], oseg[k].astype(bseg[k].dtype), idx)
        segs.append(seg)
    out["segments"] = segs
    return out


def _decode_dense_fn(cfg, params, tokens, cache, active):
    return transformer.decode_step(cfg, params, tokens, cache, active=active)


def _decode_paged_fn(cfg, live_pages, params, tokens, cache, active):
    return transformer.decode_step_paged(cfg, params, tokens, cache,
                                         active=active,
                                         live_pages=live_pages)


def _prefill_chunk_fn(cfg, live_pages, params, tokens, cache, slot, offset,
                      chunk_len):
    return transformer.prefill_chunk_paged(cfg, params, tokens, cache, slot,
                                           offset, chunk_len,
                                           live_pages=live_pages)


def _prefill_ragged_fn(cfg, live_pages, params, tokens, cache, slots, offsets,
                       lens):
    return transformer.prefill_ragged_paged(cfg, params, tokens, cache, slots,
                                            offsets, lens,
                                            live_pages=live_pages)


def _promote_fn(cfg, cache, upload_ids, payloads, slot, ctx_len):
    return transformer.promote_slot_paged(cfg, cache, upload_ids, payloads,
                                          slot, ctx_len)


# The "run" half of the plan/run decode step: model step + PRNG split +
# sample + logprob fused into ONE dispatch, returning device arrays the
# engine reads back a full step later (deferred harvest). The split/sample
# sequence is written exactly as the eager path ran it, so fused and eager
# draws are bitwise identical.

def _decode_dense_run_fn(cfg, sampler, params, tokens, cache, active, key):
    logits, cache = transformer.decode_step(cfg, params, tokens, cache,
                                            active=active)
    key, sub = jax.random.split(key)
    toks = sample(logits, sub, sampler)
    lps = token_logprob(logits, toks)
    return toks, lps, key, cache


def _decode_paged_run_fn(cfg, sampler, live_pages, params, tokens, cache,
                         active, key):
    logits, cache = transformer.decode_step_paged(cfg, params, tokens, cache,
                                                  active=active,
                                                  live_pages=live_pages)
    key, sub = jax.random.split(key)
    toks = sample(logits, sub, sampler)
    lps = token_logprob(logits, toks)
    return toks, lps, key, cache


@functools.lru_cache(maxsize=None)
def _jitted(cfg: ModelConfig, kind: str,
            sampler: Optional[SamplerConfig] = None):
    if kind == "decode":
        return jax.jit(functools.partial(_decode_dense_fn, cfg))
    if kind == "decode_paged":
        # live_pages is static (the read width is a shape); the engine
        # buckets it to powers of two, so recompiles are bounded by
        # log2(max_pages_per_seq) variants per config
        return jax.jit(functools.partial(_decode_paged_fn, cfg),
                       static_argnums=(0,), donate_argnums=(3,))
    if kind == "decode_run":
        # SamplerConfig is frozen/hashable, so the fused variants share the
        # lru_cache exactly like cfg does
        return jax.jit(functools.partial(_decode_dense_run_fn, cfg, sampler),
                       donate_argnums=(2,))
    if kind == "decode_paged_run":
        return jax.jit(functools.partial(_decode_paged_run_fn, cfg, sampler),
                       static_argnums=(0,), donate_argnums=(3,))
    if kind == "prefill":
        return jax.jit(functools.partial(_prefill_dense_fn, cfg))
    if kind == "prefill_paged":
        return jax.jit(functools.partial(transformer.prefill_paged, cfg),
                       donate_argnums=(2,))
    if kind == "prefill_chunk":
        # live_pages is static (the read width is a shape), bucketed like
        # the decode step; token shape is always (1, cfg.prefill_chunk), so
        # chunked engines compile one chunk variant per live-width bucket
        # instead of one prefill per prompt-length bucket
        return jax.jit(functools.partial(_prefill_chunk_fn, cfg),
                       static_argnums=(0,), donate_argnums=(3,))
    if kind == "prefill_ragged":
        # batched ragged ingest: one call advances EVERY ingesting slot's
        # next chunk; row count is bucketed to powers of two (lo=1), so
        # variants are bounded by log2(max_batch) x log2(live widths)
        return jax.jit(functools.partial(_prefill_ragged_fn, cfg),
                       static_argnums=(0,), donate_argnums=(3,))
    if kind == "promote":
        # swap-in scatter (host-tier resume): the upload width U is a shape
        # the engine buckets with _pow2_bucket, so variants are bounded by
        # log2(pages_per_seq) per config
        return jax.jit(functools.partial(_promote_fn, cfg),
                       donate_argnums=(0,))
    if kind == "fork":
        return jax.jit(functools.partial(transformer.fork_slot_paged, cfg),
                       donate_argnums=(0,))
    if kind == "insert":
        return jax.jit(_insert_fn, donate_argnums=(0,))
    if kind == "score":
        return jax.jit(functools.partial(_score_fn, cfg))
    raise ValueError(kind)


@dataclasses.dataclass
class Slot:
    req_id: int = -1
    active: bool = False
    tokens: List[int] = dataclasses.field(default_factory=list)
    logprobs: List[float] = dataclasses.field(default_factory=list)
    max_new: int = 0
    generated: int = 0
    prompt: List[int] = dataclasses.field(default_factory=list)
    ctx_len: int = 0        # tokens currently in the KV cache for this slot
    arrival: int = 0        # admission order (eviction picks the youngest)
    evicted: bool = False   # preempted: requeue instead of completing
    parked: bool = False    # holds a shared prefix for forking, not decoding
    # suffix tokens still to be teacher-forced into the cache (fork path):
    # each decode step feeds pending[0] instead of the last sampled token
    pending: List[int] = dataclasses.field(default_factory=list)
    fork_src: int = -1      # parked slot this one was forked from (-1: none)
    suffix: List[int] = dataclasses.field(default_factory=list)
    # prompt tokens not yet ingested (chunked prefill): while non-empty the
    # slot is excluded from the decode batch and step() feeds it one chunk
    # at a time; the first sample comes from the final chunk's logits
    prefill_toks: List[int] = dataclasses.field(default_factory=list)
    # eviction priority (higher = more latency-critical, evicted last);
    # PICE maps cloud-sketch / SLA-bound work above opportunistic
    # ensemble expansions
    priority: int = 0
    # the admitted prompt was longer than max_len and kept only its tail
    # (surfaced so callers can tell a truncated completion from a full one;
    # eviction-resume replays the same truncation deterministically)
    truncated: bool = False


@dataclasses.dataclass
class StepPlan:
    """Host-side decode plan: every decision one decode step needs, computed
    with numpy only (the "plan" half of the plan/run split — flashinfer's
    plan/run and vLLM's scheduler are the precedents). Token-independent
    state (ctx_len advance, pending-suffix pops) is applied AT PLAN TIME;
    only the sampled token's commit waits for the deferred harvest, so the
    host can plan step N+1 while the device still runs step N."""
    active_ids: List[int]           # slots in this decode batch
    last: np.ndarray                # (B, 1) int32 decode inputs
    mask: np.ndarray                # (B,) bool active-row mask
    live: int                       # paged: static live-width bucket (0=dense)
    commits: List[int]              # slots whose sampled token commits later


@dataclasses.dataclass
class _Resume:
    """A queued request: fresh, or preempted with its generated prefix
    carried. share_from >= 0 routes admission through the COW fork path
    (prompt then holds the full prefix+suffix fallback for eviction resume).
    """
    req_id: int
    prompt: List[int]
    max_new: int
    carry_tokens: List[int]
    carry_lps: List[float]
    share_from: int = -1
    suffix: List[int] = dataclasses.field(default_factory=list)
    priority: int = 0
    # host-tier swap payload (paged backend, host_swap): the victim's page
    # bytes (+ quant scales) snapshotted at demotion, one dict per attention
    # segment, plus the slot state a promote restores verbatim. Non-None
    # routes admission through `_admit_swapped` (single-upload promote and
    # direct decode re-entry) instead of a prefill replay.
    swap: Optional[dict] = None


# Public name for the request-handle admission API (`InferenceEngine
# .try_admit`): the serving front-end builds these for fresh submissions.
EngineRequest = _Resume


class InferenceEngine:
    """Continuous-batching engine for one model."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_len: int = 1024, sampler: SamplerConfig = SamplerConfig(),
                 eos_id: int = 0, name: str = "engine",
                 kv_backend: str = "dense", page_size: int = 32,
                 n_pages: Optional[int] = None, prefix_sharing: bool = True,
                 ragged_ingest: bool = True, host_swap: bool = True):
        assert kv_backend in ("dense", "paged"), kv_backend
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.sampler = sampler
        self.eos_id = eos_id
        self.name = name
        self.kv_backend = kv_backend
        # escape hatch: prefix_sharing=False makes generate_fanout submit
        # monolithically, restoring exact dense<->paged A/B at the pipeline
        # level (the fork path's teacher-forced suffixes are a different —
        # equally valid — float reduction order than one monolithic prefill)
        self.prefix_sharing = prefix_sharing
        # escape hatch: ragged_ingest=False keeps the legacy one-chunk-per-
        # step ingest scheduler (A/B reference for the batched ragged path)
        self.ragged_ingest = ragged_ingest
        self.slots = [Slot() for _ in range(max_batch)]
        self.key = jax.random.PRNGKey(0)
        self.tokens_generated = 0
        self.busy_s = 0.0
        self._arrivals = 0
        self.evictions = 0
        self.peak_pages = 0
        self._window_peak = 0
        self._window_shared = 0
        self._window_logical = 0
        self._resume_queue: List[_Resume] = []
        self._prefix_logits: Dict[int, jax.Array] = {}   # parked slot -> (1,V)
        # per-request time-to-first-token telemetry: admission time survives
        # eviction/resume (TTFT spans the preemption), recorded once at the
        # first committed token; benchmarks read + clear `ttft`
        self._t_admit: Dict[int, float] = {}
        self._admit_stamp_cap = 4096
        # req_ids a _run loop is still driving: their admission stamps must
        # never be pruned even while they sit evicted in the resume queue
        self._inflight: set = set()
        self.ttft: Dict[int, float] = BoundedRecord(self._admit_stamp_cap)
        # req_id -> prompt tokens dropped at admission (prompt > max_len);
        # the matching Slot carries `truncated` while it lives
        self.truncations: Dict[int, int] = BoundedRecord(self._admit_stamp_cap)
        self.prefill_chunk = 0
        # deferred harvest: (commit slots, device toks, device lps) of the
        # decode step dispatched last step(), read back at the next step()
        self._pending_decode: Optional[Tuple[List[int], jax.Array,
                                             jax.Array]] = None
        self._table_dirty = False
        # host-tier swap telemetry (paged backend, host_swap)
        self.swap_outs = 0
        self.swap_ins = 0
        self.swap_bytes = 0         # host<->device bytes moved by swaps
        # decode/ingest KV read traffic in bytes (pages touched per step x
        # per-page pool+scale bytes): the signal the kv_dtype A/B benches
        # compare — int8 pools shrink it ~2x against bf16
        self.kv_bytes_read = 0
        self._page_kv_bytes = 0
        self.host_swap = False
        # fault-injection surfaces (serving/faults.py): step_hook(engine) is
        # called at the top of every step() and may cancel slots, stall, or
        # raise EngineCrash; swap_fault_hook(req_id) -> True marks a swap
        # promote's upload as lost, degrading that resume to evict-and-replay
        self.step_hook = None
        self.swap_fault_hook = None
        # cancellation / degradation telemetry
        self.cancels = 0
        self.deadline_cancels = 0
        self.swap_losses = 0

        if kv_backend == "paged":
            cfg.validate_paged(page_size, max_len)
            self.page_size = page_size
            self.pages_per_seq = max_len // page_size
            self.n_pages = n_pages or max_batch * self.pages_per_seq
            self.alloc = PageAllocator(self.n_pages, page_size,
                                       self.pages_per_seq)
            self.block_table = np.full((max_batch, self.pages_per_seq), -1,
                                       np.int32)
            self.cache = transformer.init_paged_cache(
                cfg, max_batch, self.n_pages, page_size, self.pages_per_seq)
            self._push_table()
            self._decode_run = _jitted(cfg, "decode_paged_run", sampler)
            self._prefill_paged = _jitted(cfg, "prefill_paged")
            self._fork = _jitted(cfg, "fork")
            # chunked prefill needs an attention-only stack (recurrent
            # segments cannot resume their scan state mid-prompt): other
            # families silently keep the monolithic path
            chunkable = all(
                kind in ("attn", "moe", "shared_attn")
                for kind, _ in transformer.segments_of(cfg))
            self.prefill_chunk = cfg.prefill_chunk if chunkable else 0
            if self.prefill_chunk:
                self._prefill_chunk = _jitted(cfg, "prefill_chunk")
                self._prefill_ragged = _jitted(cfg, "prefill_ragged")
            # host-tier page swap (demote on eviction, promote on resume)
            # rides the same attention-only gate as chunked prefill:
            # recurrent segments would need their dense scan states
            # snapshotted too, so those families keep evict-and-replay
            self.host_swap = host_swap and chunkable
            if self.host_swap:
                self._promote = _jitted(cfg, "promote")
            # bytes one physical page contributes across every attention
            # segment's pool + scale leaves (drives kv_bytes_read)
            per_page = 0
            for seg in self.cache["segments"]:
                if "k_pages" not in seg:
                    continue
                for k in seg:
                    n = seg[k].shape[0] * seg[k].dtype.itemsize
                    for d in seg[k].shape[2:]:
                        n *= d
                    per_page += n
            self._page_kv_bytes = per_page
        else:
            assert not cfg.kv_quantized, \
                "kv_dtype quantization needs the paged backend"
            self.cache = transformer.init_cache(cfg, max_batch, max_len)
            self._decode_run = _jitted(cfg, "decode_run", sampler)
            self._prefill = _jitted(cfg, "prefill")
            self._insert = _jitted(cfg, "insert")
        self._score = _jitted(cfg, "score")

    # ------------------------------------------------------------------
    # Paged-backend bookkeeping
    # ------------------------------------------------------------------
    def _push_table(self):
        self.cache["block_table"] = jnp.asarray(self.block_table)
        self._table_dirty = False

    def _mark_table_dirty(self):
        """Host block-table edits are batched: step() pushes the table to
        the device at most ONCE per step (`_sync_table`), right before the
        first dispatch that reads it. Deferring a freed slot's row clear is
        safe because decode writes are active-masked (see pc.write_token)
        and masked rows' reads are discarded."""
        self._table_dirty = True

    def _sync_table(self):
        if self._table_dirty:
            self._push_table()

    def _occupancy(self) -> Tuple[int, int, int]:
        """(physical, shared, logical) occupancy right now. Dense slots are
        counted as one "page" each with no sharing."""
        if self.kv_backend == "paged":
            return (self.alloc.pages_in_use, self.alloc.pages_shared,
                    self.alloc.logical_pages)
        used = sum(1 for s in self.slots if s.active)
        return used, 0, used

    def _track_peak(self):
        used, shared, logical = self._occupancy()
        self.peak_pages = max(self.peak_pages, used)
        self._window_peak = max(self._window_peak, used)
        self._window_shared = max(self._window_shared, shared)
        self._window_logical = max(self._window_logical, logical)

    def consume_window(self) -> Dict[str, int]:
        """High-water occupancy since the last call, then reset the window.
        The PICE pipeline is synchronous — pools drain to zero between
        requests — so instantaneous occupancy is always 0 at observation
        time; the windowed peak is the pressure signal that survives. Both
        backends window: a dense fleet otherwise always reports ~0 active
        slots between synchronous requests."""
        self._track_peak()
        out = {"pages": self._window_peak, "shared": self._window_shared,
               "logical": self._window_logical}
        (self._window_peak, self._window_shared,
         self._window_logical) = self._occupancy()
        return out

    def consume_peak(self) -> int:
        """Windowed physical peak (see consume_window)."""
        return self.consume_window()["pages"]

    def _release_slot_pages(self, slot: int):
        self.alloc.release(slot)
        self.block_table[slot, :] = -1
        self._mark_table_dirty()

    def _evict_victim(self, protect: int) -> bool:
        """Preempt one active slot other than `protect`: the lowest-priority
        one, youngest-first within a priority class. Latency-critical work
        (cloud sketches, SLA-bound requests — higher `priority`) is only
        preempted once every opportunistic expansion is gone, so a parallel
        fan-out can never push a critical slot off the pool. Victims' pages
        return to the pool and the request is queued for resubmission."""
        victims = [i for i, s in enumerate(self.slots)
                   if s.active and i != protect]
        if not victims:
            return False
        v = min(victims,
                key=lambda i: (self.slots[i].priority,
                               -self.slots[i].arrival))
        s = self.slots[v]
        if self.host_swap:
            # demote instead of free-and-replay: the victim's uniquely-owned
            # pages move to the host tier as raw storage bytes (+ quant
            # scales), shared prefix pages stay resident with a held
            # reference (COW siblings cannot free them). Resume promotes
            # the bytes back with one scatter and decode re-enters directly
            # — no prefill replay and no PRNG draw; the restore is
            # byte-exact, so greedy continuations are bit-identical to an
            # uninterrupted run.
            swapped = self.alloc.demote(v, s.req_id)
            ids = np.asarray([p for _, p in swapped], np.int32)
            # snapshot from the CURRENT (immutable) cache value: the last
            # dispatch that wrote these pages was harvested at step start,
            # and demote's freed ids cannot be re-written before the next
            # dispatch, which this plan phase precedes
            # repro-analysis: disable=RA103 reason=eviction swap-out snapshot; one batched readback per demotion, off the decode hot loop
            host = jax.device_get(
                [{k: seg[k][:, ids] for k in seg}
                 for seg in self.cache["segments"] if "k_pages" in seg])
            self.swap_outs += 1
            self.swap_bytes += sum(a.nbytes for seg in host
                                   for a in seg.values())
            self._resume_queue.append(_Resume(
                req_id=s.req_id, prompt=list(s.prompt),
                max_new=s.max_new, carry_tokens=list(s.tokens),
                carry_lps=list(s.logprobs), priority=s.priority,
                swap={"host": host, "ctx_len": s.ctx_len,
                      "pending": list(s.pending),
                      "prefill_toks": list(s.prefill_toks),
                      "fork_src": s.fork_src, "suffix": list(s.suffix),
                      "truncated": s.truncated}))
            self.block_table[v, :] = -1
            self._mark_table_dirty()
        else:
            # release only frees the victim's *unique* pages (refcounted),
            # never prefix pages its siblings still read. A fork whose
            # prefix is still parked resumes through the fork path
            # (replaying suffix + generated tokens through decode rebuilds
            # bit-identical KV without a second prefix prefill); otherwise
            # s.prompt holds the full prefix+suffix for a monolithic
            # resume.
            refork = (0 <= s.fork_src < self.max_batch
                      and self.slots[s.fork_src].parked)
            self._resume_queue.append(_Resume(
                req_id=s.req_id, prompt=list(s.prompt),
                max_new=s.max_new, carry_tokens=list(s.tokens),
                carry_lps=list(s.logprobs),
                share_from=s.fork_src if refork else -1,
                suffix=list(s.suffix) if refork else [],
                priority=s.priority))
            self._release_slot_pages(v)
        s.active, s.evicted, s.req_id = False, True, -1
        s.pending, s.fork_src, s.suffix = [], -1, []
        s.prefill_toks = []     # a mid-prefill victim restarts its chunks
        self.evictions += 1
        return True

    def cancel(self, req_id: int) -> bool:
        """Cancel a mid-flight request: ingesting, decoding, evicted-and-
        queued, or demoted to the host tier. Frees its pages (COW refcounts
        protect shared prefix pages), drops any host-tier snapshot, and
        prunes its slot from the deferred-harvest commit list so a slot
        reused by a later admission can never receive the cancelled
        request's in-flight token. Surviving requests are untouched:
        per-row attention reads only the survivor's own block-table row,
        decode writes are active-masked, and the engine PRNG key advances
        per step regardless of which rows are active — so survivors'
        outputs are bit-identical to a run without the cancellation.

        Returns True if the request was found in any live state. The slot
        keeps its partial tokens so a driving `_run` loop collects them as
        the (truncated) result."""
        hit = False
        for i, s in enumerate(self.slots):
            if s.active and s.req_id == req_id:
                s.active = False
                s.evicted = False
                s.pending, s.prefill_toks = [], []
                s.fork_src, s.suffix = -1, []
                if self.kv_backend == "paged":
                    self._release_slot_pages(i)
                if self._pending_decode is not None:
                    commits, toks, lps = self._pending_decode
                    if i in commits:
                        # the harvest guard alone is not enough: a request
                        # admitted into this slot before the next harvest
                        # would satisfy `slots[i].active` and absorb the
                        # cancelled request's token
                        self._pending_decode = (
                            [c for c in commits if c != i], toks, lps)
                hit = True
        kept = []
        for r in self._resume_queue:
            if r.req_id != req_id:
                kept.append(r)
                continue
            if r.swap is not None:
                self.alloc.drop_hosted(r.req_id)
            hit = True
        self._resume_queue = kept
        if hit:
            self.cancels += 1
            self._t_admit.pop(req_id, None)
        return hit

    def abort_all(self) -> int:
        """Cancel every live request — the recovery path after an injected
        (or real) engine crash mid-`_run`: pages return to the pool, host-
        tier snapshots are dropped, and the in-flight decode's commits are
        discarded. Parked prefix slots are left alone (their owner's
        `generate_fanout` finally-block releases them). Returns the number
        of requests aborted."""
        n = 0
        for s in list(self.slots):
            if s.active:
                self.cancel(s.req_id)
                n += 1
        for r in list(self._resume_queue):
            self.cancel(r.req_id)
            n += 1
        self._pending_decode = None
        return n

    def memory_stats(self) -> Dict[str, float]:
        """Engine-level KV memory telemetry (for RuntimeMonitor).

        `pages_shared` counts physical pages referenced by >1 slot;
        `pages_logical` is the sum of per-slot chains (what an unshared
        layout would hold) — logical - in_use is the COW saving."""
        if self.kv_backend == "paged":
            return {"backend": "paged", "pages_total": self.n_pages,
                    "pages_in_use": self.alloc.pages_in_use,
                    "pages_shared": self.alloc.pages_shared,
                    "pages_logical": self.alloc.logical_pages,
                    "peak_pages": self.peak_pages,
                    "utilization": self.alloc.utilization,
                    "evictions": self.evictions}
        used = sum(1 for s in self.slots if s.active)
        return {"backend": "dense", "pages_total": self.max_batch,
                "pages_in_use": used, "pages_shared": 0,
                "pages_logical": used, "peak_pages": self.max_batch,
                "utilization": used / self.max_batch, "evictions": 0}

    def can_admit(self, prompt_len: int) -> bool:
        """Admission check against real memory, not just a fixed max_batch."""
        if not self.free_slots():
            return False
        if self.kv_backend == "paged":
            need = max(1, -(-min(prompt_len, self.max_len) // self.page_size))
            return len(self.alloc.free) >= need
        return True

    def can_admit_fork(self, src_slot: int, extra_tokens: int = 0) -> bool:
        """Admission check for the fork path: a free batch row plus enough
        free pages for the tail copy AND the suffix/carry replay
        (extra_tokens). Gating on the full replay need — like `can_admit`
        gates on the full prompt — prevents admit/evict livelock between
        sibling forks under a tight pool."""
        if not self.free_slots():
            return False
        src = self.slots[src_slot]
        total = min(src.ctx_len + extra_tokens, self.max_len)
        full_shared = src.ctx_len // self.page_size
        need = -(-total // self.page_size) - full_shared
        return len(self.alloc.free) >= need

    def can_admit_swap(self, req_id: int) -> bool:
        """Admission check for a demoted request: a free batch row plus
        enough free pages to re-house every swapped page (resident shared
        pages are already held by the hosted entry)."""
        if not self.free_slots():
            return False
        return len(self.alloc.free) >= self.alloc.hosted_pages(req_id)

    def _admit_swapped(self, r: _Resume) -> int:
        """Re-admit a demoted request by promoting its host-tier pages:
        allocate fresh device pages, upload the snapshotted bytes in ONE
        scatter (`promote_slot_paged`, upload width bucketed), rebuild the
        block-table row, and restore the slot so the next step's decode
        continues from the last sampled token. Versus the replay path this
        trades a host->device transfer of the swapped bytes for the whole
        prefill recompute (see docs/serving.md for the crossover)."""
        slot = self.free_slots()[0]
        t0 = time.perf_counter()
        self._t_admit.setdefault(r.req_id, t0)
        self._prune_admit_stamps()
        uploads = self.alloc.promote(r.req_id, slot)    # MemoryError if dry
        chain = self.alloc.owned[slot]
        self.block_table[slot, :] = -1
        self.block_table[slot, :len(chain)] = chain
        self._mark_table_dirty()
        sw = r.swap
        U = _pow2_bucket(max(len(uploads), 1), self.pages_per_seq)
        ids = np.full((U,), self.n_pages, np.int32)     # padding ids drop
        ids[:len(uploads)] = [p for _, p in uploads]
        payloads = []
        for seg in sw["host"]:
            pay = {}
            for k, arr in seg.items():
                buf = np.zeros((arr.shape[0], U) + arr.shape[2:], arr.dtype)
                buf[:, :arr.shape[1]] = arr
                pay[k] = jnp.asarray(buf)
            payloads.append(pay)
        self.cache = self._promote(
            self.cache, jnp.asarray(ids), payloads,
            jnp.asarray(slot, jnp.int32),
            jnp.asarray(sw["ctx_len"], jnp.int32))
        self.swap_ins += 1
        self.swap_bytes += sum(a.nbytes for seg in sw["host"]
                               for a in seg.values())
        s = self.slots[slot]
        s.req_id, s.active = r.req_id, True
        s.prompt = list(r.prompt)
        s.tokens, s.logprobs = list(r.carry_tokens), list(r.carry_lps)
        s.max_new, s.generated = r.max_new, len(r.carry_tokens)
        s.ctx_len = sw["ctx_len"]
        s.pending = list(sw["pending"])
        s.prefill_toks = list(sw["prefill_toks"])
        s.fork_src, s.suffix = sw["fork_src"], list(sw["suffix"])
        s.evicted, s.priority = False, r.priority
        s.truncated = sw["truncated"]
        s.arrival = self._arrivals
        self._arrivals += 1
        self._track_peak()
        self.busy_s += time.perf_counter() - t0
        return slot

    def _live_pages(self, active: List[int]) -> int:
        """Static read width for this decode step: enough block-table
        columns to cover every active slot's cache plus the token being
        written, bucketed to the next power of two so jit variants stay
        bounded. Trimmed columns are past every slot's valid positions and
        carry exactly-zero attention weight, so any covering width is
        bit-identical — this only stops the read path from paying for
        `max_pages_per_seq` when the batch is short."""
        return self._chunk_live(max(self.slots[i].ctx_len
                                    for i in active) + 1)

    # ------------------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots)
                if not s.active and not s.parked]

    def _alloc_slot_pages(self, slot: int, n_tokens: int):
        """Map a fresh page chain for `n_tokens` into the slot's table row."""
        pages = self.alloc.alloc_for(slot, n_tokens)    # MemoryError if dry
        self._track_peak()
        self.block_table[slot, :] = -1
        self.block_table[slot, :len(pages)] = pages
        self._mark_table_dirty()

    def _chunk_live(self, end: int) -> int:
        """Static covering read width through position `end`, bucketed to
        the next power of two (shared by the decode step and chunk ingest
        so both paths honor one recompile contract)."""
        need = -(-min(end, self.max_len) // self.page_size)
        live = 1
        while live < need:
            live *= 2
        return min(live, self.pages_per_seq)

    def _feed_chunk(self, slot: int, chunk: List[int], offset: int):
        """One (1, prefill_chunk)-shaped ingest call: pad, pick the covering
        live width, write+attend the chunk at `offset`. Returns the chunk's
        last-valid-token logits (1, V)."""
        padded = np.zeros((1, self.prefill_chunk), np.int32)
        padded[0, :len(chunk)] = chunk
        live = self._chunk_live(offset + len(chunk))
        self._sync_table()
        logits, self.cache = self._prefill_chunk(
            live, self.params, jnp.asarray(padded), self.cache,
            jnp.asarray(slot, jnp.int32), jnp.asarray(offset, jnp.int32),
            jnp.asarray(len(chunk), jnp.int32))
        return logits

    def _ingest_chunk(self, slot: int):
        """Feed the slot's next prompt chunk into the paged cache. After the
        final chunk, the first token is sampled from the chunk's logits —
        the same (1, V) sample a monolithic `add_request` takes, so the
        engine's PRNG stream (and therefore sampled output) is unchanged."""
        s = self.slots[slot]
        chunk = s.prefill_toks[:self.prefill_chunk]
        s.prefill_toks = s.prefill_toks[self.prefill_chunk:]
        logits = self._feed_chunk(slot, chunk, s.ctx_len)
        s.ctx_len += len(chunk)
        if not s.prefill_toks:
            self.key, sub = jax.random.split(self.key)
            tok = sample(logits, sub, self.sampler)
            lp = token_logprob(logits, tok)
            # repro-analysis: disable=RA103 reason=admission-time first-token draw; one batched readback, off the decode loop
            tok_h, lp_h = jax.device_get((tok, lp))
            self._commit(slot, int(tok_h[0]), float(lp_h[0]))
        return logits

    def _prefill_into_chunks(self, slot: int, toks: List[int]):
        """Synchronous chunked ingest of a whole prompt (prefill_prefix and
        direct callers outside the step loop); returns final-chunk logits.
        Performs no PRNG splits, matching `_ingest_chunk`'s contract that
        only the first-token sample advances the key stream. An empty
        prompt ingests one zero-length chunk so callers always get logits
        (matching the monolithic path's zero-padded prefill)."""
        C = self.prefill_chunk
        logits = None
        for start in range(0, max(len(toks), 1), C):
            logits = self._feed_chunk(slot, toks[start:start + C], start)
        return logits

    def _prefill_into(self, slot: int, toks: List[int], padded: np.ndarray):
        """Prefill `toks` into batch row `slot` (either backend); returns
        last-token logits (1, V)."""
        if self.kv_backend == "paged":
            self._alloc_slot_pages(slot, len(toks))
            if self.prefill_chunk:
                return self._prefill_into_chunks(slot, toks)
            self._sync_table()
            logits, self.cache = self._prefill_paged(
                self.params, jnp.asarray(padded), self.cache,
                jnp.asarray(slot, jnp.int32),
                jnp.asarray(len(toks), jnp.int32))
        else:
            one_cache = transformer.init_cache(self.cfg, 1, self.max_len)
            logits, one_cache = self._prefill(
                self.params, jnp.asarray(padded), one_cache,
                jnp.asarray([len(toks)], jnp.int32))
            self.cache = self._insert(self.cache, one_cache, slot)
        return logits

    @staticmethod
    def _pad_prompt(full_prompt: List[int], max_len: int):
        """Bucket-pad a prompt, keeping the TAIL when it exceeds max_len
        (generation conditions on the most recent context). Returns
        (kept_tokens, padded, dropped) — `dropped` > 0 surfaces the
        truncation instead of silently shortening the prompt; callers
        record it so an eviction-resume replays the identical truncation."""
        S = min(_bucket(len(full_prompt)), max_len)
        padded = np.zeros((1, S), np.int32)
        toks = full_prompt[-S:]
        padded[0, :len(toks)] = toks
        return toks, padded, len(full_prompt) - len(toks)

    # ------------------------------------------------------------------
    # Prefix sharing (PICE sketch fan-out): prefill the shared (query,
    # sketch) prefix ONCE into a parked slot, then fork N copy-on-write
    # block-table rows off it — full prefix pages are shared refcounted,
    # only the partial tail page is copied per fork.
    # ------------------------------------------------------------------
    def prefill_prefix(self, prefix: List[int]) -> int:
        """Prefill a shared prefix into a parked slot and return its id for
        `add_request(..., share_from=slot)`. The slot holds its pages (and
        is excluded from scheduling) until `release_prefix`."""
        assert self.kv_backend == "paged", \
            "prefix sharing needs the paged backend"
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free slot")
        # park in the LAST free slot: forks then land on the same batch rows
        # as independent submissions would, keeping the per-row PRNG draws —
        # and therefore sampled outputs — bit-identical to the unshared path
        slot = free[-1]
        t0 = time.perf_counter()
        toks, padded, _ = self._pad_prompt(list(prefix), self.max_len)
        logits = self._prefill_into(slot, toks, padded)
        s = self.slots[slot]
        s.req_id, s.active, s.parked = -1, False, True
        s.prompt = list(prefix)
        s.tokens, s.logprobs, s.pending = [], [], []
        s.prefill_toks = []
        s.ctx_len = len(toks)
        self._prefix_logits[slot] = logits
        self.busy_s += time.perf_counter() - t0
        return slot

    def release_prefix(self, slot: int) -> None:
        """Free a parked prefix slot; pages shared with live forks survive
        via their refcounts."""
        s = self.slots[slot]
        assert s.parked, "release_prefix on a non-parked slot"
        s.parked = False
        self._prefix_logits.pop(slot, None)
        self._release_slot_pages(slot)

    def add_request(self, req_id: int, prompt: List[int], max_new: int,
                    carry_tokens: Optional[List[int]] = None,
                    carry_lps: Optional[List[float]] = None,
                    share_from: Optional[int] = None,
                    suffix: Optional[List[int]] = None,
                    priority: int = 0) -> int:
        """Admit a request. share_from forks a parked prefix slot
        copy-on-write instead of prefilling; `suffix` tokens (the part of
        the logical prompt beyond the shared prefix) are then ingested into
        the cache before sampling starts — as are any carried tokens when a
        preempted fork resumes. `prompt` must be the full logical prompt
        (prefix + suffix) so eviction can always fall back to a monolithic
        resume. `priority` orders eviction: lower-priority slots are
        preempted first (see `_evict_victim`).

        With `cfg.prefill_chunk` set (paged backend), admission only maps
        the prompt's pages and queues its tokens: `step()` then ingests one
        chunk per call interleaved with the decode batch, so a long prompt
        never stalls running decodes for more than one chunk. Fork suffixes
        and resume carries ride the same chunked path (multi-token ingest)
        instead of token-by-token teacher forcing."""
        suffix = list(suffix or [])
        carry_tokens = carry_tokens or []
        carry_lps = carry_lps or []
        if share_from is not None:
            src = self.slots[share_from]
            assert self.kv_backend == "paged", \
                "prefix sharing needs the paged backend"
            assert src.parked and share_from in self._prefix_logits, \
                "share_from must be a parked prefill_prefix slot"
            if src.ctx_len + len(suffix) + len(carry_tokens) > self.max_len:
                share_from = None       # would overflow: prefill monolithically
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free slot")
        slot = free[0]
        t0 = time.perf_counter()
        self._t_admit.setdefault(req_id, t0)
        self._prune_admit_stamps()

        dropped = 0
        ingest: List[int] = []          # chunked path: tokens step() feeds
        logits = None
        if share_from is not None:
            src = self.slots[share_from]
            # MemoryError if the tail copy cannot be allocated
            dst_pages, tail_src, tail_dst = self.alloc.fork(
                share_from, slot, src.ctx_len)
            self._track_peak()
            self.block_table[slot, :] = -1
            self.block_table[slot, :len(dst_pages)] = dst_pages
            self._mark_table_dirty()
            self.cache = self._fork(
                self.cache, jnp.asarray(share_from, jnp.int32),
                jnp.asarray(slot, jnp.int32),
                jnp.asarray(tail_src, jnp.int32),
                jnp.asarray(tail_dst, jnp.int32))
            logits = self._prefix_logits[share_from]
            ctx = src.ctx_len
            pending = suffix + carry_tokens
            if self.prefill_chunk and pending:
                # the replay goes through multi-token chunks: map the pages
                # it will write up front (can_admit_fork gated on this need)
                target = -(-min(ctx + len(pending), self.max_len)
                           // self.page_size)
                while len(self.alloc.owned[slot]) < target:
                    p = self.alloc.extend(
                        slot, (len(self.alloc.owned[slot]) + 1)
                        * self.page_size)
                    self.block_table[slot,
                                     len(self.alloc.owned[slot]) - 1] = p
                self._mark_table_dirty()
                self._track_peak()
                ingest, pending = pending, []
        elif self.prefill_chunk:
            full = list(prompt) + carry_tokens
            toks = full[-self.max_len:]
            dropped = len(full) - len(toks)
            self._alloc_slot_pages(slot, len(toks))
            ctx, pending, ingest = 0, [], list(toks)
            if not toks:
                # degenerate empty prompt: ingest one zero-length chunk now
                # so the sample below has logits (the monolithic path
                # likewise prefills a zero-padded buffer and samples)
                logits = self._prefill_into_chunks(slot, toks)
        else:
            toks, padded, dropped = self._pad_prompt(
                list(prompt) + carry_tokens, self.max_len)
            logits = self._prefill_into(slot, toks, padded)
            ctx = len(toks)
            pending = []

        s = self.slots[slot]
        s.req_id, s.active = req_id, True
        s.prompt = list(prompt)
        s.tokens, s.logprobs = list(carry_tokens), list(carry_lps)
        s.max_new, s.generated = max_new, len(carry_tokens)
        s.ctx_len = ctx
        s.pending = list(pending)
        s.prefill_toks = list(ingest)
        s.fork_src = share_from if share_from is not None else -1
        s.suffix = suffix if share_from is not None else []
        s.evicted = False
        s.priority = priority
        s.truncated = dropped > 0
        if dropped:
            # BoundedRecord evicts the oldest entries past the cap
            self.truncations[req_id] = dropped
        s.arrival = self._arrivals
        self._arrivals += 1
        self._track_peak()
        if not s.pending and not s.prefill_toks:
            # sample the first token from (possibly shared) prefill logits
            self.key, sub = jax.random.split(self.key)
            tok = sample(logits, sub, self.sampler)
            lp = token_logprob(logits, tok)
            # repro-analysis: disable=RA103 reason=admission-time first-token draw; one batched readback, off the decode loop
            tok_h, lp_h = jax.device_get((tok, lp))
            self._commit(slot, int(tok_h[0]), float(lp_h[0]))
        # else: the first sample comes after the last suffix/prompt token
        # is ingested
        self.busy_s += time.perf_counter() - t0
        return slot

    def _prune_admit_stamps(self):
        """Bound `_t_admit` without losing live requests' TTFT: only stamps
        with NO remaining reference — no active/ingesting slot, nothing in
        the resume queue, nothing a _run loop still drives — are evictable.
        (The old cap popped the OLDEST stamp, which under churn was exactly
        a preempted or still-queued request whose TTFT then silently never
        got recorded.)"""
        if len(self._t_admit) <= self._admit_stamp_cap:
            return
        live = {s.req_id for s in self.slots if s.active}
        live |= {r.req_id for r in self._resume_queue}
        live |= self._inflight
        for rid in list(self._t_admit):
            if len(self._t_admit) <= self._admit_stamp_cap:
                break
            if rid not in live:
                self._t_admit.pop(rid)

    def _commit(self, slot: int, tok: int, lp: float):
        s = self.slots[slot]
        s.tokens.append(tok)
        s.logprobs.append(lp)
        s.generated += 1
        self.tokens_generated += 1
        if s.generated == 1 and s.req_id in self._t_admit:
            # BoundedRecord keeps the most recent window in long-running
            # fleets (insertion order, oldest evicted past the cap)
            self.ttft[s.req_id] = (time.perf_counter()
                                   - self._t_admit.pop(s.req_id))
        # context capacity counts as completion: decoding past max_len would
        # overwrite live cache positions (in either backend), so both
        # backends stop at the same point and stay bit-identical
        if (tok == self.eos_id or s.generated >= s.max_new
                or s.ctx_len >= self.max_len):
            s.active = False
            if self.kv_backend == "paged":
                self._release_slot_pages(slot)

    def _grow_pages(self):
        """Before a decode step, make every active slot's next write target
        safe: copy-on-write any shared page the write would land in, and map
        a fresh page when the slot crosses a page boundary; evict the
        youngest request when the pool is dry. Raises MemoryError only if a
        lone request cannot grow."""
        changed = False
        for i, s in enumerate(self.slots):
            # slots mid-chunked-prefill hold pages for their whole prompt
            # already and are not in the decode batch — nothing to grow
            if not s.active or s.ctx_len >= self.max_len or s.prefill_toks:
                continue
            cow, cow_done = None, False
            while True:
                try:
                    if not cow_done:
                        cow = self.alloc.cow_page(i, s.ctx_len)
                        cow_done = True
                    newp = self.alloc.extend(i, s.ctx_len + 1)
                    break
                except MemoryError:
                    if not self._evict_victim(protect=i):
                        raise
            if cow is not None:
                old, new = cow
                self.block_table[i, s.ctx_len // self.page_size] = new
                # device-side page copy: fork op with src == dst slot
                self.cache = self._fork(
                    self.cache, jnp.asarray(i, jnp.int32),
                    jnp.asarray(i, jnp.int32), jnp.asarray(old, jnp.int32),
                    jnp.asarray(new, jnp.int32))
                changed = True
                self._track_peak()
            if newp is not None:
                n_owned = len(self.alloc.owned[i])
                self.block_table[i, n_owned - 1] = newp
                changed = True
                self._track_peak()
        if changed:
            self._mark_table_dirty()

    def _harvest(self) -> bool:
        """Read back and commit the decode step dispatched LAST step(). One
        `jax.device_get` on the whole (toks, lps) pair replaces the per-slot
        scalar syncs the old loop paid — and because the read happens a full
        step after the dispatch, the host's planning for step N+1 overlapped
        the device's work on step N."""
        if self._pending_decode is None:
            return False
        commits, toks_d, lps_d = self._pending_decode
        self._pending_decode = None
        t0 = time.perf_counter()
        toks, lps = jax.device_get((toks_d, lps_d))
        for i in commits:
            # in-engine nothing deactivates a slot between dispatch and
            # harvest; the guard covers direct _evict_victim calls (tests)
            if self.slots[i].active:
                self._commit(i, int(toks[i]), float(lps[i]))
        self.busy_s += time.perf_counter() - t0
        return True

    def _plan_decode(self, active_ids: List[int]) -> StepPlan:
        """Build this step's decode plan with numpy only. Token-independent
        slot state advances here (ctx_len, pending-suffix pops) — the
        values the eventual `_commit` termination checks read are exactly
        what the old inline loop saw; only the sampled token itself arrives
        later, at harvest."""
        last = np.zeros((self.max_batch, 1), np.int32)
        mask = np.zeros((self.max_batch,), bool)
        mask[active_ids] = True
        live = self._live_pages(active_ids) \
            if self.kv_backend == "paged" else 0
        commits: List[int] = []
        for i in active_ids:
            s = self.slots[i]
            if s.pending:
                last[i, 0] = s.pending[0]
            elif s.tokens:
                last[i, 0] = s.tokens[-1]
            s.ctx_len = min(s.ctx_len + 1, self.max_len)
            if s.pending:
                s.pending.pop(0)
                if s.pending:
                    continue            # still teacher-forcing the suffix
            commits.append(i)
        return StepPlan(active_ids=active_ids, last=last, mask=mask,
                        live=live, commits=commits)

    def _dispatch_decode(self, plan: StepPlan):
        """The "run" half: ONE fused device call (decode + split + sample +
        logprob), cache donated, readback deferred to the next step's
        harvest. The PRNG key chains through the device so no sync is
        needed to keep `self.key`'s split stream identical to the eager
        loop's."""
        if self.kv_backend == "paged":
            # KV read traffic this step: mapped pages per active slot times
            # per-page pool+scale bytes (repeated-block DMAs past the live
            # range are elided by the kernel's clamped index_map)
            self.kv_bytes_read += self._page_kv_bytes * sum(
                -(-self.slots[i].ctx_len // self.page_size)
                for i in plan.active_ids)
            toks, lps, self.key, self.cache = self._decode_run(
                plan.live, self.params, jnp.asarray(plan.last), self.cache,
                jnp.asarray(plan.mask), self.key)
        else:
            toks, lps, self.key, self.cache = self._decode_run(
                self.params, jnp.asarray(plan.last), self.cache,
                jnp.asarray(plan.mask), self.key)
        self._pending_decode = (plan.commits, toks, lps)

    def _run_ingest(self) -> bool:
        """Batched ragged chunk ingest: EVERY ingesting slot's next chunk in
        one `prefill_ragged_paged` dispatch (qo_indptr-style rows of
        (slot, offset, len)), instead of one slot per step. Slots whose
        final chunk lands here draw their first token eagerly — same split
        order as the serial scheduler — and join the decode batch next
        step."""
        ing = [i for i, s in enumerate(self.slots)
               if s.active and s.prefill_toks]
        if not ing:
            return False
        # finish draws happen in this order; it matches the serial
        # scheduler's pick order (priority first, then admission age), so
        # aligned sampled streams stay aligned
        ing.sort(key=lambda j: (-self.slots[j].priority,
                                self.slots[j].arrival))
        C = self.prefill_chunk
        rows: List[Tuple[int, int, List[int]]] = []
        for i in ing:
            s = self.slots[i]
            chunk = s.prefill_toks[:C]
            s.prefill_toks = s.prefill_toks[C:]
            rows.append((i, s.ctx_len, chunk))
            s.ctx_len += len(chunk)
        R = 1
        while R < len(rows):
            R *= 2                      # bucket rows (lo=1) to bound variants
        toks = np.zeros((R, C), np.int32)
        # padding rows carry the out-of-range slot `max_batch`: their cache
        # scatters drop and their gathers clip to a live row and are
        # discarded
        slots = np.full((R,), self.max_batch, np.int32)
        offs = np.zeros((R,), np.int32)
        lens = np.zeros((R,), np.int32)
        for r, (i, off, chunk) in enumerate(rows):
            toks[r, :len(chunk)] = chunk
            slots[r], offs[r], lens[r] = i, off, len(chunk)
        live = self._chunk_live(max(off + len(chunk)
                                    for _, off, chunk in rows))
        self.kv_bytes_read += self._page_kv_bytes * sum(
            -(-(off + len(chunk)) // self.page_size)
            for _, off, chunk in rows)
        logits, self.cache = self._prefill_ragged(
            live, self.params, jnp.asarray(toks), self.cache,
            jnp.asarray(slots), jnp.asarray(offs), jnp.asarray(lens))
        draws: List[Tuple[int, object, object]] = []
        for r, (i, _, _) in enumerate(rows):
            s = self.slots[i]
            if s.active and not s.prefill_toks:
                # final chunk landed: first token — the same (1, V) sample a
                # monolithic add_request takes (row slices of the batched
                # logits are bitwise the single-slot logits)
                self.key, sub = jax.random.split(self.key)
                tok = sample(logits[r:r + 1], sub, self.sampler)
                lp = token_logprob(logits[r:r + 1], tok)
                draws.append((i, tok, lp))
        if draws:
            # repro-analysis: disable=RA103 reason=one batched readback for every first token finishing this step (was 2 scalar syncs per row)
            flat = jax.device_get([(t, l) for _, t, l in draws])
            for (i, _, _), (tok_h, lp_h) in zip(draws, flat):
                self._commit(i, int(tok_h[0]), float(lp_h[0]))
        return True

    def step(self) -> bool:
        """One engine step, structured plan/run: (0) harvest last step's
        decode readback, (1) host-plan everything — page growth/COW,
        eviction, ragged ingest rows, decode inputs — with numpy, (2) push
        the block table at most once, (3) dispatch at most one batched
        ragged ingest call and one fused decode call, deferring the decode
        readback to the next step. Returns True if work was done (including
        a harvest-only step that drains the last in-flight decode).

        Batched ingest (`ragged_ingest`, default): every ingesting slot
        advances one chunk per step through a single ragged device call, so
        decode latency between steps stays bounded by one chunk of prefill
        compute and a long prompt still cannot head-of-line-block the batch.
        Slots whose final chunk lands this step sample their first token
        eagerly (TTFT semantics unchanged) and join the decode batch next
        step; with `ragged_ingest=False` the legacy one-chunk-per-step
        scheduler runs instead, with its same-step join. Either way the
        ORDER of PRNG draws (finish draws, then the decode split) is
        unchanged, so greedy outputs and aligned sampled streams match the
        old loop bitwise.

        Slots with a pending suffix (fork path, monolithic engines) are
        teacher-forced: the step feeds `pending[0]` instead of the last
        sampled token and the sampled output is discarded until the suffix
        is exhausted — the logits after the final suffix token seed the
        first real sample."""
        if self.step_hook is not None:
            # fault injection point: may stall (straggler), cancel a slot
            # (mid-decode crash), squeeze the page pool, or raise
            # EngineCrash — all before this step's harvest/plan/dispatch
            self.step_hook(self)
        worked = self._harvest()
        if not any(s.active for s in self.slots):
            return worked
        t0 = time.perf_counter()
        batched = self.prefill_chunk and self.ragged_ingest \
            and self.kv_backend == "paged"
        if not batched and self.prefill_chunk:
            # legacy scheduler: one chunk for the most urgent ingesting
            # slot, which joins the decode batch this same step
            pref = [i for i, s in enumerate(self.slots)
                    if s.active and s.prefill_toks]
            if pref:
                # highest priority first (a latency-critical latecomer's
                # chunks jump the queue of a long opportunistic ingest),
                # oldest admission within a class
                self._ingest_chunk(min(
                    pref, key=lambda j: (-self.slots[j].priority,
                                         self.slots[j].arrival)))
                worked = True
        active = [i for i, s in enumerate(self.slots)
                  if s.active and not s.prefill_toks]
        if self.kv_backend == "paged" and active:
            self._grow_pages()          # may evict, incl. mid-ingest slots
            active = [i for i, s in enumerate(self.slots)
                      if s.active and not s.prefill_toks]
        plan = self._plan_decode(active) if active else None
        if self.kv_backend == "paged":
            # ONE table push per step, before the first dispatch that reads
            # it. Finish commits below may free rows again; those stale
            # entries ride until the next step's push — decode writes are
            # active-masked, so they cannot touch a COW sibling's pages.
            self._sync_table()
        if batched:
            worked = self._run_ingest() or worked
        if plan is not None:
            self._dispatch_decode(plan)
            worked = True
        self.busy_s += time.perf_counter() - t0
        return worked

    def warmup(self, *, max_context: Optional[int] = None,
               prompt_lens: Tuple[int, ...] = (),
               ingest_rows: Tuple[int, ...] = (1,)) -> int:
        """Precompile the step loop's jit variants on an IDLE engine so the
        first serving window is not dominated by XLA compiles (the paged
        backend's per-live-width variants otherwise all compile inside the
        measured window). Returns the number of variant dispatches made.

        max_context bounds the decode live-width buckets to warm (default
        max_len); prompt_lens warms monolithic prefill buckets (dense and
        non-chunked paged engines); ingest_rows warms batched ragged ingest
        row-bucket variants (chunked paged engines). All warm dispatches
        are state no-ops: all-inactive masks and out-of-range slot rows
        drop every write, and `self.key` is never advanced."""
        assert not any(s.active or s.parked for s in self.slots), \
            "warmup requires an idle engine"
        key0 = jax.random.PRNGKey(0)    # throwaway: self.key stays untouched
        count = 0
        last = np.zeros((self.max_batch, 1), np.int32)
        mask = np.zeros((self.max_batch,), bool)
        if self.kv_backend == "paged":
            lives = sorted({self._chunk_live(end) for end in
                            range(1, min(max_context or self.max_len,
                                         self.max_len) + 1)})
            for live in lives:
                _, _, _, self.cache = self._decode_run(
                    live, self.params, jnp.asarray(last), self.cache,
                    jnp.asarray(mask), key0)
                count += 1
            if self.prefill_chunk and self.ragged_ingest:
                rbs = set()
                for n in ingest_rows:
                    r = 1
                    while r < min(n, self.max_batch):
                        r *= 2
                    rbs.add(r)
                sent = np.full((max(rbs),), self.max_batch, np.int32)
                for rb in sorted(rbs):
                    for live in lives:
                        _, self.cache = self._prefill_ragged(
                            live, self.params,
                            jnp.zeros((rb, self.prefill_chunk), jnp.int32),
                            self.cache, jnp.asarray(sent[:rb]),
                            jnp.zeros((rb,), jnp.int32),
                            jnp.zeros((rb,), jnp.int32))
                        count += 1
            elif self.prefill_chunk:
                # serial fallback scheduler: warm the single-slot chunk
                # variants instead (zero-length chunk: every write drops)
                for live in lives:
                    _, self.cache = self._prefill_chunk(
                        live, self.params,
                        jnp.zeros((1, self.prefill_chunk), jnp.int32),
                        self.cache, jnp.asarray(0, jnp.int32),
                        jnp.asarray(0, jnp.int32), jnp.asarray(0, jnp.int32))
                    count += 1
            elif prompt_lens:
                for S in sorted({min(_bucket(n), self.max_len)
                                 for n in prompt_lens}):
                    self._sync_table()
                    _, self.cache = self._prefill_paged(
                        self.params, jnp.zeros((1, S), jnp.int32),
                        self.cache, jnp.asarray(0, jnp.int32),
                        jnp.asarray(0, jnp.int32))
                    count += 1
            # fork/COW page copy: one shape variant total (src == dst is a
            # value no-op on an idle engine)
            self.cache = self._fork(
                self.cache, jnp.asarray(0, jnp.int32),
                jnp.asarray(0, jnp.int32), jnp.asarray(0, jnp.int32),
                jnp.asarray(0, jnp.int32))
            count += 1
            if self.host_swap:
                # swap-in (promote) variants: one per upload-width bucket.
                # Padding page ids (n_pages) drop every pool write and the
                # out-of-range slot drops the lengths write, so warm
                # promotes are state no-ops.
                for U in sorted({_pow2_bucket(u, self.pages_per_seq)
                                 for u in range(1, self.pages_per_seq + 1)}):
                    payloads = [
                        {k: jnp.zeros((seg[k].shape[0], U)
                                      + seg[k].shape[2:], seg[k].dtype)
                         for k in seg}
                        for seg in self.cache["segments"]
                        if "k_pages" in seg]
                    self.cache = self._promote(
                        self.cache,
                        jnp.full((U,), self.n_pages, jnp.int32), payloads,
                        jnp.asarray(self.max_batch, jnp.int32),
                        jnp.asarray(0, jnp.int32))
                    count += 1
        else:
            _, _, _, self.cache = self._decode_run(
                self.params, jnp.asarray(last), self.cache,
                jnp.asarray(mask), key0)
            count += 1
            for S in sorted({min(_bucket(n), self.max_len)
                             for n in prompt_lens}):
                one = transformer.init_cache(self.cfg, 1, self.max_len)
                _, one = self._prefill(self.params,
                                       jnp.zeros((1, S), jnp.int32), one,
                                       jnp.asarray([0], jnp.int32))
                self.cache = self._insert(self.cache, one, 0)
                count += 1
        if prompt_lens:
            # offline scoring shares the serving buckets; warm it alongside
            # so a first score() call does not compile mid-window
            for S in sorted({min(_bucket(n), self.max_len)
                             for n in prompt_lens}):
                self._score(self.params,
                            jnp.full((S,), self.eos_id, jnp.int32))
                count += 1
        return count

    # ------------------------------------------------------------------
    def generate(self, prompts: List[List[int]], max_new: int = 128,
                 priorities: Optional[List[int]] = None,
                 deadline_s: Optional[float] = None
                 ) -> List[Tuple[List[int], List[float]]]:
        """Batch-generate; returns (tokens, logprobs) per prompt.
        `priorities` (optional, per prompt) orders preemption under memory
        pressure — higher survives longer. `deadline_s` (perf_counter
        timestamp) caps the run: once passed, every in-flight request is
        cancelled and returns whatever it generated so far."""
        priorities = priorities or [0] * len(prompts)
        assert len(priorities) == len(prompts), \
            "priorities must match prompts one-to-one"
        pending = [_Resume(req_id=i, prompt=p, max_new=max_new,
                           carry_tokens=[], carry_lps=[], priority=pr)
                   for i, (p, pr) in enumerate(zip(prompts, priorities))]
        return self._run(pending, deadline_s=deadline_s)

    def generate_fanout(self, prefix: List[int],
                        suffixes: List[List[int]], max_new: int = 128,
                        priority: int = 0,
                        deadline_s: Optional[float] = None
                        ) -> List[Tuple[List[int], List[float]]]:
        """Expand one shared prefix N ways (the PICE sketch fan-out: every
        ensemble member / parallel expansion segment repeats the same
        (query, sketch) prefix). The prefix is prefilled ONCE and each
        expansion forks a copy-on-write block-table row off it, so the pool
        holds one prefix instead of N; per-group suffixes are teacher-forced
        before sampling. Falls back to independent submissions on the dense
        backend, a 1-slot engine, or prefix_sharing=False."""
        if (self.kv_backend != "paged" or self.max_batch < 2
                or not self.prefix_sharing):
            return self.generate([list(prefix) + list(s) for s in suffixes],
                                 max_new=max_new,
                                 priorities=[priority] * len(suffixes),
                                 deadline_s=deadline_s)
        p_slot = self.prefill_prefix(prefix)
        pending = [_Resume(req_id=i, prompt=list(prefix) + list(sfx),
                           max_new=max_new, carry_tokens=[], carry_lps=[],
                           share_from=p_slot, suffix=list(sfx),
                           priority=priority)
                   for i, sfx in enumerate(suffixes)]
        try:
            return self._run(pending, deadline_s=deadline_s)
        finally:
            self.release_prefix(p_slot)

    def _run(self, pending: List[_Resume],
             deadline_s: Optional[float] = None
             ) -> List[Tuple[List[int], List[float]]]:
        n = len(pending)
        for r in pending:
            # fresh submissions must not inherit a stale admission stamp
            # from an earlier run that reused the same req_id (eviction
            # resumes within THIS run still keep their original stamp)
            self._t_admit.pop(r.req_id, None)
        # register this run's req_ids so admission-stamp pruning never drops
        # a TTFT stamp for work that is merely queued or evicted-and-waiting
        mine = {r.req_id for r in pending}
        self._inflight |= mine
        try:
            return self._run_inner(pending, n, deadline_s)
        finally:
            self._inflight -= mine

    # ------------------------------------------------------------------
    # Request-handle admission API. `try_admit` is ONE admission attempt for
    # a queued (fresh or preempted) request and `drain_resumes` hands back
    # the work eviction preempted — the synchronous `_run` loop below and
    # the async serving front-end (serving/frontend.py) drive the engine
    # through these same two calls, so a multiplexed stream of requests
    # takes exactly the admission path a dedicated run would.
    # ------------------------------------------------------------------
    def try_admit(self, r: _Resume) -> Optional[int]:
        """Attempt to admit `r`. Returns the slot index on success, or None
        when the request must wait for slots/pages to free. Raises
        MemoryError when the engine is IDLE and the request still cannot
        fit: no running work will ever free enough pool.

        May mutate `r`: an injected swap-upload loss (`swap_fault_hook`)
        degrades a host-tier resume to the evict-and-replay path — r.prompt
        and the carried tokens are exactly what a non-swap eviction queued,
        so the replay is the same bit-identical path; a fork resume whose
        parked prefix is gone falls back to a monolithic prompt."""
        if not self.free_slots():
            return None
        if r.swap is not None and self.swap_fault_hook is not None \
                and self.swap_fault_hook(r.req_id):
            self.alloc.drop_hosted(r.req_id)
            r.swap = None
            self.swap_losses += 1
        if r.swap is not None:
            # demoted request: promote its host-tier pages back and
            # re-enter decode directly (no prefill replay)
            if not self.can_admit_swap(r.req_id):
                if not any(s.active for s in self.slots):
                    raise MemoryError(
                        f"request {r.req_id} cannot fit in the page pool")
                return None                      # wait for pages to free
            return self._admit_swapped(r)
        if r.share_from >= 0 and not self.slots[r.share_from].parked:
            r.share_from, r.suffix = -1, []       # prefix gone: monolithic
        if r.share_from >= 0:
            ok = self.can_admit_fork(
                r.share_from, len(r.suffix) + len(r.carry_tokens))
        else:
            ok = self.can_admit(len(r.prompt) + len(r.carry_tokens))
        if not ok:
            if not any(s.active for s in self.slots):
                raise MemoryError(
                    f"request {r.req_id} cannot fit in the page pool")
            return None                          # wait for pages to free
        return self.add_request(
            r.req_id, r.prompt, r.max_new,
            carry_tokens=r.carry_tokens, carry_lps=r.carry_lps,
            share_from=r.share_from if r.share_from >= 0 else None,
            suffix=r.suffix, priority=r.priority)

    def drain_resumes(self) -> List[_Resume]:
        """Take the work eviction preempted, in re-admission order: oldest
        victim first (eviction queued victims youngest-first as it found
        them). Callers put these at the HEAD of their pending queue so
        preempted work re-enters before fresh submissions."""
        out = list(reversed(self._resume_queue))
        self._resume_queue.clear()
        return out

    def _run_inner(self, pending: List[_Resume], n: int,
                   deadline_s: Optional[float] = None
                   ) -> List[Tuple[List[int], List[float]]]:
        results: Dict[int, Tuple[List[int], List[float]]] = {}
        submitted: Dict[int, int] = {}          # req_id -> slot
        while pending or any(s.active for s in self.slots):
            while pending and self.free_slots():
                slot = self.try_admit(pending[0])
                if slot is None:
                    break                        # wait for pages to free
                r = pending.pop(0)
                submitted[r.req_id] = slot
            self.step()
            if deadline_s is not None and time.perf_counter() > deadline_s \
                    and (pending or any(s.active for s in self.slots)):
                # deadline blown: cancel every in-flight request (partial
                # tokens are collected below) and settle never-admitted /
                # evicted work with whatever it carried
                for rid, sl in list(submitted.items()):
                    if self.slots[sl].active:
                        self.cancel(rid)
                        self.deadline_cancels += 1
                pending[:0] = self.drain_resumes()
                for r in pending:
                    if r.swap is not None:
                        self.alloc.drop_hosted(r.req_id)
                    results[r.req_id] = (list(r.carry_tokens),
                                         list(r.carry_lps))
                    self.deadline_cancels += 1
                pending.clear()
            done = [rid for rid, sl in submitted.items()
                    if not self.slots[sl].active]
            for rid in done:
                sl = submitted.pop(rid)
                s = self.slots[sl]
                s.req_id = -1
                if s.evicted:
                    s.evicted = False
                    continue                     # resubmitted via _resume_queue
                results[rid] = (list(s.tokens), list(s.logprobs))
            # preempted work goes to the queue head, oldest first
            pending[:0] = self.drain_resumes()
        return [results[i] for i in range(n)]

    def score(self, tokens: List[int]) -> Tuple[float, np.ndarray]:
        """Mean token logprob of a sequence under this model (perplexity).

        The scoring buffer is clamped to max_len: the unbounded power-of-two
        bucket used to compile (and OOM) arbitrarily large variants for one
        long input. Sequences beyond max_len are scored on their TAIL — the
        same most-recent-context convention `_pad_prompt` applies."""
        S = min(_bucket(len(tokens)), self.max_len)
        toks = tokens[-S:]
        arr = np.full((S,), self.eos_id, np.int32)
        arr[:len(toks)] = toks
        _, gold_d = self._score(self.params, jnp.asarray(arr))
        # repro-analysis: disable=RA103 reason=offline scoring API; the readback is the result, not on the step loop
        gold = jax.device_get(gold_d)[:max(len(toks) - 1, 1)]
        return float(np.mean(gold)), gold
