"""Roofline-term derivation from dry-run artifacts.

Hardware model (TPU v5e): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI. cost_analysis() numbers come from the post-SPMD
per-device module, so terms are per-chip:

    compute    = HLO_FLOPs_dev / peak
    memory     = HLO_bytes_dev / hbm_bw
    collective = collective_bytes_dev / ici_bw

MODEL_FLOPS is the analytic useful compute: 6*N*D for training (fwd+bwd),
2*N*D for forward-only (prefill/decode), with N = active params for MoE.
The ratio MODEL_FLOPS/HLO_FLOPs exposes remat/dispatch/redundancy waste —
and for architectures whose inner loops lower to lax.scan/lax.map (SSD chunk
scans, sLSTM time scans, q-blocked long attention), XLA's static cost
analysis counts the loop body ONCE, so HLO_FLOPs underestimates and the
ratio exceeds 1; those rows are flagged `scan_undercount`.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Optional

from repro.configs.registry import SHAPES, InputShape, get_config
from repro.models.config import ModelConfig

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = {"pod16x16": 256, "pod2x16x16": 512}


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """Analytic useful FLOPs for the whole step (global, all chips)."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def has_inner_scan(cfg: ModelConfig, shape: InputShape) -> bool:
    if cfg.family in ("ssm", "hybrid"):
        return True                      # SSD chunk scan / sLSTM time scan
    if shape.kind in ("train", "prefill") and shape.seq_len >= 4096:
        return True                      # q-blocked attention lax.map
    return False


@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    status: str
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    model_flops: float = 0.0
    hlo_flops_dev: float = 0.0
    useful_ratio: float = 0.0
    temp_bytes: Optional[int] = None
    scan_undercount: bool = False
    note: str = ""

    def dominant_value(self) -> float:
        return {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}[self.dominant]


def row_from_record(rec: dict) -> RooflineRow:
    arch, shape_name, mesh = rec["arch"], rec["shape"], rec["mesh"]
    if rec["status"] != "ok":
        return RooflineRow(arch=arch, shape=shape_name, mesh=mesh,
                           status=rec["status"],
                           note=rec.get("reason", rec.get("error", ""))[:120])
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    chips = CHIPS[mesh]
    compute = rec["flops"] / PEAK_FLOPS
    memory = rec["bytes_accessed"] / HBM_BW
    coll = rec["collective_bytes"]["total"] / ICI_BW
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_flops_global = rec["flops"] * chips
    return RooflineRow(
        arch=arch, shape=shape_name, mesh=mesh, status="ok",
        compute_s=compute, memory_s=memory, collective_s=coll, dominant=dom,
        model_flops=mf, hlo_flops_dev=rec["flops"],
        useful_ratio=mf / max(hlo_flops_global, 1.0),
        temp_bytes=rec["memory"]["temp_bytes"],
        scan_undercount=has_inner_scan(cfg, shape),
    )


def load_rows(art_dir: str, mesh: str = "pod16x16", tag: str = "") -> list:
    rows = []
    for f in sorted(Path(art_dir).glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("mesh") != mesh or rec.get("tag", "") != tag:
            continue
        rows.append(row_from_record(rec))
    return rows


def suggestion(row: RooflineRow) -> str:
    """One sentence on what would move the dominant term down."""
    if row.status != "ok":
        return ""
    if row.dominant == "collective":
        return ("reduce resharding: align producer shardings with cache/param "
                "layouts, or swap TP for sequence-parallel collectives")
    if row.dominant == "memory":
        if row.shape.startswith("decode") or row.shape == "long_500k":
            return ("decode is KV-bound: shorter outputs (PICE sketching), "
                    "windowed/quantized KV, or more model-axis cache sharding")
        return ("cast/fuse activations (bf16 residuals, fused norm), tighter "
                "remat policy, or shard the residual stream")
    return ("raise MXU utilization: bigger per-chip tiles, fewer pad-waste "
            "dims, fused matmuls")
