"""Parse compiled HLO text for roofline inputs.

`compiled.cost_analysis()` supplies FLOPs and bytes-accessed, but NOT
collective traffic — we recover it by summing the output-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute op
in the post-SPMD optimized HLO (`compiled.as_text()`).
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.:  %ag = bf16[2,16,4096]{2,1,0} all-gather(...)
#        ROOT %tuple ... (f32[8,128], bf16[4,4]) all-to-all(...)
_OP_RE = re.compile(
    r"=\s*(?P<shapes>\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(?P<op>" + "|".join(COLLECTIVES) + r")\b")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output bytes per collective kind. Returns {kind: bytes, 'total': ...}."""
    out = {k: 0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        op = m.group("op")
        total = 0
        for dt, dims in _SHAPE_RE.findall(m.group("shapes")):
            if dt in _DTYPE_BYTES:
                total += _shape_bytes(dt, dims)
        out[op] += total
        counts[op] += 1
    out["total"] = sum(out[k] for k in COLLECTIVES)
    out["counts"] = counts
    return out


def collective_count(hlo_text: str) -> int:
    return sum(collective_bytes(hlo_text)["counts"].values())
