"""Divisibility-aware sharding helpers.

Every sharding decision in the framework goes through these helpers so that a
tensor dim is only sharded over a mesh axis (or axis tuple) when the size is
divisible — otherwise that dim is replicated. This makes every (architecture x
input-shape x mesh) combination lower without per-arch special cases (e.g.
qwen2-1.5b has 2 KV heads, which cannot split over a 16-way model axis, so its
KV projections replicate over `model` while Q still shards).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisName = Union[str, Tuple[str, ...], None]


def axis_size(mesh: Mesh, axis: AxisName) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis]
    n = 1
    for a in axis:
        n *= mesh.shape[a]
    return n


def shardable(mesh: Mesh, dim: int, axis: AxisName) -> AxisName:
    """Return `axis` if `dim` divides over it, else None (replicate)."""
    if axis is None:
        return None
    n = axis_size(mesh, axis)
    if n > 0 and dim % n == 0 and dim >= n:
        return axis
    # try prefixes of a tuple axis, e.g. ("data","model") -> ("data",)
    if isinstance(axis, tuple):
        for k in range(len(axis) - 1, 0, -1):
            sub = axis[:k]
            if dim % axis_size(mesh, sub) == 0 and dim >= axis_size(mesh, sub):
                return sub
    return None


def pspec(mesh: Mesh, shape: Sequence[int], axes: Sequence[AxisName]) -> P:
    """Build a PartitionSpec, dropping axes that don't divide the dims."""
    assert len(shape) == len(axes), (shape, axes)
    return P(*[shardable(mesh, d, a) for d, a in zip(shape, axes)])


def named(mesh: Mesh, shape: Sequence[int], axes: Sequence[AxisName]) -> NamedSharding:
    return NamedSharding(mesh, pspec(mesh, shape, axes))


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes used for the batch dimension ('pod' + 'data' if present)."""
    names = mesh.axis_names
    out = tuple(a for a in ("pod", "data") if a in names)
    return out or (names[0],)


def model_axis(mesh: Mesh) -> Optional[str]:
    return "model" if "model" in mesh.axis_names else None


def constraint(x, mesh: Mesh, axes: Sequence[AxisName]):
    """with_sharding_constraint with divisibility-aware spec."""
    spec = pspec(mesh, x.shape, axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def tree_shardings(mesh: Mesh, tree, spec_fn):
    """Map a spec_fn(path, leaf) -> PartitionSpec over a pytree into shardings."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        out.append(NamedSharding(mesh, spec_fn(path, leaf)))
    return jax.tree_util.tree_unflatten(treedef, out)
