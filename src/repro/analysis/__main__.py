"""CLI: `python -m repro.analysis [--strict] [--root DIR] [--report FILE]`.

Exit status:
  0  no unwaived violations (and, under --strict, every waiver has a reason)
  1  unwaived violations found, or --strict and a reason-less waiver
  2  bad invocation

The machine-readable report (default `analysis_report.json`, uploaded as a CI
artifact) lists every violation including waived ones, so waiver counts are
visible in review even though they do not fail the gate.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import Counter
from pathlib import Path

from repro.analysis import PASSES, package_root, run_all
from repro.analysis import rules


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static invariant checks for repro serving/kernels.")
    ap.add_argument("--strict", action="store_true",
                    help="fail on any unwaived violation or reason-less "
                         "waiver (the CI gate mode)")
    ap.add_argument("--root", type=Path, default=None,
                    help="package root to scan (default: the installed "
                         "repro package)")
    ap.add_argument("--report", type=Path,
                    default=Path("analysis_report.json"),
                    help="machine-readable report path ('-' to skip)")
    ap.add_argument("--pass", dest="passes", action="append",
                    choices=sorted(PASSES),
                    help="run only this pass (repeatable; default: all)")
    args = ap.parse_args(argv)

    root = (args.root or package_root()).resolve()
    if not root.is_dir():
        print(f"error: root {root} is not a directory", file=sys.stderr)
        return 2

    violations = run_all(root, args.passes)
    active = [v for v in violations if not v.waived]
    waived = [v for v in violations if v.waived]
    reasonless = [v for v in waived if not v.waive_reason]

    for v in violations:
        print(v.render())

    by_code = Counter(v.code for v in active)
    summary = (f"{len(active)} violation(s), {len(waived)} waived "
               f"({len(reasonless)} without a reason) across "
               f"{len(args.passes or PASSES)} pass(es)")
    print(summary)
    for code, n in sorted(by_code.items()):
        print(f"  {code} x{n}: {rules.RULES.get(code, '?')}")

    if str(args.report) != "-":
        report = {
            "root": str(root),
            "strict": bool(args.strict),
            "passes": sorted(args.passes or PASSES),
            "violations": [v.to_json() for v in violations],
            "counts": {"active": len(active), "waived": len(waived),
                       "waived_without_reason": len(reasonless),
                       "by_code": dict(by_code)},
            "ok": not active and not (args.strict and reasonless),
        }
        args.report.write_text(json.dumps(report, indent=2) + "\n")

    if active:
        return 1
    if args.strict and reasonless:
        print("strict: waivers without reason= are not allowed",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
