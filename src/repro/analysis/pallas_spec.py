"""Pass 4 — Pallas block-spec contracts (RA401-RA404).

Mosaic's failure modes for a bad BlockSpec are late and opaque (a lowering
error at first trace, or silent garbage from a misaligned tile), so this pass
re-derives the kernel-side contracts from the AST of each
`kernels/*/kernel.py` without importing it:

  RA401  every index_map must accept grid-rank + num_scalar_prefetch
         arguments (scalar-prefetch refs are appended to the grid indices);
  RA402  an index_map must return one coordinate per block-shape dim;
  RA403  literal block/scratch dims in the last two (sublane, lane)
         positions must be multiples of SUBLANE_MULTIPLE (the same constant
         `ModelConfig.validate_paged` enforces on page_size/prefill_chunk —
         symbolic dims are checked there at runtime, literals here);
  RA404  the summed worst-case footprint of all blocks + VMEM scratch must
         fit under VMEM_CAP_BYTES. Symbolic dims resolve through
         WORST_CASE_DIMS; the estimate ignores double buffering, so it is a
         lower bound and the cap is the full physical VMEM.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Optional, Tuple

from repro.analysis import rules
from repro.analysis.common import (SourceFile, Violation, apply_waivers,
                                   dotted, enclosing_function, parent_map)


def _resolve_dim(node: ast.AST) -> int:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    if isinstance(node, ast.Name):
        return rules.WORST_CASE_DIMS.get(node.id, rules.DEFAULT_DIM)
    if isinstance(node, ast.Attribute):
        return rules.WORST_CASE_DIMS.get(node.attr, rules.DEFAULT_DIM)
    if isinstance(node, ast.BinOp):
        lo, hi = _resolve_dim(node.left), _resolve_dim(node.right)
        if isinstance(node.op, ast.Mult):
            return lo * hi
        if isinstance(node.op, ast.Add):
            return lo + hi
        if isinstance(node.op, ast.Sub):
            return max(lo - hi, 1)
        if isinstance(node.op, ast.FloorDiv):
            return max(lo // max(hi, 1), 1)
    return rules.DEFAULT_DIM


def _shape_elems(node: ast.AST) -> Optional[List[ast.AST]]:
    if isinstance(node, (ast.Tuple, ast.List)):
        return list(node.elts)
    return None


def _index_map_signature(expr: ast.AST, scopes: List[ast.AST]
                         ) -> Optional[Tuple[int, bool, Optional[int], int]]:
    """(n_required_args, has_vararg, return_rank, lineno) for a lambda or a
    function name resolved innermost-scope-first; None when unresolvable."""
    target = None
    if isinstance(expr, ast.Lambda):
        target = expr
    elif isinstance(expr, ast.Name):
        for scope in scopes:
            for n in ast.walk(scope):
                if isinstance(n, ast.FunctionDef) and n.name == expr.id:
                    target = n
                    break
            if target is not None:
                break
    if target is None:
        return None
    a = target.args
    required = len(a.posonlyargs) + len(a.args) - len(a.defaults)
    vararg = a.vararg is not None
    ret_rank = None
    if isinstance(target, ast.Lambda):
        if isinstance(target.body, ast.Tuple):
            ret_rank = len(target.body.elts)
    else:
        for r in ast.walk(target):
            if isinstance(r, ast.Return) and isinstance(r.value, ast.Tuple):
                ret_rank = len(r.value.elts)
                break
    return required, vararg, ret_rank, target.lineno


def _kw(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _block_specs(node: ast.AST) -> List[ast.Call]:
    """All pl.BlockSpec(...) calls inside an in_specs/out_specs expression."""
    if node is None:
        return []
    return [c for c in ast.walk(node)
            if isinstance(c, ast.Call)
            and dotted(c.func).split(".")[-1] == "BlockSpec"]


def _vmem_scratch_shapes(node: ast.AST) -> List[ast.Call]:
    if node is None:
        return []
    return [c for c in ast.walk(node)
            if isinstance(c, ast.Call)
            and dotted(c.func).split(".")[-1] == "VMEM"]


def _check_alignment(sf: SourceFile, elems: List[ast.AST], where: str,
                     out: List[Violation]) -> None:
    for pos, e in enumerate(elems[-2:]):
        if isinstance(e, ast.Constant) and isinstance(e.value, int):
            v = e.value
            if v != 1 and v % rules.SUBLANE_MULTIPLE != 0:
                dim = "lane" if pos == len(elems[-2:]) - 1 else "sublane"
                out.append(Violation(
                    file=sf.rel, line=e.lineno, code="RA403",
                    message=f"{where}: {dim} dim {v} is not a multiple of "
                            f"{rules.SUBLANE_MULTIPLE}"))


def check_file(sf: SourceFile) -> List[Violation]:
    out: List[Violation] = []
    parents = parent_map(sf.tree)

    for call in ast.walk(sf.tree):
        if not isinstance(call, ast.Call) \
                or dotted(call.func).split(".")[-1] != "pallas_call":
            continue

        grid = _kw(call, "grid")
        num_prefetch = 0
        in_specs = _kw(call, "in_specs")
        out_specs = _kw(call, "out_specs")
        scratch = _kw(call, "scratch_shapes")

        spec_expr = _kw(call, "grid_spec")
        if isinstance(spec_expr, ast.Name):
            scope = enclosing_function(call, parents) or sf.tree
            for a in ast.walk(scope):
                if isinstance(a, ast.Assign) and any(
                        isinstance(t, ast.Name) and t.id == spec_expr.id
                        for t in a.targets):
                    spec_expr = a.value
                    break
        if isinstance(spec_expr, ast.Call):
            grid = _kw(spec_expr, "grid") or grid
            in_specs = _kw(spec_expr, "in_specs") or in_specs
            out_specs = _kw(spec_expr, "out_specs") or out_specs
            scratch = _kw(spec_expr, "scratch_shapes") or scratch
            np_node = _kw(spec_expr, "num_scalar_prefetch")
            if isinstance(np_node, ast.Constant) \
                    and isinstance(np_node.value, int):
                num_prefetch = np_node.value

        grid_rank = None
        grid_elems = _shape_elems(grid) if grid is not None else None
        if grid_elems is not None:
            grid_rank = len(grid_elems)

        total_bytes = 0
        specs = _block_specs(in_specs) + _block_specs(out_specs)
        for spec in specs:
            shape = _kw(spec, "block_shape")
            index_map = _kw(spec, "index_map")
            pos_args = list(spec.args)
            if shape is None and pos_args:
                shape = pos_args.pop(0)
            if index_map is None and pos_args:
                index_map = pos_args.pop(0)
            elems = _shape_elems(shape) if shape is not None else None

            if elems is not None:
                _check_alignment(sf, elems, "BlockSpec", out)
                total_bytes += rules.F32_BYTES * _prod(elems)

            if index_map is not None:
                fn_scope = enclosing_function(call, parents)
                scopes = ([fn_scope] if fn_scope is not None else []) \
                    + [sf.tree]
                sig = _index_map_signature(index_map, scopes)
                if sig is not None and grid_rank is not None:
                    required, vararg, ret_rank, line = sig
                    expected = grid_rank + num_prefetch
                    bad = (required > expected) if vararg \
                        else (required != expected)
                    if bad:
                        out.append(Violation(
                            file=sf.rel, line=spec.lineno, code="RA401",
                            message=f"index_map takes {required} args but "
                                    f"grid rank {grid_rank} + "
                                    f"{num_prefetch} scalar-prefetch refs "
                                    f"= {expected}"))
                    if ret_rank is not None and elems is not None \
                            and ret_rank != len(elems):
                        out.append(Violation(
                            file=sf.rel, line=spec.lineno, code="RA402",
                            message=f"index_map returns {ret_rank} coords "
                                    f"for a {len(elems)}-dim block shape"))

        for vm in _vmem_scratch_shapes(scratch):
            shp = vm.args[0] if vm.args else None
            elems = _shape_elems(shp) if shp is not None else None
            if elems is not None:
                _check_alignment(sf, elems, "VMEM scratch", out)
                total_bytes += rules.F32_BYTES * _prod(elems)

        if total_bytes > rules.VMEM_CAP_BYTES:
            out.append(Violation(
                file=sf.rel, line=call.lineno, code="RA404",
                message=f"estimated VMEM footprint {total_bytes} B "
                        f"(worst-case dims) exceeds cap "
                        f"{rules.VMEM_CAP_BYTES} B"))
    return apply_waivers(sf, out)


def _prod(elems: List[ast.AST]) -> int:
    p = 1
    for e in elems:
        p *= max(_resolve_dim(e), 1)
    return p


def run(root: Path) -> List[Violation]:
    out: List[Violation] = []
    for p in sorted(root.glob(rules.PALLAS_SCOPE_GLOB)):
        out.extend(check_file(SourceFile.load(p, root)))
    return out
