"""Shared constants and rule registry for the `repro.analysis` checkers.

This module is intentionally stdlib-only: it is imported both by the static
passes (which must run without jax installed, e.g. in a bare CI job) and by
runtime validation code (`ModelConfig.validate_paged`), so the runtime check
and the static pallas-spec pass read the SAME alignment constants and can
never disagree.
"""
from __future__ import annotations

import re

# ---------------------------------------------------------------------------
# TPU tiling contracts (see /opt guides + docs/static-analysis.md).
#
# The second-to-last ("sublane") dimension of a VMEM tile must be a multiple
# of 8 for float32 (bf16/int8 need 16/32, so 8 is the *minimum* contract the
# repo enforces everywhere a page or chunk becomes a tile dimension); the
# last ("lane") dimension of the native tile is 128. `validate_paged` applies
# SUBLANE_MULTIPLE to page_size/prefill_chunk at engine construction; the
# pallas-spec pass applies it to literal BlockSpec dims at analysis time.
# ---------------------------------------------------------------------------
SUBLANE_MULTIPLE = 8
LANE_MULTIPLE = 128

# Static VMEM budget for one kernel invocation: block tiles + scratch must
# fit comfortably in the ~16 MiB of VMEM per TensorCore. The estimator is a
# conservative lower bound (it ignores Mosaic's double buffering), so the cap
# is the full physical size rather than a derated one.
VMEM_CAP_BYTES = 16 * 1024 * 1024

# Worst-case values for symbolic dimensions appearing in BlockSpec / scratch
# shapes, keyed by the variable names the kernels use. The pallas-spec pass
# resolves literal dims exactly and symbolic dims from this table; unknown
# names fall back to DEFAULT_DIM. Values are the maxima the engine/configs
# can reach (page_size <= 256, prefill_chunk <= 256, head_dim <= 128,
# q_per_kv <= 8, d_model <= 4096, scan chunk <= 512).
WORST_CASE_DIMS = {
    "hd": 128, "ps": 256, "rep": 8, "C": 256,
    "bq": 256, "bkv": 256, "bs": 512, "br": 256,
    "D": 4096, "Q": 256, "P": 256, "N": 256,
}
DEFAULT_DIM = 128
F32_BYTES = 4

# ---------------------------------------------------------------------------
# Rule registry. Codes are stable: tests assert them and pragmas name them.
# ---------------------------------------------------------------------------
RULES = {
    # host-sync / trace-safety
    "RA101": "implicit host sync: float()/int()/bool()/.item() on a device "
             "value in a serving hot path",
    "RA102": "np.asarray/np.array on a device value forces a transfer in a "
             "serving hot path",
    "RA103": "jax.device_get outside the sanctioned per-step harvest site",
    "RA104": "block_until_ready in a serving hot path",
    # recompile budget
    "RA201": "power-of-two bucket used as a shape without an upper clamp "
             "(compiles O(requests) variants)",
    "RA202": "jax.jit call site outside the shared lru_cache jit registry",
    "RA203": "static jit argument fed from a raw request-derived value "
             "instead of a bucketing helper",
    "RA204": "jit registry is not lru_cache-decorated (engines recompile "
             "per instance)",
    "RA205": "registry-held jitted entry point never referenced in warmup() "
             "(first call compiles inside a serving window)",
    # donation safety
    "RA301": "donated buffer not reassigned from the donating call's result",
    "RA302": "donated buffer read after the jitted call that consumed it",
    # pallas block specs
    "RA401": "index_map arity does not match grid rank + num_scalar_prefetch",
    "RA402": "BlockSpec block-shape rank does not match its index_map's "
             "return rank",
    "RA403": "literal BlockSpec/scratch dim in the last two positions is "
             "not sublane-aligned (multiple of 8)",
    "RA404": "estimated VMEM footprint (blocks + scratch) exceeds the cap",
    # fault observability
    "RA501": "except clause swallows the exception without re-raising or "
             "recording it to a monitor/telemetry counter",
    # async-blocking (serving front-end event loop)
    "RA601": "blocking time.sleep in the async serving layer (stalls every "
             "in-flight stream; use `await asyncio.sleep`)",
    "RA602": "bare device sync (jax.device_get / block_until_ready) in an "
             "async serving path",
}

# ---------------------------------------------------------------------------
# Pass scopes: path suffixes/prefixes relative to the repro package root.
# ---------------------------------------------------------------------------
# Serving hot paths + telemetry/training loops the one-readback contract and
# taint analysis cover.
HOST_SYNC_SCOPE = (
    "serving/", "models/paged_cache.py", "models/transformer.py",
    "training/train_loop.py", "finetune/", "core/profiler.py",
)
# jit call-site discipline (shared registry, bounded buckets).
RECOMPILE_SCOPE = ("serving/", "finetune/", "training/")
# donation-safety: files that donate buffers today.
DONATION_SCOPE = ("serving/engine.py", "training/train_loop.py")
# pallas-spec: every kernel module.
PALLAS_SCOPE_GLOB = "kernels/*/kernel.py"
# fault observability: the trees the degradation ladder runs through.
EXCEPTIONS_SCOPE = ("serving/", "core/")
# async-blocking: the cooperative event-loop modules (one driver coroutine
# serves every stream — any blocking call here stalls them all).
ASYNC_SCOPE = ("serving/frontend.py", "serving/loadgen.py")

# The ONLY function allowed to call jax.device_get without a pragma: the
# engine's deferred-harvest readback (one device_get per step, the plan/run
# contract). Everything else — admission-time first-token draws, offline
# scoring, train-loop logging — must carry an inline waiver with a reason.
HOST_SYNC_ALLOWLIST = {("serving/engine.py", "_harvest")}

# Helpers whose results count as "bucketed" (bounded jit shape variants).
BUCKET_HELPERS = ("_bucket", "_chunk_live", "_live_pages", "_pow2_bucket")
# Attribute names that are config-bounded (not request-derived) when used as
# a static jit argument.
BOUNDED_ATTR_NAMES = {
    "live", "max_batch", "max_len", "prefill_chunk", "pages_per_seq",
    "page_size", "n_pages", "seq_len", "max_sketch_tokens",
}

# ---------------------------------------------------------------------------
# Inline waiver pragma:   # repro-analysis: disable=RA101 reason=why
# (comma-separated codes; reason is mandatory under --strict). The pragma
# waives matches on its own line or, when it is a whole-line comment, on the
# line directly below.
# ---------------------------------------------------------------------------
PRAGMA_RE = re.compile(
    r"#\s*repro-analysis:\s*disable=(?P<codes>[A-Z0-9,\s]+?)"
    r"(?:\s+reason=(?P<reason>.*))?$")


def parse_pragmas(source: str):
    """Map line number -> (set of rule codes, reason or None).

    A pragma on a code line waives that line; a standalone comment line
    waives the following line (both entries are emitted).
    """
    out = {}
    lines = source.splitlines()
    for i, line in enumerate(lines, 1):
        m = PRAGMA_RE.search(line)
        if not m:
            continue
        codes = {c.strip() for c in m.group("codes").split(",") if c.strip()}
        reason = m.group("reason")
        reason = reason.strip() if reason else None
        out[i] = (codes, reason)
        if line.lstrip().startswith("#"):
            out[i + 1] = (codes, reason)
    return out
