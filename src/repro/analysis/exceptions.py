"""Pass 5 — swallowed exceptions in the fault-handling paths (RA501).

The fault model (docs/serving.md) requires every caught fault to be
OBSERVABLE: an `except` clause in the serving/core trees must either
re-raise, or record the event somewhere telemetry can see it — a
RuntimeMonitor / injector call (`monitor.*`, `record_*`, `on_*`, `log*`),
or a counter bump on a fault/telemetry attribute (`*.cancels += 1`,
`self.stats[...] = ...`). A handler that does neither silently converts a
fault into wrong behavior the chaos benchmarks cannot attribute.

Like the other passes this is deliberately syntactic: it proves the
*presence* of a recording pattern in the handler body, not that the value
recorded is meaningful.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis import rules
from repro.analysis.common import (SourceFile, Violation, apply_waivers,
                                   dotted, load_files)

# dotted-name segments that mark a call as "recording" the fault
_RECORDING_SEGMENTS = {"monitor", "logger", "logging", "warnings"}
_RECORDING_PREFIXES = ("record", "on_", "log", "warn", "abort", "fault",
                       "note")
# attribute/subscript name segments that count as telemetry counters when
# assigned/augmented inside a handler
_COUNTER_SEGMENTS = ("fault", "shed", "retr", "cancel", "fail", "event",
                     "loss", "stat", "error", "count", "degraded", "crash")


def _call_records(call: ast.Call) -> bool:
    d = dotted(call.func)
    if not d:
        return False
    parts = d.split(".")
    if any(p in _RECORDING_SEGMENTS for p in parts):
        return True
    return any(parts[-1].startswith(p) for p in _RECORDING_PREFIXES)


def _target_is_counter(node: ast.AST) -> bool:
    """`self.cancels`, `monitor.net_failures`, `self.stats["x"]`, ..."""
    if isinstance(node, ast.Subscript):
        node = node.value
    d = dotted(node).lower()
    return any(seg in d for seg in _COUNTER_SEGMENTS)


def _handler_observes(handler: ast.ExceptHandler) -> bool:
    for node in ast.walk(handler):
        if isinstance(node, ast.Raise):
            return True
        if isinstance(node, ast.Call) and _call_records(node):
            return True
        if isinstance(node, ast.AugAssign) and _target_is_counter(node.target):
            return True
        if isinstance(node, ast.Assign) and any(
                _target_is_counter(t) for t in node.targets):
            return True
    return False


def check_file(sf: SourceFile) -> List[Violation]:
    out: List[Violation] = []
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if _handler_observes(node):
            continue
        caught = dotted(node.type) if node.type is not None else "BaseException"
        out.append(Violation(
            file=sf.rel, line=node.lineno, code="RA501",
            message=rules.RULES["RA501"] + f" (catches {caught or 'tuple'})"))
    return apply_waivers(sf, out)


def run(root) -> List[Violation]:
    out: List[Violation] = []
    for sf in load_files(root, rules.EXCEPTIONS_SCOPE):
        out.extend(check_file(sf))
    return out
