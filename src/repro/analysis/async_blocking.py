"""Pass 6 — async-blocking (RA601-RA602).

The serving front-end and load generator are cooperative asyncio code: ONE
driver coroutine interleaves engine steps with request intake/streaming, so
a single blocking call in these modules stalls every in-flight stream at
once — there is no other thread to make progress.

  RA601  `time.sleep` in the async serving layer (use `await
         asyncio.sleep`; a bare `sleep` imported from time counts too,
         an awaited `sleep(...)` does not).
  RA602  bare device sync — `jax.device_get` / `.block_until_ready` — in
         an async path. The engine's step/harvest entry points are the only
         sanctioned device boundary; the front-end must consume tokens the
         engine has already committed to host, never force its own sync.

Purely syntactic like the other passes: it proves the presence of known
blocking patterns in the scoped files, not their absence elsewhere.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis import rules
from repro.analysis.common import (SourceFile, Violation, apply_waivers,
                                   dotted, load_files, parent_map)


def check_file(sf: SourceFile) -> List[Violation]:
    out: List[Violation] = []
    parents = parent_map(sf.tree)
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        d = dotted(node.func)
        if d == "time.sleep" or (
                d == "sleep" and not isinstance(parents.get(node),
                                                ast.Await)):
            out.append(Violation(
                file=sf.rel, line=node.lineno, code="RA601",
                message="blocking sleep stalls every in-flight stream on "
                        "the event loop (use `await asyncio.sleep`)"))
        elif d in ("jax.device_get", "device_get") \
                or d.endswith(".block_until_ready"):
            out.append(Violation(
                file=sf.rel, line=node.lineno, code="RA602",
                message=f"`{d}` forces a device sync in an async serving "
                        "path (the engine step/harvest is the only "
                        "sanctioned device boundary)"))
    return apply_waivers(sf, out)


def run(root) -> List[Violation]:
    out: List[Violation] = []
    for sf in load_files(root, rules.ASYNC_SCOPE):
        out.extend(check_file(sf))
    return out
