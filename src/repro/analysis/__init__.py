"""Static invariant checkers for the serving engine and kernels.

Six passes (see docs/static-analysis.md for the rule catalogue):

  host_sync       RA1xx  one-readback-per-step / implicit device syncs
  recompile       RA2xx  bounded jit shape variants + shared registry
  donation        RA3xx  donated buffers never read after dispatch
  pallas_spec     RA4xx  BlockSpec arity/alignment/VMEM contracts
  exceptions      RA5xx  caught faults must be re-raised or recorded
  async_blocking  RA6xx  no blocking calls on the serving event loop

Run `python -m repro.analysis --strict` locally or in CI. Everything in this
package is stdlib-only: the passes parse source and never import the modules
they check.
"""
from __future__ import annotations

from pathlib import Path
from typing import List

from repro.analysis import (async_blocking, donation, exceptions, host_sync,
                            pallas_spec, recompile, rules)
from repro.analysis.common import SourceFile, Violation

PASSES = {
    "host-sync": host_sync.run,
    "recompile": recompile.run,
    "donation": donation.run,
    "pallas-spec": pallas_spec.run,
    "exceptions": exceptions.run,
    "async-blocking": async_blocking.run,
}


def package_root() -> Path:
    """The `repro` package directory that pass scopes are relative to."""
    return Path(__file__).resolve().parents[1]


def run_all(root: Path = None, passes=None) -> List[Violation]:
    root = root or package_root()
    out: List[Violation] = []
    for name in (passes or PASSES):
        out.extend(PASSES[name](root))
    out.sort(key=lambda v: (v.file, v.line, v.code))
    return out


__all__ = ["PASSES", "run_all", "package_root", "Violation", "SourceFile",
           "rules"]
