"""Pass 1 — trace-safety / host-sync (RA101-RA104).

Enforces the engine's one-readback-per-step contract over the serving hot
paths: the ONLY unannotated `jax.device_get` lives in the engine's deferred
harvest; every implicit sync — `.item()`, `float()/int()/bool()` on a device
value, `np.asarray` on a device value, `block_until_ready` — is a violation
unless explicitly waived with a pragma.

The pass is a lightweight per-function taint analysis: names assigned from
device-producing calls (jnp.*, jax.* transforms, jitted callables, the
engine's sampler helpers) are "device"; subscripts/attribute loads/arithmetic
propagate the taint; `jax.device_get` results are host values and clear it.
It is deliberately syntactic — it proves the *presence* of known sync
patterns, not their absence.
"""
from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis import rules
from repro.analysis.common import (SourceFile, Violation, apply_waivers,
                                   dotted, load_files)

_SCALAR_CASTS = {"float", "int", "bool"}
_NP_TRANSFER = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
                "onp.asarray", "onp.array"}
# attribute-call suffixes on `self.` / locals that return device arrays
_DEVICE_METHOD_PREFIXES = ("_decode_run", "_prefill", "_score", "_fork",
                           "_insert", "_feed_chunk", "_ingest_chunk")
_DEVICE_FN_NAMES = {"sample", "token_logprob"}


def _is_device_get(call: ast.Call) -> bool:
    return dotted(call.func) in ("jax.device_get", "device_get")


def _is_jit_expr(node: ast.AST) -> bool:
    """`jax.jit(...)` (possibly keyword-heavy) producing a jitted callable."""
    return (isinstance(node, ast.Call)
            and dotted(node.func) in ("jax.jit", "functools.partial")
            and any(dotted(getattr(a, "func", None)) == "jax.jit"
                    for a in ast.walk(node) if isinstance(a, ast.Call))
            or (isinstance(node, ast.Call)
                and dotted(node.func) == "jax.jit"))


class _FnChecker(ast.NodeVisitor):
    def __init__(self, sf: SourceFile, fn: ast.FunctionDef,
                 jitted_names: Set[str], allowlisted: bool):
        self.sf = sf
        self.fn = fn
        self.jitted = jitted_names
        self.allowlisted = allowlisted
        self.taint: Set[str] = set()
        self.violations: List[Violation] = []

    # -- taint machinery -------------------------------------------------
    def _producer_call(self, call: ast.Call) -> bool:
        d = dotted(call.func)
        if not d:
            return False
        if _is_device_get(call):
            return False                      # readback: result is host
        if d.startswith("jnp.") or d.startswith("jax."):
            return True
        if d.startswith("transformer."):
            return True
        last = d.split(".")[-1]
        if last in _DEVICE_FN_NAMES or last in self.jitted:
            return True
        return any(last.startswith(p) for p in _DEVICE_METHOD_PREFIXES)

    def _tainted(self, node: ast.AST) -> bool:
        for n in ast.walk(node):
            if isinstance(n, (ast.Name, ast.Attribute)):
                if dotted(n) in self.taint:
                    return True
            elif isinstance(n, ast.Call) and self._producer_call(n):
                return True
        return False

    def _mark(self, target: ast.AST, on: bool):
        for n in ast.walk(target):
            if isinstance(n, (ast.Name, ast.Attribute)):
                d = dotted(n)
                if d:
                    (self.taint.add if on else self.taint.discard)(d)

    # -- sinks -----------------------------------------------------------
    def _report(self, node: ast.AST, code: str, msg: str):
        self.violations.append(Violation(
            file=self.sf.rel, line=node.lineno, code=code, message=msg))

    def visit_Call(self, node: ast.Call):
        d = dotted(node.func)
        if _is_device_get(node):
            if not self.allowlisted:
                self._report(node, "RA103",
                             "jax.device_get outside the sanctioned harvest "
                             f"site (in `{self.fn.name}`)")
            # arguments are read, result is host: fall through to visit args
        elif d in _SCALAR_CASTS and node.args \
                and self._tainted(node.args[0]):
            self._report(node, "RA101",
                         f"`{d}()` on a device value forces a host sync")
        elif d.endswith(".item") and isinstance(node.func, ast.Attribute) \
                and self._tainted(node.func.value):
            self._report(node, "RA101",
                         "`.item()` on a device value forces a host sync")
        elif d in _NP_TRANSFER and node.args \
                and self._tainted(node.args[0]):
            self._report(node, "RA102",
                         f"`{d}` on a device value forces a transfer")
        elif d.endswith(".block_until_ready"):
            self._report(node, "RA104",
                         "block_until_ready stalls the dispatch pipeline")
        self.generic_visit(node)

    # -- statement-order taint updates ----------------------------------
    def visit_Assign(self, node: ast.Assign):
        self.visit(node.value)
        # a readback result is a host value, also through [slices]
        root = node.value
        while isinstance(root, ast.Subscript):
            root = root.value
        clean = isinstance(root, ast.Call) and _is_device_get(root)
        on = (not clean) and self._tainted(node.value)
        for t in node.targets:
            self._mark(t, on)

    def visit_AugAssign(self, node: ast.AugAssign):
        self.visit(node.value)
        if self._tainted(node.value):
            self._mark(node.target, True)

    def visit_For(self, node: ast.For):
        self.visit(node.iter)
        if self._tainted(node.iter):
            self._mark(node.target, True)
        for stmt in node.body + node.orelse:
            self.visit(stmt)

    def _visit_comp(self, node):
        for gen in node.generators:
            self.visit(gen.iter)
            if self._tainted(gen.iter):
                self._mark(gen.target, True)
        for field in ("elt", "key", "value"):
            sub = getattr(node, field, None)
            if sub is not None:
                self.visit(sub)

    visit_ListComp = visit_SetComp = visit_DictComp = _visit_comp
    visit_GeneratorExp = _visit_comp

    def visit_FunctionDef(self, node: ast.FunctionDef):
        if node is not self.fn:
            return                             # nested defs: checked separately
        for stmt in node.body:
            self.visit(stmt)

    visit_AsyncFunctionDef = visit_FunctionDef


def _jitted_names(tree: ast.AST) -> Set[str]:
    """Names bound (module- or function-level) to jitted callables, plus
    functions decorated with @jax.jit."""
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and _is_jit_expr(node.value):
            for t in node.targets:
                for n in ast.walk(t):
                    if isinstance(n, (ast.Name, ast.Attribute)):
                        d = dotted(n)
                        if d:
                            names.add(d.split(".")[-1])
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if "jit" in dotted(dec) or "jit" in dotted(
                        getattr(dec, "func", ast.Pass())):
                    names.add(node.name)
    return names


def check_file(sf: SourceFile) -> List[Violation]:
    jitted = _jitted_names(sf.tree)
    out: List[Violation] = []
    for node in ast.walk(sf.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        allow = any(sf.rel.endswith(path) and node.name == fn
                    for path, fn in rules.HOST_SYNC_ALLOWLIST)
        checker = _FnChecker(sf, node, jitted, allow)
        checker.visit_FunctionDef(node)
        out.extend(checker.violations)
    return apply_waivers(sf, out)


def run(root) -> List[Violation]:
    out: List[Violation] = []
    for sf in load_files(root, rules.HOST_SYNC_SCOPE):
        out.extend(check_file(sf))
    return out
