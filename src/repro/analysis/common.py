"""Shared plumbing for the static passes: violations, file walking, waivers.

Everything here is stdlib-only (ast + pathlib): the passes parse source, they
never import the modules they check, so the CLI runs in environments without
jax (e.g. the CI analysis job) and on fixture files that are deliberately
broken.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple

from repro.analysis import rules


@dataclasses.dataclass
class Violation:
    file: str                   # path as reported (relative to package root)
    line: int
    code: str
    message: str
    waived: bool = False
    waive_reason: Optional[str] = None

    def render(self) -> str:
        tag = " (waived: %s)" % (self.waive_reason or "no reason given") \
            if self.waived else ""
        return f"{self.file}:{self.line}: {self.code} {self.message}{tag}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class SourceFile:
    """One parsed file plus its pragma table."""
    path: Path                  # absolute
    rel: str                    # path relative to the scanned root
    source: str
    tree: ast.AST
    pragmas: Dict[int, Tuple[set, Optional[str]]]

    @classmethod
    def load(cls, path: Path, root: Path) -> "SourceFile":
        src = path.read_text()
        return cls(path=path, rel=str(path.relative_to(root)), source=src,
                   tree=ast.parse(src, filename=str(path)),
                   pragmas=rules.parse_pragmas(src))


def load_files(root: Path, suffixes: Iterable[str]) -> List[SourceFile]:
    """Files under `root` whose root-relative path starts with (or equals)
    one of `suffixes` (directory prefixes end with '/')."""
    out = []
    for p in sorted(root.rglob("*.py")):
        rel = str(p.relative_to(root))
        for s in suffixes:
            if rel == s or (s.endswith("/") and rel.startswith(s)):
                out.append(SourceFile.load(p, root))
                break
    return out


def apply_waivers(sf: SourceFile, violations: List[Violation]
                  ) -> List[Violation]:
    """Mark violations matched by an inline pragma as waived."""
    for v in violations:
        entry = sf.pragmas.get(v.line)
        if entry and v.code in entry[0]:
            v.waived = True
            v.waive_reason = entry[1]
    return violations


def dotted(node: ast.AST) -> str:
    """'jax.device_get' for Attribute/Name chains, '' otherwise."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    return ""


def parent_map(tree: ast.AST) -> Dict[ast.AST, ast.AST]:
    return {child: parent for parent in ast.walk(tree)
            for child in ast.iter_child_nodes(parent)}


def enclosing_function(node: ast.AST, parents: Dict[ast.AST, ast.AST]
                       ) -> Optional[ast.FunctionDef]:
    cur = parents.get(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur
        cur = parents.get(cur)
    return None
