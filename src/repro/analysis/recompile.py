"""Pass 2 — recompile budget (RA201-RA205).

The engine's latency contract allows a bounded set of jit shape variants per
config: prompt/score buffers bucket to powers of two *clamped to max_len*,
paged read widths bucket via `_chunk_live`/`_live_pages`, ragged ingest rows
bucket via a pow2 loop, and every `jax.jit` in serving lives inside the
shared `lru_cache` registry so fleets and A/B pairs share one trace cache.

This pass enforces the *syntactic* shape of that contract:

  RA201  a call to a power-of-two bucket helper that does not clamp (either
         the helper itself returns `min(...)` or the call site wraps it in
         `min(...)`) — the PR-5 `score()` bug class: one long request
         compiles (and can OOM) an arbitrarily large variant.
  RA202  a `jax.jit` call in serving code outside an lru_cache-decorated
         registry function.
  RA203  a call to a static-argnums jitted engine entry point whose static
         (first) argument is visibly request-derived — contains `len(...)`
         or a per-request attribute — or is a local name with no bucketed
         provenance.
  RA204  a jit registry (a function returning >= 2 jax.jit closures) that is
         not lru_cache-decorated, so every engine instance recompiles.
  RA205  a jitted entry point taken from a registry (`self.x = _jitted(...)`)
         that `warmup()` never references — its first call pays its XLA
         compile inside a serving window, exactly what warmup() exists to
         front-load. Classes holding registry entries without any warmup()
         are flagged the same way.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis import rules
from repro.analysis.common import (SourceFile, Violation, apply_waivers,
                                   dotted, enclosing_function, load_files,
                                   parent_map)

# entry points the engine jits with static_argnums=(0,): the first argument
# is a SHAPE and must come from a bucketing helper or a config bound
_STATIC_ARG_CALLEES = ("_prefill_chunk", "_prefill_ragged", "_decode_run")
_REQUEST_ATTRS = {"ctx_len", "prompt", "tokens", "prefill_toks", "pending",
                  "suffix", "carry_tokens"}


def _helper_name(call: ast.Call) -> Optional[str]:
    last = dotted(call.func).split(".")[-1]
    return last if last in rules.BUCKET_HELPERS else None


def _self_clamping_helpers(tree: ast.AST) -> Set[str]:
    """Bucket helpers whose own return value is clamped (contains min(...)
    or delegates to another self-clamping helper)."""
    defs = {n.name: n for n in ast.walk(tree)
            if isinstance(n, ast.FunctionDef)
            and n.name in rules.BUCKET_HELPERS}
    clamped: Set[str] = set()
    for _ in range(len(defs) + 1):          # fixpoint over delegation chains
        for name, fn in defs.items():
            if name in clamped:
                continue
            for ret in ast.walk(fn):
                if not isinstance(ret, ast.Return) or ret.value is None:
                    continue
                for c in ast.walk(ret.value):
                    if isinstance(c, ast.Call) and (
                            dotted(c.func) == "min"
                            or _helper_name(c) in clamped):
                        clamped.add(name)
    return clamped


def _wrapped_in_min(node: ast.AST, parents: Dict[ast.AST, ast.AST]) -> bool:
    cur = parents.get(node)
    while cur is not None and not isinstance(cur, ast.stmt):
        if isinstance(cur, ast.Call) and dotted(cur.func) == "min":
            return True
        cur = parents.get(cur)
    return False


def _bucketed_names(fn: ast.FunctionDef) -> Set[str]:
    """Local names with bucketed provenance inside `fn`: assigned from a
    bucket-helper call, doubled in a pow2 while loop, looped over a bucketed
    collection, or a collection accumulating bucketed values."""
    bucketed: Set[str] = set()

    def expr_bucketed(e: ast.AST) -> bool:
        for n in ast.walk(e):
            if isinstance(n, ast.Call) and _helper_name(n):
                return True
            if isinstance(n, ast.Name) and n.id in bucketed:
                return True
        return False

    for _ in range(3):                       # small fixpoint
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and expr_bucketed(node.value):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        bucketed.add(t.id)
            elif isinstance(node, ast.While):
                # `while r < n: r *= 2` — the pow2 bucket idiom
                for sub in ast.walk(node):
                    if (isinstance(sub, ast.AugAssign)
                            and isinstance(sub.op, ast.Mult)
                            and isinstance(sub.target, ast.Name)):
                        bucketed.add(sub.target.id)
            elif isinstance(node, ast.For) and expr_bucketed(node.iter):
                if isinstance(node.target, ast.Name):
                    bucketed.add(node.target.id)
            elif (isinstance(node, ast.Call)
                  and isinstance(node.func, ast.Attribute)
                  and node.func.attr in ("add", "append")
                  and isinstance(node.func.value, ast.Name)
                  and node.args and expr_bucketed(node.args[0])):
                bucketed.add(node.func.value.id)
    return bucketed


def _first_arg_ok(arg: ast.AST, bucketed: Set[str]) -> Optional[str]:
    """None if the static arg is acceptable, else a reason string."""
    for n in ast.walk(arg):
        if isinstance(n, ast.Call) and _helper_name(n):
            return None
        if isinstance(n, ast.Call) and dotted(n.func) == "len":
            return "contains len(...) of request data"
        if isinstance(n, ast.Attribute) and n.attr in _REQUEST_ATTRS:
            return f"derived from per-request `.{n.attr}`"
    if isinstance(arg, ast.Constant) and isinstance(arg.value, int):
        return None
    if isinstance(arg, ast.Attribute):
        if arg.attr in rules.BOUNDED_ATTR_NAMES:
            return None
        return None                          # conservative: config attrs pass
    if isinstance(arg, ast.Name):
        if arg.id in bucketed:
            return None
        return f"`{arg.id}` has no bucketed provenance in this function"
    return None


def _has_lru_cache(fn: ast.FunctionDef) -> bool:
    for dec in fn.decorator_list:
        d = dotted(dec) or dotted(getattr(dec, "func", ast.Pass()))
        if "lru_cache" in d or "cache" == d.split(".")[-1]:
            return True
    return False


def check_file(sf: SourceFile) -> List[Violation]:
    out: List[Violation] = []
    parents = parent_map(sf.tree)
    clamped = _self_clamping_helpers(sf.tree)
    in_serving = "serving/" in sf.rel or sf.rel.startswith("serving")

    registries: Set[str] = set()
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.FunctionDef):
            # registry pattern: a function whose RETURN VALUE is a jitted
            # closure (vs. one that merely builds and calls jits locally)
            returns_jit = any(
                isinstance(r, ast.Return) and r.value is not None
                and any(isinstance(c, ast.Call)
                        and dotted(c.func) == "jax.jit"
                        for c in ast.walk(r.value))
                for r in ast.walk(node) if isinstance(r, ast.Return))
            if returns_jit:
                registries.add(node.name)
            if returns_jit and not _has_lru_cache(node):
                out.append(Violation(
                    file=sf.rel, line=node.lineno, code="RA204",
                    message=f"jit registry `{node.name}` returns jitted "
                            "closures but is not lru_cache-decorated: every "
                            "caller recompiles its variants"))

    # RA205: every registry-held entry point an engine class binds must be
    # referenced by its warmup() — warmup is the precompile list, and an
    # unlisted entry pays its first compile inside a serving window
    for cls in ast.walk(sf.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        warmups = [m for m in cls.body
                   if isinstance(m, ast.FunctionDef) and m.name == "warmup"]
        warmed: Set[str] = set()
        for w in warmups:
            warmed |= {n.attr for n in ast.walk(w)
                       if isinstance(n, ast.Attribute)}
        for node in ast.walk(cls):
            if not (isinstance(node, ast.Assign)
                    and isinstance(node.value, ast.Call)
                    and dotted(node.value.func).split(".")[-1] in registries):
                continue
            for t in node.targets:
                if not (isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self") or t.attr in warmed:
                    continue
                why = ("never referenced in warmup()" if warmups
                       else "the class defines no warmup()")
                out.append(Violation(
                    file=sf.rel, line=node.lineno, code="RA205",
                    message=f"jitted entry point `self.{t.attr}` is not "
                            f"precompiled: {why} — its first call pays its "
                            "XLA compile inside the serving window"))

    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        # RA202: serving jits must live in the shared registry
        if dotted(node.func) == "jax.jit" and in_serving:
            fn = enclosing_function(node, parents)
            if fn is None or not _has_lru_cache(fn):
                out.append(Violation(
                    file=sf.rel, line=node.lineno, code="RA202",
                    message="jax.jit outside the shared lru_cache registry: "
                            "engines with the same config will not share "
                            "this trace cache"))
        # RA201: unclamped bucket
        helper = _helper_name(node)
        if helper and helper not in clamped \
                and not _wrapped_in_min(node, parents):
            fn = enclosing_function(node, parents)
            # the helper's own recursive body is not a call site to clamp
            if fn is None or fn.name != helper:
                out.append(Violation(
                    file=sf.rel, line=node.lineno, code="RA201",
                    message=f"`{helper}(...)` used without an upper clamp: "
                            "one long request compiles an unbounded shape "
                            "variant (wrap in min(..., max_len))"))
        # RA203: static shape args at engine entry points
        last = dotted(node.func).split(".")[-1]
        if any(last.startswith(c) for c in _STATIC_ARG_CALLEES) \
                and isinstance(node.func, ast.Attribute) and node.args:
            fn = enclosing_function(node, parents)
            bucketed = _bucketed_names(fn) if fn is not None else set()
            reason = _first_arg_ok(node.args[0], bucketed)
            if reason:
                out.append(Violation(
                    file=sf.rel, line=node.lineno, code="RA203",
                    message=f"static argument of `{last}` is not visibly "
                            f"bucketed: {reason}"))
    return apply_waivers(sf, out)


def run(root) -> List[Violation]:
    out: List[Violation] = []
    for sf in load_files(root, rules.RECOMPILE_SCOPE):
        out.extend(check_file(sf))
    return out
