"""Pass 3 — donation safety (RA301-RA302).

`donate_argnums` hands the XLA runtime the donated buffer's memory: after the
call, the Python reference points at freed (or aliased-output) storage, and
reading it is undefined behaviour that jax only sometimes catches at runtime.
The engine's convention is that every donating call *reassigns the donated
name in the same statement* — `self.cache = self._insert(self.cache, ...)` —
so there is no window in which the stale reference is reachable.

The pass reconstructs donation maps from two sources:

  * direct bindings:  `f = jax.jit(fn, donate_argnums=(0, 1))`
  * the engine's lru_cache registry: a function whose body returns
    `jax.jit(..., donate_argnums=...)` per `kind ==` branch, plus
    `self.attr = _registry(cfg, "kind")` bindings mapping attributes to
    those kinds.

At every call through a donating binding, each donated positional argument
that names a long-lived buffer (cache / params / opt_state / state) must be
reassigned by the enclosing statement (RA301); any later read of that name
before its next reassignment is RA302.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis import rules
from repro.analysis.common import (SourceFile, Violation, apply_waivers,
                                   dotted, enclosing_function, load_files,
                                   parent_map)

_BUFFER_HINTS = ("cache", "params", "opt_state", "state")


def _is_bufferish(arg: ast.AST) -> Optional[str]:
    """Dotted name if `arg` names a long-lived buffer, else None."""
    if isinstance(arg, (ast.Name, ast.Attribute)):
        d = dotted(arg)
        last = d.split(".")[-1]
        if any(h in last for h in _BUFFER_HINTS):
            return d
    return None


def _donate_indices(jit_call: ast.Call) -> Tuple[int, ...]:
    for kw in jit_call.keywords:
        if kw.arg == "donate_argnums" and isinstance(kw.value, ast.Tuple):
            return tuple(e.value for e in kw.value.elts
                         if isinstance(e, ast.Constant)
                         and isinstance(e.value, int))
    return ()


def _jit_call_in(expr: ast.AST) -> Optional[ast.Call]:
    for n in ast.walk(expr):
        if isinstance(n, ast.Call) and dotted(n.func) == "jax.jit":
            return n
    return None


def _registry_kind_map(tree: ast.AST) -> Dict[str, Set[Tuple[int, ...]]]:
    """kind-string -> set of donate-index tuples, from any function whose
    body dispatches `kind == "..."` to `return jax.jit(...)`."""
    kinds: Dict[str, Set[Tuple[int, ...]]] = {}
    for fn in ast.walk(tree):
        if not isinstance(fn, ast.FunctionDef):
            continue
        for br in ast.walk(fn):
            if not isinstance(br, ast.If):
                continue
            test = br.test
            kind_strs = [c.value for c in ast.walk(test)
                         if isinstance(c, ast.Constant)
                         and isinstance(c.value, str)]
            if not kind_strs:
                continue
            for ret in br.body:
                jc = _jit_call_in(ret) if isinstance(ret, ast.Return) else None
                if jc is not None:
                    idx = _donate_indices(jc)
                    for k in kind_strs:
                        kinds.setdefault(k, set()).add(idx)
    return kinds


def _donor_bindings(tree: ast.AST,
                    kinds: Dict[str, Set[Tuple[int, ...]]]
                    ) -> Dict[str, Set[Tuple[int, ...]]]:
    """Last-segment name -> possible donate-index tuples (non-empty only)."""
    donors: Dict[str, Set[Tuple[int, ...]]] = {}
    registry_names = set()
    for fn in ast.walk(tree):
        if isinstance(fn, ast.FunctionDef) and any(
                isinstance(c, ast.Call) and dotted(c.func) == "jax.jit"
                for c in ast.walk(fn)):
            registry_names.add(fn.name)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        name = None
        for t in node.targets:
            if isinstance(t, (ast.Name, ast.Attribute)):
                name = dotted(t).split(".")[-1]
        if name is None:
            continue
        jc = _jit_call_in(node.value)
        if jc is not None:
            idx = _donate_indices(jc)
            if idx:
                donors.setdefault(name, set()).add(idx)
            continue
        # registry binding: self.attr = _registry(cfg, "kind", ...)
        if isinstance(node.value, ast.Call) \
                and dotted(node.value.func).split(".")[-1] in registry_names:
            for a in node.value.args:
                if isinstance(a, ast.Constant) and isinstance(a.value, str) \
                        and a.value in kinds:
                    for idx in kinds[a.value]:
                        if idx:
                            donors.setdefault(name, set()).add(idx)
    return donors


def _stmt_of(node: ast.AST, parents) -> Optional[ast.stmt]:
    cur = node
    while cur is not None and not isinstance(cur, ast.stmt):
        cur = parents.get(cur)
    return cur


def _targets_of(stmt: ast.stmt) -> Set[str]:
    out: Set[str] = set()
    targets = []
    if isinstance(stmt, ast.Assign):
        targets = stmt.targets
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    for t in targets:
        for n in ast.walk(t):
            if isinstance(n, (ast.Name, ast.Attribute)):
                d = dotted(n)
                if d:
                    out.add(d)
    return out


def _reads(stmt: ast.stmt, name: str) -> Optional[int]:
    """Line of the first Load of `name` in `stmt`, else None."""
    for n in ast.walk(stmt):
        if isinstance(n, (ast.Name, ast.Attribute)) \
                and isinstance(getattr(n, "ctx", None), ast.Load) \
                and dotted(n) == name:
            return n.lineno
    return None


def check_file(sf: SourceFile) -> List[Violation]:
    out: List[Violation] = []
    parents = parent_map(sf.tree)
    kinds = _registry_kind_map(sf.tree)
    donors = _donor_bindings(sf.tree, kinds)
    if not donors:
        return out

    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        callee = dotted(node.func).split(".")[-1]
        idx_sets = donors.get(callee)
        if not idx_sets:
            continue
        donated = {i for idx in idx_sets for i in idx}
        stmt = _stmt_of(node, parents)
        fn = enclosing_function(node, parents)
        for i in sorted(donated):
            if i >= len(node.args):
                continue
            buf = _is_bufferish(node.args[i])
            if buf is None:
                continue
            reassigned_here = stmt is not None and buf in _targets_of(stmt)
            if not reassigned_here:
                out.append(Violation(
                    file=sf.rel, line=node.lineno, code="RA301",
                    message=f"`{buf}` is donated (argnum {i}) to `{callee}` "
                            "but the statement does not rebind it; the stale "
                            "reference now points at freed storage"))
                # RA302: a later read before the next rebind
                if fn is not None:
                    body = [s for s in ast.walk(fn) if isinstance(s, ast.stmt)
                            and s.lineno > node.lineno]
                    for s in sorted(body, key=lambda s: s.lineno):
                        if buf in _targets_of(s):
                            break
                        rd = _reads(s, buf)
                        if rd is not None:
                            out.append(Violation(
                                file=sf.rel, line=rd, code="RA302",
                                message=f"`{buf}` read after being donated "
                                        f"at line {node.lineno}"))
                            break
    return apply_waivers(sf, out)


def run(root) -> List[Violation]:
    out: List[Violation] = []
    for sf in load_files(root, rules.DONATION_SCOPE):
        out.extend(check_file(sf))
    return out
