"""Paper Fig. 6: dynamic vs static scheduling (throughput/latency), plus
cloud-only and routing for reference."""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core.simulator import (SimConfig, make_requests,
                                  simulate_cloud_only, simulate_pice,
                                  simulate_routing)


def run(n_requests: int = 300):
    base = dict(cloud_model="llama3-70b", cloud_batch=20, rpm=60,
                n_requests=n_requests)
    rows = {}
    for name, fn, kw in [
        ("cloud_only", simulate_cloud_only, {}),
        ("routing", simulate_routing, {}),
        ("pice_static", simulate_pice, {"dynamic": False}),
        ("pice_dynamic", simulate_pice, {"dynamic": True}),
    ]:
        cfg = SimConfig(**base, **kw)
        res, us = timed(fn, cfg, make_requests(n_requests, cfg.rpm, cfg.seed))
        rows[name] = res
        emit(f"fig6/{name}", us, f"thr={res.throughput_per_min:.2f}/min;"
                                 f"lat={res.avg_latency_s:.2f}s")
    gain = (rows["pice_dynamic"].throughput_per_min
            / max(rows["pice_static"].throughput_per_min, 1e-9) - 1)
    emit("fig6/dynamic_over_static", 0.0, f"throughput_gain={gain:.1%}")
    return rows


if __name__ == "__main__":
    run()
