"""Paper Table III: inference efficiency (throughput + latency) of
Cloud-only / Edge-only / Routing / PICE across cloud models, under the
paper's protocol (RPM = 1.5 x cloud max batch size).

Validation targets: PICE 1.5-2x cloud-only throughput for 70B-class clouds;
latency reduction >= 43%; Llama3-8B cloud => PICE ~ cloud-only; edge-only
worst; routing below cloud-only under load."""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core.simulator import METHODS, SimConfig, make_requests

# (cloud model, cloud max batch) — batch scaled inversely with model size as
# in the paper's setup ("other devices and models proportionally adjusted")
SETTINGS = [
    ("qwen2.5-72b", 20),
    ("llama3-70b", 20),
    ("qwen2.5-32b", 44),
    ("llama3-8b", 80),
    ("qwen2.5-7b", 84),
    ("qwen2.5-1.5b", 120),
]


def run(n_requests: int = 300):
    rows = {}
    for model, batch in SETTINGS:
        edge = tuple(m for m, _ in SETTINGS
                     if _param_rank(m) < _param_rank(model)) or ("qwen2.5-1.5b",)
        cfg = SimConfig(cloud_model=model, cloud_batch=batch,
                        rpm=1.5 * batch, n_requests=n_requests,
                        edge_models=edge[-3:])
        for method, fn in METHODS.items():
            reqs = make_requests(cfg.n_requests, cfg.rpm, cfg.seed)
            res, us = timed(fn, cfg, reqs)
            rows[(model, method)] = res
            emit(f"table3/{model}/{method}", us,
                 f"thr={res.throughput_per_min:.2f}/min;"
                 f"lat={res.avg_latency_s:.2f}s")
        c, p = rows[(model, "cloud_only")], rows[(model, "pice")]
        ratio = p.throughput_per_min / max(c.throughput_per_min, 1e-9)
        cut = 1 - p.avg_latency_s / max(c.avg_latency_s, 1e-9)
        emit(f"table3/{model}/pice_vs_cloud", 0.0,
             f"tput_ratio={ratio:.2f};latency_cut={cut:.1%}")
    return rows


_RANKS = {"qwen2.5-1.5b": 0, "qwen2.5-7b": 1, "llama3-8b": 2,
          "qwen2.5-32b": 3, "llama3-70b": 4, "qwen2.5-72b": 5}


def _param_rank(m):
    return _RANKS[m]


if __name__ == "__main__":
    run()
