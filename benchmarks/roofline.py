"""Roofline analysis over the dry-run artifacts (see EXPERIMENTS.md
§Roofline). Emits one row per (arch x shape x mesh) and writes
artifacts/roofline.md with the full table."""
from __future__ import annotations

from pathlib import Path

from benchmarks.common import emit
from repro.distributed.roofline import load_rows, suggestion

ART = Path(__file__).resolve().parent.parent / "artifacts"


def run(mesh: str = "pod16x16", write_md: bool = True):
    rows = load_rows(str(ART / "dryrun"), mesh=mesh)
    lines = [
        f"# Roofline — mesh {mesh} (197 TFLOP/s bf16, 819 GB/s HBM, "
        f"50 GB/s ICI per chip)",
        "",
        "| arch | shape | compute (s) | memory (s) | collective (s) | "
        "dominant | MODEL_FLOPS | useful ratio | scan-undercount | "
        "next lever |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.status != "ok":
            emit(f"roofline/{mesh}/{r.arch}/{r.shape}", 0.0,
                 f"status={r.status}")
            lines.append(f"| {r.arch} | {r.shape} | - | - | - | "
                         f"{r.status} | - | - | - | {r.note} |")
            continue
        emit(f"roofline/{mesh}/{r.arch}/{r.shape}", r.dominant_value() * 1e6,
             f"dom={r.dominant};compute={r.compute_s:.4f}s;"
             f"memory={r.memory_s:.4f}s;coll={r.collective_s:.4f}s;"
             f"ratio={r.useful_ratio:.2f}")
        lines.append(
            f"| {r.arch} | {r.shape} | {r.compute_s:.4f} | {r.memory_s:.4f} "
            f"| {r.collective_s:.4f} | **{r.dominant}** | "
            f"{r.model_flops:.3e} | {r.useful_ratio:.2f} | "
            f"{'yes' if r.scan_undercount else ''} | {suggestion(r)} |")
    if write_md:
        ART.mkdir(exist_ok=True)
        (ART / f"roofline_{mesh}.md").write_text("\n".join(lines) + "\n")
    return rows


if __name__ == "__main__":
    run()
