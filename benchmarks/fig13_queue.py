"""Paper Fig. 13: impact of the job-queue length cap.

Validation: optimum near the number of edge devices (4); much larger queues
inflate waiting time and end-to-end latency."""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit, timed
from repro.core.simulator import SimConfig, make_requests, simulate_pice


def run(n_requests: int = 250):
    out = {}
    for qmax in (1, 2, 4, 8, 16, 32):
        cfg = SimConfig(cloud_model="llama3-70b", cloud_batch=20, rpm=40,
                        n_requests=n_requests, queue_max=qmax)
        res, us = timed(simulate_pice, cfg,
                        make_requests(n_requests, cfg.rpm, cfg.seed))
        out[qmax] = res
        emit(f"fig13/queue_{qmax}", us,
             f"thr={res.throughput_per_min:.2f};lat={res.avg_latency_s:.1f}s")
    best = max(out, key=lambda q: out[q].throughput_per_min)
    emit("fig13/best_queue_len", 0.0, f"best={best}")
    return out


if __name__ == "__main__":
    run()
