"""Paper Figs. 8+9: SLM confidence diversity and ensemble quality gains.

Real-compute: trains three tiny edge SLMs (different seeds/families), expands
gold corpus sketches with each, and compares per-category quality (Rouge-1 F1
vs ground truth) of each single model against the Eq.(3) ensemble selection.

Validation targets: confidence rankings differ across categories (Fig. 8);
ensemble >= best single model on average (paper: +2.8% overall)."""
from __future__ import annotations

import jax

from benchmarks.common import emit
from repro.configs.pice_cloud_edge import TINY_EDGE_CONFIGS
from repro.core import ensemble as ens
from repro.core.metrics import rouge_1
from repro.data import corpus as corpus_lib
from repro.data import tokenizer as tok
from repro.data.pipeline import PackedDataset
from repro.serving.engine import InferenceEngine
from repro.training import optimizer as opt_lib
from repro.training.train_loop import init_train_state, train


def _train_engine(cfg, seed, steps=120, categories=None):
    # category-biased corpora give the SLMs complementary strengths
    # (paper §IV-C: diversity from variations in training data)
    text = corpus_lib.lm_text(1500, seed, categories=categories)
    ds = PackedDataset(text, 192, 8, seed)
    state = init_train_state(cfg, seed)
    state = train(cfg, state, iter(ds),
                  opt_lib.AdamWConfig(lr=2e-3, warmup_steps=10,
                                      total_steps=steps),
                  steps, log_every=10**9, log_fn=lambda s: None)
    return InferenceEngine(cfg, state.params, max_batch=4, max_len=768,
                           name=cfg.name)


def run(n_examples: int = 24, train_steps: int = 120):
    biases = {"tiny-edge-a": ["writing", "generic"],
              "tiny-edge-b": ["knowledge", "roleplay"],
              "tiny-edge-c": ["fermi", "stem"]}
    engines = {name: _train_engine(cfg, seed=i * 13 + 1, steps=train_steps,
                                   categories=biases.get(name))
               for i, (name, cfg) in enumerate(TINY_EDGE_CONFIGS.items())}
    cats = ["generic", "writing", "roleplay", "knowledge"]
    per_model = {m: [] for m in engines}
    ens_scores = []
    for ci, cat in enumerate(cats):
        examples = corpus_lib.corpus(max(n_examples // len(cats), 3),
                                     seed=100 + ci, category=cat)
        cat_scores = {m: [] for m in engines}
        cat_ens = []
        for ex in examples:
            prompt = tok.encode(
                f"Q: {ex.query}\nS: {ex.sketch}\nE: {ex.sketch_sentences[0]}|")
            cands = []
            for m, eng in engines.items():
                (out, lps), = eng.generate([prompt], max_new=72)
                text = tok.decode(out).strip()
                q = rouge_1(ex.answer_sentences[0], text)[2]
                cat_scores[m].append(q)
                per_model[m].append(q)
                cands.append(ens.Candidate(
                    text=text, mean_log2_prob=ens.mean_log2_from_nats(lps),
                    n_tokens=len(out), model=m, extra={"q": q}))
            best, _ = ens.select_best(cands, ex.sketch)
            cat_ens.append(best.extra["q"])
            ens_scores.append(best.extra["q"])
        for m in engines:
            emit(f"fig8/{cat}/{m}", 0.0,
                 f"quality={_avg(cat_scores[m]):.3f}")
        emit(f"fig9/{cat}/ensemble", 0.0, f"quality={_avg(cat_ens):.3f}")
    singles = {m: _avg(v) for m, v in per_model.items()}
    best_single = max(singles.values())
    emit("fig9/overall", 0.0,
         f"ensemble={_avg(ens_scores):.3f};best_single={best_single:.3f};"
         f"gain={(_avg(ens_scores) - best_single):.3f}")
    return singles, _avg(ens_scores)


def _avg(v):
    return sum(v) / max(len(v), 1)


if __name__ == "__main__":
    run()
