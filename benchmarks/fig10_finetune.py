"""Paper Figs. 10+11: effect of the §IV-D fine-tuning component.

SFT teaches the tiny cloud model to emit sketches; preference labeling +
reward model + RLAIF then push it toward *concise* sketches that preserve
semantics. Reports per-category sketch lengths before/after and the quality
proxy (Rouge-1 recall of key tokens in the sketch).

Validation targets: sketch length drops in most categories after RLAIF
(paper: writing 52.3->42.6, knowledge 36.9->27.7)."""
from __future__ import annotations

import jax

from benchmarks.common import emit
from repro.configs.pice_cloud_edge import TINY_CLOUD
from repro.core.metrics import rouge_1
from repro.data import corpus as corpus_lib
from repro.data import tokenizer as tok
from repro.finetune.preference import PreferenceTriple
from repro.finetune.reward_model import train_reward_model
from repro.finetune.rlaif import RLAIFConfig, run_rlaif
from repro.finetune.sft import run_sft
from repro.serving.engine import InferenceEngine


def _sketch_stats(cfg, params, cats, seed=0, n=6):
    eng = InferenceEngine(cfg, params, max_batch=4, max_len=768, name="sft")
    out = {}
    for ci, cat in enumerate(cats):
        lens, quals = [], []
        for ex in corpus_lib.corpus(n, seed=200 + ci, category=cat):
            prompt = tok.encode(f"A: {ex.answer[:200]}\nS:")
            (toks, _), = eng.generate([prompt], max_new=64)
            text = tok.decode(toks).strip()
            lens.append(len(text.split()))
            quals.append(rouge_1(ex.sketch, text)[1])
        out[cat] = (sum(lens) / len(lens), sum(quals) / len(quals))
    return out


def run(sft_steps: int = 150, rm_steps: int = 60, rl_steps: int = 12):
    cfg = TINY_CLOUD.with_(dtype="float32")
    cats = ["writing", "knowledge", "generic", "counterfactual"]

    state = run_sft(cfg, n_steps=sft_steps, log_fn=lambda s: None)
    before = _sketch_stats(cfg, state.params, cats)
    for cat, (l, q) in before.items():
        emit(f"fig10/before/{cat}", 0.0, f"sketch_len={l:.1f};quality={q:.3f}")

    # two-sided preference triples: the gold sketch must beat BOTH an
    # inflated sketch (verbose) and a truncated one (semantically broken) —
    # otherwise the reward model learns "shorter is always better" and RLAIF
    # collapses sketches to single words (observed; the paper's
    # conciseness/completeness trade-off taken to its degenerate end).
    triples = []
    for i, ex in enumerate(corpus_lib.corpus(64, seed=5)):
        if i % 2 == 0:
            bad = ex.answer[: len(ex.sketch) * 2]          # inflated
        else:
            bad = " ".join(ex.sketch.split()[:2])          # broken-short
        triples.append(PreferenceTriple(x=ex.answer[:120], r_w=ex.sketch,
                                        r_l=bad, score_w=1.0, score_l=0.0))
    rm_params = train_reward_model(cfg, triples, n_steps=rm_steps,
                                   log_fn=lambda s: None)
    policy, hist = run_rlaif(cfg, state.params, state.params, cfg, rm_params,
                             RLAIFConfig(n_steps=rl_steps, batch=2),
                             log_fn=lambda s: None)
    after = _sketch_stats(cfg, policy, cats)
    shorter = 0
    for cat, (l, q) in after.items():
        emit(f"fig10/after/{cat}", 0.0, f"sketch_len={l:.1f};quality={q:.3f}")
        shorter += l <= before[cat][0] + 1.0
    emit("fig10/summary", 0.0,
         f"categories_shorter_or_equal={shorter}/{len(cats)};"
         f"reward_trend={hist[-1]['mean_reward'] - hist[0]['mean_reward']:+.3f}")
    return before, after


if __name__ == "__main__":
    run()
