"""Kernel microbenchmarks: Pallas (interpret) vs jnp reference.

NOTE: interpret-mode wall time on CPU says nothing about TPU performance —
the derived column carries the structural numbers that matter (FLOPs, bytes,
arithmetic intensity); wall time is reported only to satisfy the CSV
contract and catch pathological regressions."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed


def run():
    key = jax.random.PRNGKey(0)

    # flash attention: prefill tile
    from repro.kernels.flash_attention import ops as fa, ref as fa_ref
    B, S, H, hd = 1, 512, 4, 64
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    v = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    flops = 4 * B * H * S * S * hd
    _, us = timed(lambda: jax.block_until_ready(
        fa.flash_attention(q, k, v, block_q=128, block_kv=128)))
    emit("kernels/flash_attention_pallas", us, f"flops={flops:.2e}")
    _, us = timed(lambda: jax.block_until_ready(fa_ref.mha_ref(q, k, v)))
    emit("kernels/flash_attention_ref", us, f"flops={flops:.2e}")

    # decode attention: the PICE hotspot (KV streaming)
    from repro.kernels.decode_attention import ops as da, ref as da_ref
    B, S, Hq, Hkv, hd = 4, 4096, 8, 2, 64
    q1 = jax.random.normal(key, (B, 1, Hq, hd), jnp.float32)
    kc = jax.random.normal(key, (B, S, Hkv, hd), jnp.float32)
    vc = jax.random.normal(key, (B, S, Hkv, hd), jnp.float32)
    lens = jnp.full((B,), S, jnp.int32)
    bytes_ = 2 * B * S * Hkv * hd * 4
    _, us = timed(lambda: jax.block_until_ready(
        da.decode_attention(q1, kc, vc, lens, block_s=512)))
    emit("kernels/decode_attention_pallas", us,
         f"kv_bytes={bytes_:.2e};ai={4*Hq*hd/(2*Hkv*hd*4):.2f}flops_per_byte")
    _, us = timed(lambda: jax.block_until_ready(
        da_ref.decode_attention_ref(q1, kc, vc, lens)))
    emit("kernels/decode_attention_ref", us, f"kv_bytes={bytes_:.2e}")

    # paged decode read path: block-table gather + the same attention — the
    # serving engine's paged backend (gather cost is the paging overhead a
    # TPU kernel would stream away)
    from repro.models import paged_cache as pc
    page = 64
    P = S // page
    n_pages = B * P
    pages_k = kc.reshape(n_pages, page, Hkv, hd)
    pages_v = vc.reshape(n_pages, page, Hkv, hd)
    table = jnp.arange(n_pages, dtype=jnp.int32).reshape(B, P)

    @jax.jit
    def paged_decode(q, pk, pv, tbl, ln):
        gk = pc.gather_sequence(pk, tbl)
        gv = pc.gather_sequence(pv, tbl)
        return da_ref.decode_attention_ref(q, gk, gv, ln)

    jax.block_until_ready(paged_decode(q1, pages_k, pages_v, table, lens))
    _, us = timed(lambda: jax.block_until_ready(
        paged_decode(q1, pages_k, pages_v, table, lens)))
    emit("kernels/decode_attention_paged_gather", us,
         f"kv_bytes={bytes_:.2e};page={page};pages={n_pages}")

    # rmsnorm
    from repro.kernels.rmsnorm import ops as rn, ref as rn_ref
    x = jax.random.normal(key, (4096, 1024), jnp.bfloat16)
    s = jax.random.normal(key, (1024,))
    _, us = timed(lambda: jax.block_until_ready(rn.rmsnorm(x, s)))
    emit("kernels/rmsnorm_pallas", us, f"bytes={x.size*2*2:.2e}")
    _, us = timed(lambda: jax.block_until_ready(rn_ref.rmsnorm_ref(x, s)))
    emit("kernels/rmsnorm_ref", us, f"bytes={x.size*2*2:.2e}")

    # ssd scan
    from repro.kernels.ssm_scan import ops as ssm, ref as ssm_ref
    Bb, S, H, P, N = 2, 1024, 4, 64, 64
    x = jax.random.normal(key, (Bb, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(key, (Bb, S, H))) * 0.1
    A = -jnp.exp(jax.random.normal(key, (H,)))
    Bm = jax.random.normal(key, (Bb, S, N)) * 0.3
    Cm = jax.random.normal(key, (Bb, S, N)) * 0.3
    flops = 2 * Bb * S * H * P * N * 3
    _, us = timed(lambda: jax.block_until_ready(
        ssm.ssm_scan(x, dt, A, Bm, Cm, chunk=128)[0]))
    emit("kernels/ssm_scan_pallas", us, f"flops={flops:.2e}")
    _, us = timed(lambda: jax.block_until_ready(
        ssm_ref.ssd_chunked_ref(x, dt, A, Bm, Cm, chunk=128)[0]))
    emit("kernels/ssm_scan_ref", us, f"flops={flops:.2e}")


if __name__ == "__main__":
    run()
