"""Kernel microbenchmarks: Pallas (interpret) vs jnp reference.

NOTE: interpret-mode wall time on CPU says nothing about TPU performance —
the derived column carries the structural numbers that matter (FLOPs, bytes,
arithmetic intensity); wall time is reported only to satisfy the CSV
contract and catch pathological regressions.

  PYTHONPATH=src python -m benchmarks.kernels_bench [--paged-smoke]

--paged-smoke runs only the paged decode A/B at tiny sizes (CI: parity +
the per-step KV read-volume accounting must not regress)."""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed


def paged_decode_case(smoke: bool = False):
    """Paged decode read path A/B: Pallas block-table streaming kernel vs
    the gather oracle (full table width) vs the live-trimmed gather the
    engine's fallback now uses.

    Lengths are skewed (one near-max straggler, short rest), which is where
    the gather pays for `max_pages_per_seq` on every slot: its per-step KV
    read volume is O(B * max_pages * page), the kernel's is O(sum
    ceil(len/page) * page). The emitted kv_bytes column carries exactly
    that accounting."""
    from repro.kernels.paged_decode_attention import ops as pda
    from repro.kernels.paged_decode_attention import ref as pda_ref

    key = jax.random.PRNGKey(3)
    if smoke:
        B, Hq, Hkv, hd, page, P = 4, 8, 2, 32, 8, 8
    else:
        B, Hq, Hkv, hd, page, P = 8, 8, 2, 64, 32, 64
    n_pages = B * P
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, hd), jnp.float32)
    pages_k = jax.random.normal(ks[1], (n_pages, page, Hkv, hd), jnp.float32)
    pages_v = jax.random.normal(ks[2], (n_pages, page, Hkv, hd), jnp.float32)
    # length-skewed batch: one straggler at half the table width, the rest
    # short — so all three read strategies differ: the trim drops the
    # columns NO slot uses, the kernel additionally skips per-row dead width
    max_tok = P * page
    lens_np = np.full((B,), max(page // 2, 1), np.int64)
    lens_np[0] = max_tok // 2 - page // 2
    lens = jnp.asarray(lens_np, jnp.int32)
    table_np = np.full((B, P), -1, np.int64)
    nxt = 0
    for b in range(B):
        live = -(-int(lens_np[b]) // page)
        table_np[b, :live] = np.arange(nxt, nxt + live)
        nxt += live
    table = jnp.asarray(table_np, jnp.int32)
    live_w = max(1, -(-int(lens_np.max()) // page))

    tok_bytes = 2 * Hkv * hd * 4                      # K+V, f32
    bytes_gather = B * P * page * tok_bytes           # full table width
    bytes_trim = B * live_w * page * tok_bytes        # live-trimmed gather
    pages_live = sum(-(-int(l) // page) for l in lens_np)
    bytes_kernel = pages_live * page * tok_bytes      # only mapped pages

    out_k, us_k = timed(lambda: jax.block_until_ready(
        pda.paged_decode_attention(q, pages_k, pages_v, table, lens)))
    out_k, us_k = timed(lambda: jax.block_until_ready(
        pda.paged_decode_attention(q, pages_k, pages_v, table, lens)))
    emit("kernels/paged_decode_pallas", us_k,
         f"kv_bytes={bytes_kernel:.2e};page={page};pages_read={pages_live}")

    oracle = jax.jit(pda_ref.paged_decode_attention_ref)
    out_o, _ = timed(lambda: jax.block_until_ready(
        oracle(q, pages_k, pages_v, table, lens)))
    out_o, us_o = timed(lambda: jax.block_until_ready(
        oracle(q, pages_k, pages_v, table, lens)))
    emit("kernels/paged_decode_gather_oracle", us_o,
         f"kv_bytes={bytes_gather:.2e};page={page};pages_read={B * P}")

    trimmed = jax.jit(pda_ref.paged_decode_attention_ref)
    tt = table[:, :live_w]
    _, _ = timed(lambda: jax.block_until_ready(
        trimmed(q, pages_k, pages_v, tt, lens)))
    out_t, us_t = timed(lambda: jax.block_until_ready(
        trimmed(q, pages_k, pages_v, tt, lens)))
    emit("kernels/paged_decode_gather_trimmed", us_t,
         f"kv_bytes={bytes_trim:.2e};page={page};pages_read={B * live_w}")

    # regression guards: the kernel must match the oracle, and the
    # trimmed read must actually shrink the per-step volume
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_o),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(out_t), np.asarray(out_o),
                               rtol=2e-5, atol=2e-5)
    assert bytes_kernel < bytes_trim <= bytes_gather
    print(f"# paged decode: kernel reads {pages_live} pages/step "
          f"({bytes_kernel / bytes_gather:.0%} of the gather's {B * P}); "
          f"gather full={us_o:.0f}us trimmed={us_t:.0f}us "
          f"(x{us_o / max(us_t, 1e-9):.2f} at this length skew)")


def run():
    key = jax.random.PRNGKey(0)

    # flash attention: prefill tile
    from repro.kernels.flash_attention import ops as fa, ref as fa_ref
    B, S, H, hd = 1, 512, 4, 64
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    v = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    flops = 4 * B * H * S * S * hd
    _, us = timed(lambda: jax.block_until_ready(
        fa.flash_attention(q, k, v, block_q=128, block_kv=128)))
    emit("kernels/flash_attention_pallas", us, f"flops={flops:.2e}")
    _, us = timed(lambda: jax.block_until_ready(fa_ref.mha_ref(q, k, v)))
    emit("kernels/flash_attention_ref", us, f"flops={flops:.2e}")

    # decode attention: the PICE hotspot (KV streaming)
    from repro.kernels.decode_attention import ops as da, ref as da_ref
    B, S, Hq, Hkv, hd = 4, 4096, 8, 2, 64
    q1 = jax.random.normal(key, (B, 1, Hq, hd), jnp.float32)
    kc = jax.random.normal(key, (B, S, Hkv, hd), jnp.float32)
    vc = jax.random.normal(key, (B, S, Hkv, hd), jnp.float32)
    lens = jnp.full((B,), S, jnp.int32)
    bytes_ = 2 * B * S * Hkv * hd * 4
    _, us = timed(lambda: jax.block_until_ready(
        da.decode_attention(q1, kc, vc, lens, block_s=512)))
    emit("kernels/decode_attention_pallas", us,
         f"kv_bytes={bytes_:.2e};ai={4*Hq*hd/(2*Hkv*hd*4):.2f}flops_per_byte")
    _, us = timed(lambda: jax.block_until_ready(
        da_ref.decode_attention_ref(q1, kc, vc, lens)))
    emit("kernels/decode_attention_ref", us, f"kv_bytes={bytes_:.2e}")

    # paged decode read path: Pallas block-table streaming kernel vs the
    # gather oracle (full and live-trimmed widths) at skewed lengths
    paged_decode_case()

    # rmsnorm
    from repro.kernels.rmsnorm import ops as rn, ref as rn_ref
    x = jax.random.normal(key, (4096, 1024), jnp.bfloat16)
    s = jax.random.normal(key, (1024,))
    _, us = timed(lambda: jax.block_until_ready(rn.rmsnorm(x, s)))
    emit("kernels/rmsnorm_pallas", us, f"bytes={x.size*2*2:.2e}")
    _, us = timed(lambda: jax.block_until_ready(rn_ref.rmsnorm_ref(x, s)))
    emit("kernels/rmsnorm_ref", us, f"bytes={x.size*2*2:.2e}")

    # ssd scan
    from repro.kernels.ssm_scan import ops as ssm, ref as ssm_ref
    Bb, S, H, P, N = 2, 1024, 4, 64, 64
    x = jax.random.normal(key, (Bb, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(key, (Bb, S, H))) * 0.1
    A = -jnp.exp(jax.random.normal(key, (H,)))
    Bm = jax.random.normal(key, (Bb, S, N)) * 0.3
    Cm = jax.random.normal(key, (Bb, S, N)) * 0.3
    flops = 2 * Bb * S * H * P * N * 3
    _, us = timed(lambda: jax.block_until_ready(
        ssm.ssm_scan(x, dt, A, Bm, Cm, chunk=128)[0]))
    emit("kernels/ssm_scan_pallas", us, f"flops={flops:.2e}")
    _, us = timed(lambda: jax.block_until_ready(
        ssm_ref.ssd_chunked_ref(x, dt, A, Bm, Cm, chunk=128)[0]))
    emit("kernels/ssm_scan_ref", us, f"flops={flops:.2e}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--paged-smoke", action="store_true",
                    help="only the paged decode A/B at tiny sizes (CI)")
    if ap.parse_args().paged_smoke:
        paged_decode_case(smoke=True)
    else:
        run()
