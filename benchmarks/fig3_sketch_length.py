"""Paper Fig. 3: throughput vs the LLM's max response tokens.

Sweeps the cloud generation cap (full answers truncated to `max_tokens`);
validation target: cutting 500 -> 200 tokens lifts throughput 1.5-2x."""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit, timed
from repro.core.simulator import SimConfig, _Server, _finalize, make_requests
from repro.core.profiler import paper_latency_model


def run(n_requests: int = 300):
    out = {}
    base = None
    for max_tokens in (100, 200, 300, 400, 500):
        cfg = SimConfig(cloud_model="llama3-70b", cloud_batch=20, rpm=45,
                        n_requests=n_requests)
        reqs = make_requests(cfg.n_requests, cfg.rpm, cfg.seed)
        cloud = paper_latency_model(cfg.cloud_model, "cloud")
        server = _Server(cfg.cloud_batch)
        toks = 0
        for r in reqs:
            l = min(r.answer_len, max_tokens)
            r.done_s = server.submit(r.arrival_s, cloud.f(l))
            r.mode = "cloud_full"
            toks += l
        res = _finalize(reqs, toks, 0)
        out[max_tokens] = res
        if max_tokens == 500:
            base = res
        emit(f"fig3/max_tokens_{max_tokens}", 0.0,
             f"thr={res.throughput_per_min:.2f}/min")
    ratio = out[200].throughput_per_min / out[500].throughput_per_min
    emit("fig3/ratio_200_vs_500", 0.0, f"ratio={ratio:.2f}")
    return out


if __name__ == "__main__":
    run()
