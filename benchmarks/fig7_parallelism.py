"""Paper Fig. 7: optimal edge parallelism vs sketch length per task type, and
the latency effect of the parallel mechanism (binary-tree merging).

Validation targets: parallelism grows with sketch length then saturates
(edge memory/KV limits, modeled via max_parallelism and prompt overhead);
short-answer categories stay at low parallelism."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.exec_optimizer import plan_expansion
from repro.core.profiler import paper_latency_model


def run():
    edge = paper_latency_model("llama3-8b", "edge")
    cloud = paper_latency_model("llama3-70b", "cloud")
    out = {}
    # generic/roleplay: many sentences; math/common-sense: few
    for category, toks_per_sent in (("generic", 12), ("roleplay", 14),
                                    ("math", 30), ("common-sense", 25)):
        for sketch_tokens in (50, 100, 200, 300, 500, 700):
            n_sent = max(1, sketch_tokens // toks_per_sent)
            sentences = [" ".join(["w"] * toks_per_sent)] * n_sent
            answer_len = sketch_tokens * 3

            def lat(p, longest):
                # KV/prompt overhead: each parallel prompt re-reads the sketch
                overhead = 0.002 * sketch_tokens * p
                return edge.f(longest) + overhead

            # Eq.(2) budget nets out the cloud's sketch-generation time
            budget = cloud.f(answer_len) - cloud.f(sketch_tokens)
            plan = plan_expansion(sentences, lat, latency_budget_s=budget,
                                  max_parallelism=16)
            out[(category, sketch_tokens)] = plan
            emit(f"fig7/{category}/sketch_{sketch_tokens}", 0.0,
                 f"parallelism={plan.parallelism};"
                 f"lat={plan.est_latency_s:.2f}s")
    # latency reduction vs sequential expansion at 500-token sketches
    sentences = [" ".join(["w"] * 12)] * (500 // 12)
    seq_lat = edge.f(500 * 3)
    plan = plan_expansion(sentences, lambda p, l: edge.f(l) + 0.002 * 500 * p,
                          latency_budget_s=seq_lat, max_parallelism=16)
    emit("fig7/latency_reduction_500tok", 0.0,
         f"sequential={seq_lat:.1f}s;parallel={plan.est_latency_s:.1f}s;"
         f"saved={seq_lat - plan.est_latency_s:.1f}s")
    return out


if __name__ == "__main__":
    run()
