"""Shared benchmark utilities: CSV emission per the harness contract."""
from __future__ import annotations

import sys
import time
from typing import Callable, Iterable


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def timed(fn: Callable, *args, repeats: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6
