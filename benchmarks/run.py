"""Benchmark runner: one function per paper table/figure + roofline + kernels.

Prints ``name,us_per_call,derived`` CSV rows. Heavy real-compute benchmarks
(fig9 ensemble, fig10 finetune) are included by default; pass --fast to run
only the calibrated-simulator and analysis benchmarks.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip real-compute (model-training) benchmarks")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()

    from benchmarks import (fig3_sketch_length, fig6_scheduler,
                            fig7_parallelism, fig12_rpm, fig13_queue,
                            fig14_bandwidth, kernels_bench, roofline,
                            table3_efficiency)

    suites = [
        ("table3", table3_efficiency.run),
        ("fig3", fig3_sketch_length.run),
        ("fig6", fig6_scheduler.run),
        ("fig7", fig7_parallelism.run),
        ("fig12", fig12_rpm.run),
        ("fig13", fig13_queue.run),
        ("fig14", fig14_bandwidth.run),
        ("kernels", kernels_bench.run),
        ("roofline", roofline.run),
    ]
    if not args.fast:
        from benchmarks import fig9_ensemble, fig10_finetune, paged_engine_bench
        suites += [
            ("fig9", fig9_ensemble.run),
            ("fig10", fig10_finetune.run),
            ("paged_engine", paged_engine_bench.run),
        ]
    if args.only:
        keep = set(args.only.split(","))
        suites = [(n, f) for n, f in suites if n in keep]

    failures = 0
    for name, fn in suites:
        print(f"# --- {name} ---", file=sys.stderr)
        try:
            fn()
        except Exception:
            failures += 1
            print(f"benchmark {name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark suite(s) failed")


if __name__ == "__main__":
    main()
