"""Dense vs paged serving-engine throughput under request-length skew, the
PICE ensemble fan-out under copy-on-write prefix sharing, and the chunked-
prefill head-of-line sweep.

For each workload the same prompt stream runs through both KV backends of
`InferenceEngine` (greedy decode, so outputs are identical) and we report
tokens/s plus the KV memory each backend actually reserves. The paged
backend's pool is sized to the workload's *mean* demand, not the dense
worst case (max_batch x max_len), which is where its win comes from: at
high length skew most dense slot memory is dead reservation.

The fan-out scenario prefills one (query, sketch)-style prefix and expands
it N ways — once as N independent submissions, once through the COW fork
path (`generate_fanout`) — and reports the peak page usage of each. The
shared path must stay well under N x the unshared reservation (< 0.6x is
asserted, so CI smoke runs catch a silent regression to per-slot prefills).

The chunk sweep measures decode head-of-line blocking at skewed prompt
lengths: residents decode while long admissions arrive, and the max gap
between consecutive decode steps is the stall one admission inflicts.
Monolithic prefill stalls for the whole prompt; `cfg.prefill_chunk` bounds
the stall by one chunk. Chunked must beat monolithic on max stall (asserted)
and the whole trajectory — tokens/s, TTFT p50/p95, per-admission decode
stall — lands in a machine-readable BENCH_serving.json for future PRs to
regress against.

Two quantized-KV scenarios A/B a bf16 page pool against int8
(`cfg.kv_dtype`): slot capacity at a FIXED pool byte budget (int8 must fit
>= 1.8x the concurrent sequences bf16 does) and per-step KV read traffic on
the skewed workload (`engine.kv_bytes_read` must shrink >= 1.8x while
tokens/s stays within 10% of bf16). A swap-vs-replay scenario preempts one
request at growing generated lengths and times resume-to-next-token under
both eviction policies — host-tier page swap (`host_swap=True`, promote the
snapshotted bytes) vs evict-and-replay (recompute the prefill) — reporting
the crossover length and the modeled edge-link transfer cost of the
swapped bytes (`NetworkModel.transfer_s`).

The chaos scenario drives the full progressive pipeline (cloud sketch ->
edge ensemble -> select) through a seeded `FaultInjector`: a transfer-loss
sweep exercises `transfer_with_retry`'s backoff, and a composite plan adds
an edge-engine crash plus a straggler step. Per scenario it reports
availability (every request must still get SOME answer — the degradation
ladder's contract, asserted at 1.0 in CI), SLA attainment, goodput of
in-deadline tokens, and the degraded-mode histogram.

The offered-load sweep (`--load-sweep`) drives the multiplexed
serving front-end (serving.frontend) with the trace-driven load generator
(serving.loadgen): a saturated parity point gates front-end goodput at
>= MIN_FRONTEND_DIRECT_RATIO of direct engine.generate() throughput, and a
1x/2x/4x-of-capacity Poisson curve records goodput, SLA attainment, and
shedding vs offered load.

  PYTHONPATH=src python -m benchmarks.paged_engine_bench [--smoke]
      [--chunk-sweep] [--chaos] [--load-sweep]
      [--out BENCH_serving.json] [--timestamp ISO8601]

--smoke shrinks the workloads to a few requests/steps for CI (and leaves
the sweep to the dedicated step); --chunk-sweep runs only the sweep and
merges it into an existing BENCH_serving.json rather than clobbering the
workload/fan-out sections. Every run stamps `meta` with the git SHA,
jax/jaxlib versions, and a timestamp (--timestamp injects a fixed one so
CI artifacts are reproducible).
"""
from __future__ import annotations

import argparse
import datetime
import json
import subprocess
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.pice_cloud_edge import TINY_EDGE_A
from repro.models import transformer
from repro.serving.engine import InferenceEngine

MAX_BATCH = 8
MAX_LEN = 256
PAGE = 16
N_REQ = 24
MAX_NEW = 32
FANOUT = 6
FANOUT_PREFIX = 128          # 8 pages: a typical query+sketch expansion prefix

# request-length-skew settings: (name, prompt-length sampler)
WORKLOADS = [
    ("uniform", lambda rng: int(rng.integers(20, 28))),
    # heavy-tailed: mostly short prompts, a few near-max_len stragglers
    ("skewed", lambda rng: int(rng.integers(160, 200))
               if rng.random() < 0.2 else int(rng.integers(6, 16))),
]


def _prompts(sampler, seed: int, n_req: int):
    rng = np.random.default_rng(seed)
    return [[int(t) for t in rng.integers(1, 250, size=sampler(rng))]
            for _ in range(n_req)]


def _stamp(timestamp: str = ""):
    """Provenance fields for `meta`: without them a BENCH_serving.json
    artifact cannot be tied back to the commit/toolchain that produced it
    when trajectories are compared across PRs."""
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, timeout=10).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        sha = "unknown"
    try:
        import jaxlib
        jaxlib_v = getattr(jaxlib, "__version__", "unknown")
    except ImportError:
        jaxlib_v = "unknown"
    return {"git_sha": sha, "jax_version": jax.__version__,
            "jaxlib_version": jaxlib_v,
            "timestamp": timestamp or datetime.datetime.now(
                datetime.timezone.utc).isoformat(timespec="seconds")}


def _pctl(vals, q):
    return float(np.percentile(np.asarray(vals, np.float64), q)) if vals \
        else 0.0


def _run(engine: InferenceEngine, prompts, max_new: int):
    # precompile every decode/prefill variant the prompt mix will hit
    # BEFORE the measured window: without this the paged backend pays its
    # per-live-width XLA compiles inside the window and the throughput
    # ratio reads as an order-of-magnitude regression that is not there
    engine.warmup(prompt_lens=tuple(len(p) for p in prompts))
    engine.generate([prompts[0]], max_new=4)       # warm remaining glue
    engine.ttft.clear()
    base = engine.tokens_generated
    t0 = time.perf_counter()
    engine.generate(prompts, max_new=max_new)
    dt = time.perf_counter() - t0
    return (engine.tokens_generated - base) / dt, dt


# paged serving must hold its own on raw throughput while spending a
# fraction of the dense KV reservation; the plan/run step loop (one table
# push, fused sample, deferred harvest) is what pays for the paging
# bookkeeping, and this floor is the regression guard on it
MIN_PAGED_DENSE_RATIO = 0.9


def _run_workloads(cfg, params, kv_bytes_per_tok, n_req, max_new, results):
    failures = []
    for wi, (name, sampler) in enumerate(WORKLOADS):
        prompts = _prompts(sampler, seed=97 + wi, n_req=n_req)
        demand = sum(min(len(p), MAX_LEN) + max_new for p in prompts)

        dense = InferenceEngine(cfg, params, max_batch=MAX_BATCH,
                                max_len=MAX_LEN)
        tps, dt = _run(dense, prompts, max_new)
        dense_bytes = MAX_BATCH * MAX_LEN * kv_bytes_per_tok
        emit(f"paged_engine/{name}_dense", dt * 1e6,
             f"tok_s={tps:.1f};kv_bytes={dense_bytes:.2e}")

        # pool sized at ~60% of the dense reservation: enough for the mean
        # demand; the skewed tail is absorbed by paging (evict + resume)
        n_pages = int(0.6 * MAX_BATCH * MAX_LEN / PAGE)
        paged = InferenceEngine(cfg, params, max_batch=MAX_BATCH,
                                max_len=MAX_LEN, kv_backend="paged",
                                page_size=PAGE, n_pages=n_pages)
        tps_p, dt_p = _run(paged, prompts, max_new)
        paged_bytes = n_pages * PAGE * kv_bytes_per_tok
        st = paged.memory_stats()
        ttfts = list(paged.ttft.values())
        emit(f"paged_engine/{name}_paged", dt_p * 1e6,
             f"tok_s={tps_p:.1f};kv_bytes={paged_bytes:.2e}"
             f";peak_pages={st['peak_pages']};evictions={st['evictions']}")
        ratio = tps_p / tps
        print(f"# {name}: demand={demand} tok; dense reserves "
              f"{MAX_BATCH * MAX_LEN} tok, paged pool {n_pages * PAGE} tok "
              f"({paged_bytes / dense_bytes:.0%}); throughput ratio "
              f"paged/dense={ratio:.2f}")
        results["workloads"][name] = {
            "tok_s_dense": tps, "tok_s_paged": tps_p,
            "paged_dense_ratio": ratio,
            "kv_bytes_dense": dense_bytes, "kv_bytes_paged": paged_bytes,
            "peak_pages": st["peak_pages"], "evictions": st["evictions"],
            "ttft_p50_s": _pctl(ttfts, 50), "ttft_p95_s": _pctl(ttfts, 95),
        }
        if ratio < MIN_PAGED_DENSE_RATIO:
            failures.append(
                f"{name}: paged/dense throughput ratio {ratio:.2f} below "
                f"the {MIN_PAGED_DENSE_RATIO} floor")
    return failures


def _run_fanout(cfg, params, kv_bytes_per_tok, fanout, prefix_len, max_new,
                results):
    """N-way expansion of one shared prefix: independent vs COW fork path."""
    rng = np.random.default_rng(211)
    prefix = [int(t) for t in rng.integers(1, 250, size=prefix_len)]
    kw = dict(max_batch=fanout + 1, max_len=MAX_LEN, kv_backend="paged",
              page_size=PAGE)

    unshared = InferenceEngine(cfg, params, **kw)
    unshared.generate([prefix], max_new=4)         # warmup / compile
    unshared.peak_pages = 0
    t0 = time.perf_counter()
    out_u = unshared.generate([prefix] * fanout, max_new=max_new)
    dt_u = time.perf_counter() - t0
    peak_u = unshared.memory_stats()["peak_pages"]
    emit(f"paged_engine/fanout{fanout}_unshared", dt_u * 1e6,
         f"peak_pages={peak_u};kv_bytes={peak_u * PAGE * kv_bytes_per_tok:.2e}")

    shared = InferenceEngine(cfg, params, **kw)
    shared.generate([prefix], max_new=4)
    shared.peak_pages = 0
    t0 = time.perf_counter()
    out_s = shared.generate_fanout(prefix, [[] for _ in range(fanout)],
                                   max_new=max_new)
    dt_s = time.perf_counter() - t0
    peak_s = shared.memory_stats()["peak_pages"]
    emit(f"paged_engine/fanout{fanout}_shared", dt_s * 1e6,
         f"peak_pages={peak_s};kv_bytes={peak_s * PAGE * kv_bytes_per_tok:.2e}"
         f";ratio={peak_s / max(peak_u, 1):.2f}")
    print(f"# fanout x{fanout}: prefix {prefix_len} tok "
          f"({prefix_len // PAGE} pages); peak pages unshared={peak_u} "
          f"shared={peak_s} ({peak_s / max(peak_u, 1):.0%})")
    results["fanout"] = {"n": fanout, "peak_pages_unshared": peak_u,
                         "peak_pages_shared": peak_s,
                         "ratio": peak_s / max(peak_u, 1)}

    # regression guards: the fork path must stay bit-identical to the
    # independent submissions AND far under the unshared reservation —
    # a silent fallback to per-slot prefills would fail here
    assert out_s == out_u, "fan-out diverged from independent submissions"
    assert peak_s < 0.6 * peak_u, \
        f"fan-out peak {peak_s} not < 0.6 x unshared {peak_u}"


# ---------------------------------------------------------------------------
# Quantized KV pages: slot capacity at fixed bytes + KV read traffic A/B
# ---------------------------------------------------------------------------

# int8 pages store ~1/4 the bytes of a float32 pool and ~1/2 of bf16 (plus
# a small f32 scale per (page, kv-head)); both capacity and read-traffic
# wins must clear this floor or the quantization plumbing has regressed
MIN_INT8_BF16_RATIO = 1.8
# int8 decode pays a dequant on every page read; the throughput cost of
# that must stay within 10% of the bf16 pool on the same workload
MIN_INT8_TOK_S_RATIO = 0.9


def _run_kv_dtype(cfg, params, smoke, results):
    """bf16 vs int8 KV pools: (a) concurrent slots at a FIXED pool byte
    budget, (b) per-step KV read bytes + tokens/s on the skewed workload.

    Both engines run the same geometry; only `cfg.kv_dtype` differs, so the
    per-page byte cost (pool + scale leaves, `engine._page_kv_bytes`) is
    the only lever. Slot capacity is measured through the real admission
    path (`can_admit` gates on free pages), not arithmetic on constants."""
    failures = []
    engines, page_bytes = {}, {}
    n_pages_budget = {}
    # fixed byte budget = what 32 bf16 pages cost; int8 fits more pages
    for kd in ("bfloat16", "int8"):
        probe = InferenceEngine(cfg.with_(kv_dtype=kd), params, max_batch=4,
                                max_len=MAX_LEN, kv_backend="paged",
                                page_size=PAGE, n_pages=8)
        page_bytes[kd] = probe._page_kv_bytes
    budget = 32 * page_bytes["bfloat16"]
    slot_prompt = [int(t) for t in
                   np.random.default_rng(7).integers(1, 250, size=60)]
    slots = {}
    for kd in ("bfloat16", "int8"):
        n_pages_budget[kd] = budget // page_bytes[kd]
        eng = InferenceEngine(cfg.with_(kv_dtype=kd), params, max_batch=32,
                              max_len=MAX_LEN, kv_backend="paged",
                              page_size=PAGE, n_pages=int(n_pages_budget[kd]))
        count = 0
        while eng.free_slots() and eng.can_admit(len(slot_prompt)):
            eng.add_request(1000 + count, slot_prompt, max_new=4)
            count += 1
        slots[kd] = count
        engines[kd] = eng
    slot_ratio = slots["int8"] / max(slots["bfloat16"], 1)
    print(f"# kv_dtype capacity: {budget} B pool budget -> "
          f"bf16 {int(n_pages_budget['bfloat16'])} pages / "
          f"{slots['bfloat16']} slots, int8 {int(n_pages_budget['int8'])} "
          f"pages / {slots['int8']} slots ({slot_ratio:.2f}x)")
    emit("paged_engine/kv_dtype_slots", slot_ratio * 100,
         f"bf16_slots={slots['bfloat16']};int8_slots={slots['int8']}"
         f";pool_bytes={budget}")
    if slot_ratio < MIN_INT8_BF16_RATIO:
        failures.append(
            f"kv_dtype: int8 fits {slot_ratio:.2f}x the bf16 slots at a "
            f"fixed pool budget, below the {MIN_INT8_BF16_RATIO} floor")

    # (b) read-traffic A/B on the skewed workload: same prompts, same page
    # count (same paging behavior), per-page bytes is the only difference
    n_req, max_new = (6, 8) if smoke else (16, MAX_NEW)
    prompts = _prompts(WORKLOADS[1][1], seed=131, n_req=n_req)
    ab = {}
    for kd in ("bfloat16", "int8"):
        eng = InferenceEngine(cfg.with_(kv_dtype=kd), params,
                              max_batch=MAX_BATCH, max_len=MAX_LEN,
                              kv_backend="paged", page_size=PAGE,
                              n_pages=int(0.6 * MAX_BATCH * MAX_LEN / PAGE))
        eng.warmup(prompt_lens=tuple(len(p) for p in prompts))
        eng.generate([prompts[0]], max_new=4)       # warm remaining glue
        base_tok, base_bytes = eng.tokens_generated, eng.kv_bytes_read
        t0 = time.perf_counter()
        eng.generate(prompts, max_new=max_new)
        dt = time.perf_counter() - t0
        ab[kd] = {"tok_s": (eng.tokens_generated - base_tok) / dt,
                  "kv_bytes_read": eng.kv_bytes_read - base_bytes,
                  "page_kv_bytes": page_bytes[kd]}
        emit(f"paged_engine/kv_dtype_{kd}", dt * 1e6,
             f"tok_s={ab[kd]['tok_s']:.1f}"
             f";kv_bytes_read={ab[kd]['kv_bytes_read']:.3e}")
    bytes_ratio = ab["bfloat16"]["kv_bytes_read"] \
        / max(ab["int8"]["kv_bytes_read"], 1)
    tok_ratio = ab["int8"]["tok_s"] / ab["bfloat16"]["tok_s"]
    print(f"# kv_dtype skewed A/B: KV read bytes bf16/int8="
          f"{bytes_ratio:.2f}x, tok/s int8/bf16={tok_ratio:.2f}")
    results["kv_dtype"] = {
        "pool_budget_bytes": budget,
        "slots_at_fixed_bytes": {"bfloat16": slots["bfloat16"],
                                 "int8": slots["int8"],
                                 "ratio": slot_ratio},
        "skewed_ab": {**{kd: ab[kd] for kd in ab},
                      "kv_bytes_read_ratio": bytes_ratio,
                      "tok_s_ratio_int8_bf16": tok_ratio},
    }
    if bytes_ratio < MIN_INT8_BF16_RATIO:
        failures.append(
            f"kv_dtype: int8 KV read bytes shrink only {bytes_ratio:.2f}x "
            f"vs bf16, below the {MIN_INT8_BF16_RATIO} floor")
    if tok_ratio < MIN_INT8_TOK_S_RATIO:
        failures.append(
            f"kv_dtype: int8 tok/s is {tok_ratio:.2f}x bf16, below the "
            f"{MIN_INT8_TOK_S_RATIO} floor")
    return failures


# ---------------------------------------------------------------------------
# Host-tier page swap vs evict-and-replay resume latency
# ---------------------------------------------------------------------------

def _swap_cycle(eng, req_id, prompt, gen_before_evict):
    """Admit -> decode `gen_before_evict` tokens -> preempt -> resume, and
    time resume-to-next-committed-token under the engine's eviction policy
    (host_swap demote/promote vs free-and-replay). Returns (resume_s,
    evict_s, swapped_bytes)."""
    eng.add_request(req_id, prompt, max_new=gen_before_evict + 2)
    slot = next(i for i, s in enumerate(eng.slots) if s.req_id == req_id)
    while eng.slots[slot].generated < gen_before_evict:
        eng.step()
    eng._harvest()      # drain the in-flight dispatch: consistent snapshot
    bytes0 = eng.swap_bytes
    t0 = time.perf_counter()
    assert eng._evict_victim(protect=-1)
    evict_s = time.perf_counter() - t0
    r = eng._resume_queue.pop(0)
    n0 = len(r.carry_tokens)
    t0 = time.perf_counter()
    if r.swap is not None:
        slot = eng._admit_swapped(r)
    else:
        slot = eng.add_request(r.req_id, r.prompt, r.max_new,
                               carry_tokens=r.carry_tokens,
                               carry_lps=r.carry_lps, priority=r.priority)
    while len(eng.slots[slot].tokens) <= n0:
        eng.step()
    resume_s = time.perf_counter() - t0
    while any(s.active for s in eng.slots):
        eng.step()
    return resume_s, evict_s, eng.swap_bytes - bytes0


def _run_swap_resume(cfg, params, smoke, results):
    """Resume latency, host-tier swap vs evict-and-replay, as the victim's
    decoded length grows. Replay recomputes the whole prefill (cost scales
    with context); swap re-uploads the quantized page bytes (cost scales
    with pages, at host-link bandwidth) — swap must win at the largest
    length and the smallest winning length is reported as the crossover.
    Runs under kv_dtype=int8 so the swapped payload is the quantized pool
    (half the bytes bf16 would move). `NetworkModel.transfer_s` prices the
    same payload over a modeled cloud-edge link for the simulator."""
    from repro.serving.network import NetworkModel
    cfg_q = cfg.with_(kv_dtype="int8")
    prompt = [int(t) for t in
              np.random.default_rng(17).integers(1, 250, size=96)]
    gens = [8, 32] if smoke else [8, 32, 96]
    net = NetworkModel()
    points = []
    lat = {}
    for hs in (True, False):
        eng = InferenceEngine(cfg_q, params, max_batch=2, max_len=MAX_LEN,
                              kv_backend="paged", page_size=PAGE,
                              n_pages=32, eos_id=-1, host_swap=hs)
        eng.warmup(prompt_lens=(len(prompt),))
        per_g = {}
        for gi, g in enumerate(gens):
            _swap_cycle(eng, 2000 + 10 * gi, prompt, g)   # compile pass
            per_g[g] = _swap_cycle(eng, 2001 + 10 * gi, prompt, g)
        lat[hs] = per_g
    for g in gens:
        swap_s, _, swapped = lat[True][g]
        replay_s, _, _ = lat[False][g]
        # the demotion moved `swapped` bytes out; resume moves them back
        one_way = swapped // 2
        points.append({
            "generated": g, "ctx_len": len(prompt) + g,
            "resume_swap_s": swap_s, "resume_replay_s": replay_s,
            "swap_evict_s": lat[True][g][1],
            "swapped_bytes_one_way": one_way,
            "modeled_link_transfer_s": net.transfer_s(one_way),
        })
        emit(f"paged_engine/swap_resume_g{g}", swap_s * 1e6,
             f"replay_s={replay_s:.4f};swapped_bytes={one_way}")
        print(f"# swap-vs-replay g={g}: swap {swap_s * 1e3:.1f} ms vs "
              f"replay {replay_s * 1e3:.1f} ms "
              f"({one_way} B, modeled link "
              f"{net.transfer_s(one_way) * 1e3:.1f} ms)")
    crossover = next((p["generated"] for p in points
                      if p["resume_swap_s"] < p["resume_replay_s"]), None)
    results["swap"] = {"kv_dtype": "int8", "prompt_len": len(prompt),
                       "points": points,
                       "crossover_generated": crossover}
    last = points[-1]
    if not last["resume_swap_s"] < last["resume_replay_s"]:
        return [f"swap: resume at generated={last['generated']} took "
                f"{last['resume_swap_s']:.4f}s, not below replay "
                f"{last['resume_replay_s']:.4f}s"]
    return []


# ---------------------------------------------------------------------------
# Chaos: goodput + SLA attainment vs injected fault rate
# ---------------------------------------------------------------------------

# degraded-mode availability is the hard gate: EVERY request must get an
# answer under EVERY fault scenario (the degradation ladder's whole point)
REQUIRED_AVAILABILITY = 1.0


def _build_chaos_pipeline(params_cache):
    """A real-compute PICE pipeline cheap enough to rebuild per scenario:
    untrained tiny models (the fault machinery doesn't care about text
    quality) and synthetic latency models (cloud deliberately slow, edges
    fast) so the scheduler always has a feasible progressive plan."""
    from repro.configs.pice_cloud_edge import TINY_CLOUD, TINY_EDGE_B
    from repro.core.profiler import LatencyModel
    from repro.core.progressive import PICEConfig, PICEPipeline
    from repro.core.scheduler import EdgeModelInfo
    from repro.serving.network import NetworkModel

    # max_len 512: the untrained sketch decodes to replacement glyphs that
    # re-encode ~3x longer than trained text, and the expansion context is
    # query + sketch + group — 256 would truncate the decode to one token
    kw = dict(max_batch=MAX_BATCH, max_len=512, kv_backend="paged",
              page_size=PAGE, eos_id=-1)
    if "cloud" not in params_cache:
        for key, c in (("cloud", TINY_CLOUD), ("edge-a", TINY_EDGE_A),
                       ("edge-b", TINY_EDGE_B)):
            c = c.with_(dtype="float32")
            params_cache[key] = (c, transformer.init_params(
                c, jax.random.PRNGKey(3)))
    cfg_c, p_c = params_cache["cloud"]
    cloud = InferenceEngine(cfg_c, p_c, name="chaos-cloud", **kw)
    edges, infos = {}, []
    for key, capability in (("edge-a", 0.7), ("edge-b", 0.55)):
        cfg_e, p_e = params_cache[key]
        edges[key] = InferenceEngine(cfg_e, p_e, name=key, **kw)
        infos.append(EdgeModelInfo(
            name=key, latency=LatencyModel(t0=0.05, rate=200.0, name=key),
            capability=capability))
    return PICEPipeline(cloud, edges, LatencyModel(t0=0.5, rate=20.0,
                                                   name="chaos-cloud"),
                        infos, network=NetworkModel(),
                        cfg=PICEConfig(ensemble_size=2))


def _chaos_requests(n):
    from repro.serving.requests import Request, SLA

    def mk(i, sla_s):
        return Request(
            query=f"explain in detail how the paging allocator layer "
                  f"number {i} stores and evicts token pages",
            category="generic", max_new_tokens=96,
            sla=SLA(max_latency_s=sla_s) if sla_s else SLA())
    return mk, n


def _chaos_pass(pipe, mk, n, sla_s):
    t0 = time.perf_counter()
    resps = [pipe.handle(mk(i, sla_s)) for i in range(n)]
    wall = time.perf_counter() - t0
    answered = [r for r in resps if r.text.strip()]
    in_sla = [r for r in answered
              if sla_s == 0.0 or r.latency_s <= sla_s]
    return {
        "n": n,
        "availability": len(answered) / n,
        "sla_attainment": len(in_sla) / n,
        "goodput_tok_s": sum(r.cloud_tokens + r.edge_tokens
                             for r in in_sla) / wall,
        "degraded": {m: sum(1 for r in resps if r.degraded == m)
                     for m in set(r.degraded for r in resps) if m},
        "retries": sum(r.retries for r in resps),
        "hedges": sum(r.hedges for r in resps),
        "wall_s": wall,
    }


def _run_chaos(smoke, results):
    """Drive the full progressive pipeline through a seeded `FaultInjector`
    at increasing transfer-loss rates plus one composite scenario (edge
    crash + 5% loss + straggler). Publishes availability / SLA-attainment /
    goodput curves; availability below REQUIRED_AVAILABILITY at ANY point
    is a failure — degraded answers are fine, dropped requests are not."""
    from repro.serving.faults import FaultInjector, FaultPlan

    params_cache = {}
    pipe = _build_chaos_pipeline(params_cache)
    mk, n = _chaos_requests(3 if smoke else 8)
    _chaos_pass(pipe, mk, n, sla_s=0.0)            # warm every compile path

    pipe = _build_chaos_pipeline(params_cache)
    calib = _chaos_pass(pipe, mk, n, sla_s=0.0)
    sla_s = 3.0 * calib["wall_s"] / n              # generous per-request SLA
    # smoke makes so few transfers that a 5% loss rate rarely fires at all;
    # 0.25 reliably exercises the retry/backoff path in a 3-request pass
    loss_rates = [0.0, 0.25] if smoke else [0.0, 0.05, 0.2]

    curve = []
    failures = []
    for rate in loss_rates:
        pipe = _build_chaos_pipeline(params_cache)
        inj = FaultInjector(FaultPlan(seed=4, transfer_loss_p=rate))
        inj.attach(network=pipe.network, engines=pipe.edges.values())
        m = _chaos_pass(pipe, mk, n, sla_s)
        inj.detach()
        m.update(fault_rate=rate, scenario=f"loss_{rate}",
                 injected=dict(inj.events))
        curve.append(m)
        emit(f"paged_engine/chaos_loss_{rate}", m["wall_s"] * 1e6,
             f"availability={m['availability']:.2f}"
             f";sla={m['sla_attainment']:.2f}"
             f";goodput={m['goodput_tok_s']:.1f}")
        print(f"# chaos loss={rate}: availability={m['availability']:.2f} "
              f"sla={m['sla_attainment']:.2f} "
              f"goodput={m['goodput_tok_s']:.1f} tok/s "
              f"degraded={m['degraded']} injected={m['injected']}")

    # composite scenario from the acceptance bar: one edge engine crashes,
    # 5% transfer loss, one straggler step
    pipe = _build_chaos_pipeline(params_cache)
    inj = FaultInjector(FaultPlan(
        seed=11, transfer_loss_p=0.05, engine_crash_steps=(4,),
        straggler_steps=(9,), straggler_delay_s=0.02))
    inj.attach(network=pipe.network, engines=pipe.edges.values())
    comp = _chaos_pass(pipe, mk, n, sla_s)
    inj.detach()
    comp.update(fault_rate=0.05, scenario="composite",
                injected=dict(inj.events))
    curve.append(comp)
    emit("paged_engine/chaos_composite", comp["wall_s"] * 1e6,
         f"availability={comp['availability']:.2f}"
         f";sla={comp['sla_attainment']:.2f}"
         f";goodput={comp['goodput_tok_s']:.1f}")
    print(f"# chaos composite: availability={comp['availability']:.2f} "
          f"sla={comp['sla_attainment']:.2f} degraded={comp['degraded']} "
          f"injected={comp['injected']}")

    results["chaos"] = {
        "sla_s": sla_s,
        "calibration_goodput_tok_s": calib["goodput_tok_s"],
        "scenarios": curve,
    }
    for m in curve:
        if m["availability"] < REQUIRED_AVAILABILITY:
            failures.append(
                f"chaos {m['scenario']}: availability "
                f"{m['availability']:.2f} below {REQUIRED_AVAILABILITY} — "
                f"{int((1 - m['availability']) * m['n'])} request(s) got no "
                f"answer")
    return failures


# ---------------------------------------------------------------------------
# Chunked-prefill head-of-line sweep
# ---------------------------------------------------------------------------

def _stall_scenario(cfg, params, chunk, *, max_len, page, n_resident,
                    long_len, n_long):
    """Residents decode while `n_long` long admissions arrive; the gap
    between consecutive engine steps is the decode stall the residents see.
    Each long admission is chased by a short latency-critical request
    (priority 1) that *arrives* the instant the long one is admitted: under
    monolithic prefill it waits out the whole prompt before its own
    `add_request` can even run, while the chunked engine admits it on the
    next step and its (priority-ordered) chunk jumps the ingest queue.
    Returns per-scenario metrics (second run of a warmed engine)."""
    rng = np.random.default_rng(41 + chunk)
    residents = [[int(t) for t in rng.integers(1, 250, size=8)]
                 for _ in range(n_resident)]
    longs = [[int(t) for t in rng.integers(1, 250, size=long_len)]
             for _ in range(n_long)]
    shorts = [[int(t) for t in rng.integers(1, 250, size=8)]
              for _ in range(n_long)]

    def once(measure: bool):
        eng = InferenceEngine(cfg.with_(prefill_chunk=chunk), params,
                              max_batch=n_resident + 2, max_len=max_len,
                              kv_backend="paged", page_size=page)
        for i, p in enumerate(residents):
            eng.add_request(100 + i, p, max_new=10 ** 6)
        for _ in range(3):                         # settle into steady decode
            eng.step()
        base = eng.tokens_generated
        gaps = []
        pending = list(range(n_long))
        due_shorts: list = []                      # short ids awaiting a slot
        arrival = {}                               # short id -> arrival wall
        admit_wait = {}                            # short id -> wait for slot
        t0 = last = time.perf_counter()
        while (pending or due_shorts or any(
                s.active and s.req_id >= 200 for s in eng.slots)):
            long_in_flight = any(s.active and 200 <= s.req_id < 300
                                 for s in eng.slots)
            if due_shorts and eng.free_slots() and eng.can_admit(8):
                sid = due_shorts.pop(0)
                admit_wait[sid] = time.perf_counter() - arrival[sid]
                eng.add_request(300 + sid, shorts[sid], max_new=2,
                                priority=1)
            elif (pending and not long_in_flight and eng.free_slots()
                    and eng.can_admit(long_len)):
                j = pending.pop(0)
                # its latency-critical chaser arrives NOW — under
                # monolithic prefill, add_request blocks the driver for
                # the whole prompt before the chaser can be admitted
                arrival[j] = time.perf_counter()
                due_shorts.append(j)
                eng.add_request(200 + j, longs[j], max_new=4)
            eng.step()
            now = time.perf_counter()
            gaps.append(now - last)
            last = now
        dt = time.perf_counter() - t0
        if not measure:
            return None
        # wall TTFT from *arrival*: admission wait + engine-side TTFT
        short_ttfts = [admit_wait[j] + eng.ttft[300 + j]
                       for j in range(n_long)]
        long_ttfts = [eng.ttft[200 + j] for j in range(n_long)]
        return {
            "chunk": chunk,
            "tok_s": (eng.tokens_generated - base) / dt,
            "stall_max_s": max(gaps),
            "stall_mean_s": float(np.mean(gaps)),
            "step_median_s": _pctl(gaps, 50),
            "ttft_long_p50_s": _pctl(long_ttfts, 50),
            "ttft_long_p95_s": _pctl(long_ttfts, 95),
            "ttft_critical_p50_s": _pctl(short_ttfts, 50),
            "ttft_critical_p95_s": _pctl(short_ttfts, 95),
        }

    once(measure=False)                            # compile every shape
    return once(measure=True)


def _run_chunk_sweep(cfg, params, smoke, results):
    max_len, page = (512, 16) if smoke else (1024, 16)
    chunks = [0, 32, 64] if smoke else [0, 128, 256]
    long_len = int(0.85 * max_len)
    n_long = 2 if smoke else 4
    sweep = {}
    for chunk in chunks:
        m = _stall_scenario(cfg, params, chunk, max_len=max_len, page=page,
                            n_resident=3, long_len=long_len, n_long=n_long)
        tag = f"chunk_{chunk or 'monolithic'}"
        sweep[tag] = m
        emit(f"paged_engine/sweep_{tag}", m["stall_max_s"] * 1e6,
             f"tok_s={m['tok_s']:.1f};stall_max_s={m['stall_max_s']:.4f}"
             f";ttft_critical_p95_s={m['ttft_critical_p95_s']:.4f}")
        print(f"# sweep {tag}: stall_max={m['stall_max_s'] * 1e3:.1f} ms "
              f"stall_mean={m['stall_mean_s'] * 1e3:.1f} ms "
              f"ttft_critical_p95={m['ttft_critical_p95_s'] * 1e3:.1f} ms "
              f"tok/s={m['tok_s']:.1f}")
    results["chunk_sweep"] = {
        "meta": {"max_len": max_len, "page": page, "long_len": long_len,
                 "n_long": n_long},
        "scenarios": sweep,
    }
    # regression guards: a chunked admission must never stall running
    # decodes as long as a monolithic prefill does, and a latency-critical
    # latecomer must see its first token faster than a monolithic engine
    # can even admit it behind a long prefill. Violations are RETURNED so
    # the caller can write the trajectory first — the measured numbers are
    # most valuable exactly when the guard trips.
    failures = []
    mono = sweep["chunk_monolithic"]
    for tag, m in sweep.items():
        if m["chunk"]:
            if not m["stall_max_s"] < mono["stall_max_s"]:
                failures.append(
                    f"{tag}: max decode stall {m['stall_max_s']:.4f}s not "
                    f"below monolithic {mono['stall_max_s']:.4f}s")
            if not m["ttft_critical_p95_s"] < mono["ttft_critical_p95_s"]:
                failures.append(
                    f"{tag}: critical TTFT {m['ttft_critical_p95_s']:.4f}s "
                    f"not below monolithic "
                    f"{mono['ttft_critical_p95_s']:.4f}s")
    return failures


# ---------------------------------------------------------------------------
# Serving front-end: goodput vs offered load (trace-driven)
# ---------------------------------------------------------------------------

# under a saturating arrival pattern the multiplexed front-end must deliver
# at least this fraction of direct engine.generate() throughput on the same
# request set — the asyncio driver is allowed bookkeeping overhead, not a
# batching or scheduling regression
MIN_FRONTEND_DIRECT_RATIO = 0.9


def _run_load_sweep(cfg, params, smoke, results):
    from repro.core.profiler import RuntimeMonitor
    from repro.serving import loadgen
    from repro.serving.frontend import EngineFrontend

    kw = dict(max_batch=MAX_BATCH, max_len=MAX_LEN, kv_backend="paged",
              page_size=PAGE, eos_id=-1)
    n_req = 8 if smoke else N_REQ
    max_new = 16 if smoke else MAX_NEW
    seed = 11
    prompt_len = (4, 16)
    prompts = [loadgen.trace_prompt(seed, i, 4 + (i * 7) % 12,
                                    cfg.vocab_size)
               for i in range(n_req)]

    def mk_engine():
        return InferenceEngine(cfg, params, name="serve-front", **kw)

    # direct baseline: the same prompt population straight through
    # engine.generate (its internal pending queue does the batching). One
    # unmeasured pass compiles every shape; the jit registry is shared, so
    # the front-end engines below start warm too.
    mk_engine().generate(prompts, max_new=max_new)
    eng = mk_engine()
    t0 = time.perf_counter()
    outs = eng.generate(prompts, max_new=max_new)
    direct_wall = time.perf_counter() - t0
    direct_tokens = sum(len(toks) for toks, _ in outs)
    direct_tps = direct_tokens / direct_wall

    def mk_frontend():
        return EngineFrontend(mk_engine(), monitor=RuntimeMonitor(),
                              queue_max=4 * n_req)

    # parity point: every request arrives at t=0 (batch tier, no deadline,
    # queue sized to admit all) — offered load is off the x-axis and the
    # front-end is engine-bound, so goodput is directly comparable to the
    # baseline. This is the CI gate.
    sat_trace = loadgen.synthesize_trace(
        1e6, n_req, seed=seed, prompt_len=prompt_len,
        max_new=(max_new, max_new), tier_mix={"batch": 1.0})
    sat = loadgen.replay_sync(mk_frontend(), sat_trace, seed=seed,
                              time_scale=0.0)
    ratio = sat.goodput_tps / direct_tps
    emit("paged_engine/frontend_saturated", sat.elapsed_s * 1e6,
         f"goodput_tps={sat.goodput_tps:.1f};direct_tps={direct_tps:.1f}"
         f";ratio={ratio:.3f}")
    print(f"# serving saturated: frontend {sat.goodput_tps:.1f} tok/s vs "
          f"direct {direct_tps:.1f} tok/s (ratio {ratio:.3f})")

    # offered-load curve: 1x ~= measured capacity, then 2x/4x overload.
    # Deadline budgets scale with the measured per-request service time so
    # the SLA-attainment curve degrades for capacity reasons, not because
    # a fixed budget happens to straddle this host's speed.
    avg_tokens = direct_tokens / n_req
    capacity_rps = direct_tps / max(avg_tokens, 1.0)
    tier_budget_s = max(1.0, 4.0 * direct_wall / n_req * MAX_BATCH)
    multipliers = (1.0, 2.0) if smoke else (1.0, 2.0, 4.0)
    reports = loadgen.sweep(mk_frontend, capacity_rps, n_req,
                            load_multipliers=multipliers, seed=seed,
                            tier_budget_s=tier_budget_s,
                            prompt_len=prompt_len, max_new=(8, max_new))
    curve = []
    for m, r in zip(multipliers, reports):
        curve.append({"load_multiplier": m, **r.summary()})
        emit(f"paged_engine/load_{m:g}x", r.elapsed_s * 1e6,
             f"offered_rps={r.offered_rps:.2f}"
             f";goodput_tps={r.goodput_tps:.1f}"
             f";sla={r.sla_attainment:.3f};shed={r.shed}")
        print(f"# load {m:g}x ({r.offered_rps:.2f} rps): "
              f"goodput={r.goodput_tps:.1f} tok/s "
              f"sla={r.sla_attainment:.2f} shed={r.shed} "
              f"deadline_cancelled={r.deadline_cancelled}")
    results["serving"] = {
        "meta": {"n_req": n_req, "max_new": max_new, "seed": seed,
                 "capacity_rps": capacity_rps,
                 "tier_budget_s": tier_budget_s},
        "direct_tok_s": direct_tps,
        "saturated": sat.summary(),
        "frontend_direct_ratio": ratio,
        "min_frontend_direct_ratio": MIN_FRONTEND_DIRECT_RATIO,
        "curve": curve,
    }
    failures = []
    if ratio < MIN_FRONTEND_DIRECT_RATIO:
        failures.append(
            f"frontend saturated goodput {sat.goodput_tps:.1f} tok/s is "
            f"{ratio:.3f} of direct {direct_tps:.1f} tok/s "
            f"(< {MIN_FRONTEND_DIRECT_RATIO})")
    if sat.shed or sat.failed:
        failures.append(
            f"saturated parity run shed={sat.shed} failed={sat.failed}: "
            f"gate load must fit the queue and never fault")
    return failures


def run(smoke: bool = False, chunk_sweep_only: bool = False,
        chaos_only: bool = False, load_sweep_only: bool = False,
        out: str = "BENCH_serving.json",
        timestamp: str = ""):
    cfg = TINY_EDGE_A.with_(dtype="float32")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    kv_bytes_per_tok = (2 * cfg.n_layers * cfg.n_kv_heads
                       * cfg.resolved_head_dim * 4)
    results = {"meta": {"smoke": smoke, "model": cfg.name,
                        "max_batch": MAX_BATCH, "max_len": MAX_LEN,
                        "page_size": PAGE, **_stamp(timestamp)},
               "workloads": {}}

    merge_only = chunk_sweep_only or chaos_only or load_sweep_only
    failures = []
    if not merge_only:
        n_req, max_new = (6, 8) if smoke else (N_REQ, MAX_NEW)
        failures += _run_workloads(cfg, params, kv_bytes_per_tok, n_req,
                                   max_new, results)
        fanout, prefix_len, fan_new = (4, 80, 8) if smoke else (FANOUT,
                                                                FANOUT_PREFIX,
                                                                MAX_NEW)
        _run_fanout(cfg, params, kv_bytes_per_tok, fanout, prefix_len,
                    fan_new, results)
        failures += _run_kv_dtype(cfg, params, smoke, results)
        failures += _run_swap_resume(cfg, params, smoke, results)
    if chunk_sweep_only or (not smoke and not merge_only):
        # smoke CI splits the sweep into its own step (--chunk-sweep after
        # the fan-out smoke) so the stall measurement is not paid twice
        failures += _run_chunk_sweep(cfg, params, smoke, results)
    if chaos_only or (not smoke and not merge_only):
        failures += _run_chaos(smoke, results)
    if load_sweep_only or (not smoke and not merge_only):
        failures += _run_load_sweep(cfg, params, smoke, results)

    if merge_only:
        # enrich an existing trajectory instead of clobbering its
        # workloads/fanout sections (CI writes the sections from separate
        # steps); the provenance stamp is refreshed — it must describe the
        # LAST writer of the artifact
        try:
            with open(out) as f:
                prev = json.load(f)
            for key in ("chunk_sweep", "chaos", "serving"):
                if key in results:
                    prev[key] = results[key]
            prev.setdefault("meta", {}).update(_stamp(timestamp))
            results = prev
        except (OSError, ValueError, KeyError):
            pass
    with open(out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {out}")
    assert not failures, "; ".join(failures)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config / few steps (CI)")
    ap.add_argument("--chunk-sweep", action="store_true",
                    help="run only the chunked-prefill stall sweep")
    ap.add_argument("--chaos", action="store_true",
                    help="run only the fault-injection chaos scenario")
    ap.add_argument("--load-sweep", action="store_true",
                    help="run only the front-end offered-load sweep")
    ap.add_argument("--out", default="BENCH_serving.json",
                    help="machine-readable trajectory output path")
    ap.add_argument("--timestamp", default="",
                    help="inject a fixed ISO-8601 timestamp into meta "
                         "(default: current UTC time)")
    args = ap.parse_args()
    run(smoke=args.smoke, chunk_sweep_only=args.chunk_sweep,
        chaos_only=args.chaos, load_sweep_only=args.load_sweep,
        out=args.out, timestamp=args.timestamp)
