"""Dense vs paged serving-engine throughput under request-length skew, the
PICE ensemble fan-out under copy-on-write prefix sharing, and the chunked-
prefill head-of-line sweep.

For each workload the same prompt stream runs through both KV backends of
`InferenceEngine` (greedy decode, so outputs are identical) and we report
tokens/s plus the KV memory each backend actually reserves. The paged
backend's pool is sized to the workload's *mean* demand, not the dense
worst case (max_batch x max_len), which is where its win comes from: at
high length skew most dense slot memory is dead reservation.

The fan-out scenario prefills one (query, sketch)-style prefix and expands
it N ways — once as N independent submissions, once through the COW fork
path (`generate_fanout`) — and reports the peak page usage of each. The
shared path must stay well under N x the unshared reservation (< 0.6x is
asserted, so CI smoke runs catch a silent regression to per-slot prefills).

The chunk sweep measures decode head-of-line blocking at skewed prompt
lengths: residents decode while long admissions arrive, and the max gap
between consecutive decode steps is the stall one admission inflicts.
Monolithic prefill stalls for the whole prompt; `cfg.prefill_chunk` bounds
the stall by one chunk. Chunked must beat monolithic on max stall (asserted)
and the whole trajectory — tokens/s, TTFT p50/p95, per-admission decode
stall — lands in a machine-readable BENCH_serving.json for future PRs to
regress against.

  PYTHONPATH=src python -m benchmarks.paged_engine_bench [--smoke]
      [--chunk-sweep] [--out BENCH_serving.json]

--smoke shrinks the workloads to a few requests/steps for CI (and leaves
the sweep to the dedicated step); --chunk-sweep runs only the sweep and
merges it into an existing BENCH_serving.json rather than clobbering the
workload/fan-out sections.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.pice_cloud_edge import TINY_EDGE_A
from repro.models import transformer
from repro.serving.engine import InferenceEngine

MAX_BATCH = 8
MAX_LEN = 256
PAGE = 16
N_REQ = 24
MAX_NEW = 32
FANOUT = 6
FANOUT_PREFIX = 128          # 8 pages: a typical query+sketch expansion prefix

# request-length-skew settings: (name, prompt-length sampler)
WORKLOADS = [
    ("uniform", lambda rng: int(rng.integers(20, 28))),
    # heavy-tailed: mostly short prompts, a few near-max_len stragglers
    ("skewed", lambda rng: int(rng.integers(160, 200))
               if rng.random() < 0.2 else int(rng.integers(6, 16))),
]


def _prompts(sampler, seed: int, n_req: int):
    rng = np.random.default_rng(seed)
    return [[int(t) for t in rng.integers(1, 250, size=sampler(rng))]
            for _ in range(n_req)]


def _pctl(vals, q):
    return float(np.percentile(np.asarray(vals, np.float64), q)) if vals \
        else 0.0


def _run(engine: InferenceEngine, prompts, max_new: int):
    # precompile every decode/prefill variant the prompt mix will hit
    # BEFORE the measured window: without this the paged backend pays its
    # per-live-width XLA compiles inside the window and the throughput
    # ratio reads as an order-of-magnitude regression that is not there
    engine.warmup(prompt_lens=tuple(len(p) for p in prompts))
    engine.generate([prompts[0]], max_new=4)       # warm remaining glue
    engine.ttft.clear()
    base = engine.tokens_generated
    t0 = time.perf_counter()
    engine.generate(prompts, max_new=max_new)
    dt = time.perf_counter() - t0
    return (engine.tokens_generated - base) / dt, dt


# paged serving must hold its own on raw throughput while spending a
# fraction of the dense KV reservation; the plan/run step loop (one table
# push, fused sample, deferred harvest) is what pays for the paging
# bookkeeping, and this floor is the regression guard on it
MIN_PAGED_DENSE_RATIO = 0.9


def _run_workloads(cfg, params, kv_bytes_per_tok, n_req, max_new, results):
    failures = []
    for wi, (name, sampler) in enumerate(WORKLOADS):
        prompts = _prompts(sampler, seed=97 + wi, n_req=n_req)
        demand = sum(min(len(p), MAX_LEN) + max_new for p in prompts)

        dense = InferenceEngine(cfg, params, max_batch=MAX_BATCH,
                                max_len=MAX_LEN)
        tps, dt = _run(dense, prompts, max_new)
        dense_bytes = MAX_BATCH * MAX_LEN * kv_bytes_per_tok
        emit(f"paged_engine/{name}_dense", dt * 1e6,
             f"tok_s={tps:.1f};kv_bytes={dense_bytes:.2e}")

        # pool sized at ~60% of the dense reservation: enough for the mean
        # demand; the skewed tail is absorbed by paging (evict + resume)
        n_pages = int(0.6 * MAX_BATCH * MAX_LEN / PAGE)
        paged = InferenceEngine(cfg, params, max_batch=MAX_BATCH,
                                max_len=MAX_LEN, kv_backend="paged",
                                page_size=PAGE, n_pages=n_pages)
        tps_p, dt_p = _run(paged, prompts, max_new)
        paged_bytes = n_pages * PAGE * kv_bytes_per_tok
        st = paged.memory_stats()
        ttfts = list(paged.ttft.values())
        emit(f"paged_engine/{name}_paged", dt_p * 1e6,
             f"tok_s={tps_p:.1f};kv_bytes={paged_bytes:.2e}"
             f";peak_pages={st['peak_pages']};evictions={st['evictions']}")
        ratio = tps_p / tps
        print(f"# {name}: demand={demand} tok; dense reserves "
              f"{MAX_BATCH * MAX_LEN} tok, paged pool {n_pages * PAGE} tok "
              f"({paged_bytes / dense_bytes:.0%}); throughput ratio "
              f"paged/dense={ratio:.2f}")
        results["workloads"][name] = {
            "tok_s_dense": tps, "tok_s_paged": tps_p,
            "paged_dense_ratio": ratio,
            "kv_bytes_dense": dense_bytes, "kv_bytes_paged": paged_bytes,
            "peak_pages": st["peak_pages"], "evictions": st["evictions"],
            "ttft_p50_s": _pctl(ttfts, 50), "ttft_p95_s": _pctl(ttfts, 95),
        }
        if ratio < MIN_PAGED_DENSE_RATIO:
            failures.append(
                f"{name}: paged/dense throughput ratio {ratio:.2f} below "
                f"the {MIN_PAGED_DENSE_RATIO} floor")
    return failures


def _run_fanout(cfg, params, kv_bytes_per_tok, fanout, prefix_len, max_new,
                results):
    """N-way expansion of one shared prefix: independent vs COW fork path."""
    rng = np.random.default_rng(211)
    prefix = [int(t) for t in rng.integers(1, 250, size=prefix_len)]
    kw = dict(max_batch=fanout + 1, max_len=MAX_LEN, kv_backend="paged",
              page_size=PAGE)

    unshared = InferenceEngine(cfg, params, **kw)
    unshared.generate([prefix], max_new=4)         # warmup / compile
    unshared.peak_pages = 0
    t0 = time.perf_counter()
    out_u = unshared.generate([prefix] * fanout, max_new=max_new)
    dt_u = time.perf_counter() - t0
    peak_u = unshared.memory_stats()["peak_pages"]
    emit(f"paged_engine/fanout{fanout}_unshared", dt_u * 1e6,
         f"peak_pages={peak_u};kv_bytes={peak_u * PAGE * kv_bytes_per_tok:.2e}")

    shared = InferenceEngine(cfg, params, **kw)
    shared.generate([prefix], max_new=4)
    shared.peak_pages = 0
    t0 = time.perf_counter()
    out_s = shared.generate_fanout(prefix, [[] for _ in range(fanout)],
                                   max_new=max_new)
    dt_s = time.perf_counter() - t0
    peak_s = shared.memory_stats()["peak_pages"]
    emit(f"paged_engine/fanout{fanout}_shared", dt_s * 1e6,
         f"peak_pages={peak_s};kv_bytes={peak_s * PAGE * kv_bytes_per_tok:.2e}"
         f";ratio={peak_s / max(peak_u, 1):.2f}")
    print(f"# fanout x{fanout}: prefix {prefix_len} tok "
          f"({prefix_len // PAGE} pages); peak pages unshared={peak_u} "
          f"shared={peak_s} ({peak_s / max(peak_u, 1):.0%})")
    results["fanout"] = {"n": fanout, "peak_pages_unshared": peak_u,
                         "peak_pages_shared": peak_s,
                         "ratio": peak_s / max(peak_u, 1)}

    # regression guards: the fork path must stay bit-identical to the
    # independent submissions AND far under the unshared reservation —
    # a silent fallback to per-slot prefills would fail here
    assert out_s == out_u, "fan-out diverged from independent submissions"
    assert peak_s < 0.6 * peak_u, \
        f"fan-out peak {peak_s} not < 0.6 x unshared {peak_u}"


# ---------------------------------------------------------------------------
# Chunked-prefill head-of-line sweep
# ---------------------------------------------------------------------------

def _stall_scenario(cfg, params, chunk, *, max_len, page, n_resident,
                    long_len, n_long):
    """Residents decode while `n_long` long admissions arrive; the gap
    between consecutive engine steps is the decode stall the residents see.
    Each long admission is chased by a short latency-critical request
    (priority 1) that *arrives* the instant the long one is admitted: under
    monolithic prefill it waits out the whole prompt before its own
    `add_request` can even run, while the chunked engine admits it on the
    next step and its (priority-ordered) chunk jumps the ingest queue.
    Returns per-scenario metrics (second run of a warmed engine)."""
    rng = np.random.default_rng(41 + chunk)
    residents = [[int(t) for t in rng.integers(1, 250, size=8)]
                 for _ in range(n_resident)]
    longs = [[int(t) for t in rng.integers(1, 250, size=long_len)]
             for _ in range(n_long)]
    shorts = [[int(t) for t in rng.integers(1, 250, size=8)]
              for _ in range(n_long)]

    def once(measure: bool):
        eng = InferenceEngine(cfg.with_(prefill_chunk=chunk), params,
                              max_batch=n_resident + 2, max_len=max_len,
                              kv_backend="paged", page_size=page)
        for i, p in enumerate(residents):
            eng.add_request(100 + i, p, max_new=10 ** 6)
        for _ in range(3):                         # settle into steady decode
            eng.step()
        base = eng.tokens_generated
        gaps = []
        pending = list(range(n_long))
        due_shorts: list = []                      # short ids awaiting a slot
        arrival = {}                               # short id -> arrival wall
        admit_wait = {}                            # short id -> wait for slot
        t0 = last = time.perf_counter()
        while (pending or due_shorts or any(
                s.active and s.req_id >= 200 for s in eng.slots)):
            long_in_flight = any(s.active and 200 <= s.req_id < 300
                                 for s in eng.slots)
            if due_shorts and eng.free_slots() and eng.can_admit(8):
                sid = due_shorts.pop(0)
                admit_wait[sid] = time.perf_counter() - arrival[sid]
                eng.add_request(300 + sid, shorts[sid], max_new=2,
                                priority=1)
            elif (pending and not long_in_flight and eng.free_slots()
                    and eng.can_admit(long_len)):
                j = pending.pop(0)
                # its latency-critical chaser arrives NOW — under
                # monolithic prefill, add_request blocks the driver for
                # the whole prompt before the chaser can be admitted
                arrival[j] = time.perf_counter()
                due_shorts.append(j)
                eng.add_request(200 + j, longs[j], max_new=4)
            eng.step()
            now = time.perf_counter()
            gaps.append(now - last)
            last = now
        dt = time.perf_counter() - t0
        if not measure:
            return None
        # wall TTFT from *arrival*: admission wait + engine-side TTFT
        short_ttfts = [admit_wait[j] + eng.ttft[300 + j]
                       for j in range(n_long)]
        long_ttfts = [eng.ttft[200 + j] for j in range(n_long)]
        return {
            "chunk": chunk,
            "tok_s": (eng.tokens_generated - base) / dt,
            "stall_max_s": max(gaps),
            "stall_mean_s": float(np.mean(gaps)),
            "step_median_s": _pctl(gaps, 50),
            "ttft_long_p50_s": _pctl(long_ttfts, 50),
            "ttft_long_p95_s": _pctl(long_ttfts, 95),
            "ttft_critical_p50_s": _pctl(short_ttfts, 50),
            "ttft_critical_p95_s": _pctl(short_ttfts, 95),
        }

    once(measure=False)                            # compile every shape
    return once(measure=True)


def _run_chunk_sweep(cfg, params, smoke, results):
    max_len, page = (512, 16) if smoke else (1024, 16)
    chunks = [0, 32, 64] if smoke else [0, 128, 256]
    long_len = int(0.85 * max_len)
    n_long = 2 if smoke else 4
    sweep = {}
    for chunk in chunks:
        m = _stall_scenario(cfg, params, chunk, max_len=max_len, page=page,
                            n_resident=3, long_len=long_len, n_long=n_long)
        tag = f"chunk_{chunk or 'monolithic'}"
        sweep[tag] = m
        emit(f"paged_engine/sweep_{tag}", m["stall_max_s"] * 1e6,
             f"tok_s={m['tok_s']:.1f};stall_max_s={m['stall_max_s']:.4f}"
             f";ttft_critical_p95_s={m['ttft_critical_p95_s']:.4f}")
        print(f"# sweep {tag}: stall_max={m['stall_max_s'] * 1e3:.1f} ms "
              f"stall_mean={m['stall_mean_s'] * 1e3:.1f} ms "
              f"ttft_critical_p95={m['ttft_critical_p95_s'] * 1e3:.1f} ms "
              f"tok/s={m['tok_s']:.1f}")
    results["chunk_sweep"] = {
        "meta": {"max_len": max_len, "page": page, "long_len": long_len,
                 "n_long": n_long},
        "scenarios": sweep,
    }
    # regression guards: a chunked admission must never stall running
    # decodes as long as a monolithic prefill does, and a latency-critical
    # latecomer must see its first token faster than a monolithic engine
    # can even admit it behind a long prefill. Violations are RETURNED so
    # the caller can write the trajectory first — the measured numbers are
    # most valuable exactly when the guard trips.
    failures = []
    mono = sweep["chunk_monolithic"]
    for tag, m in sweep.items():
        if m["chunk"]:
            if not m["stall_max_s"] < mono["stall_max_s"]:
                failures.append(
                    f"{tag}: max decode stall {m['stall_max_s']:.4f}s not "
                    f"below monolithic {mono['stall_max_s']:.4f}s")
            if not m["ttft_critical_p95_s"] < mono["ttft_critical_p95_s"]:
                failures.append(
                    f"{tag}: critical TTFT {m['ttft_critical_p95_s']:.4f}s "
                    f"not below monolithic "
                    f"{mono['ttft_critical_p95_s']:.4f}s")
    return failures


def run(smoke: bool = False, chunk_sweep_only: bool = False,
        out: str = "BENCH_serving.json"):
    cfg = TINY_EDGE_A.with_(dtype="float32")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    kv_bytes_per_tok = (2 * cfg.n_layers * cfg.n_kv_heads
                       * cfg.resolved_head_dim * 4)
    results = {"meta": {"smoke": smoke, "model": cfg.name,
                        "max_batch": MAX_BATCH, "max_len": MAX_LEN,
                        "page_size": PAGE},
               "workloads": {}}

    failures = []
    if not chunk_sweep_only:
        n_req, max_new = (6, 8) if smoke else (N_REQ, MAX_NEW)
        failures += _run_workloads(cfg, params, kv_bytes_per_tok, n_req,
                                   max_new, results)
        fanout, prefix_len, fan_new = (4, 80, 8) if smoke else (FANOUT,
                                                                FANOUT_PREFIX,
                                                                MAX_NEW)
        _run_fanout(cfg, params, kv_bytes_per_tok, fanout, prefix_len,
                    fan_new, results)
    if chunk_sweep_only or not smoke:
        # smoke CI splits the sweep into its own step (--chunk-sweep after
        # the fan-out smoke) so the stall measurement is not paid twice
        failures += _run_chunk_sweep(cfg, params, smoke, results)

    if chunk_sweep_only:
        # enrich an existing trajectory instead of clobbering its
        # workloads/fanout sections (CI writes both from separate steps)
        try:
            with open(out) as f:
                prev = json.load(f)
            prev["chunk_sweep"] = results["chunk_sweep"]
            results = prev
        except (OSError, ValueError, KeyError):
            pass
    with open(out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {out}")
    assert not failures, "; ".join(failures)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config / few steps (CI)")
    ap.add_argument("--chunk-sweep", action="store_true",
                    help="run only the chunked-prefill stall sweep")
    ap.add_argument("--out", default="BENCH_serving.json",
                    help="machine-readable trajectory output path")
    args = ap.parse_args()
    run(smoke=args.smoke, chunk_sweep_only=args.chunk_sweep, out=args.out)
