"""Dense vs paged serving-engine throughput under request-length skew, plus
the PICE ensemble fan-out under copy-on-write prefix sharing.

For each workload the same prompt stream runs through both KV backends of
`InferenceEngine` (greedy decode, so outputs are identical) and we report
tokens/s plus the KV memory each backend actually reserves. The paged
backend's pool is sized to the workload's *mean* demand, not the dense
worst case (max_batch x max_len), which is where its win comes from: at
high length skew most dense slot memory is dead reservation.

The fan-out scenario prefills one (query, sketch)-style prefix and expands
it N ways — once as N independent submissions, once through the COW fork
path (`generate_fanout`) — and reports the peak page usage of each. The
shared path must stay well under N x the unshared reservation (< 0.6x is
asserted, so CI smoke runs catch a silent regression to per-slot prefills).

  PYTHONPATH=src python -m benchmarks.paged_engine_bench [--smoke]

--smoke shrinks the workloads to a few requests/steps for CI.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.pice_cloud_edge import TINY_EDGE_A
from repro.models import transformer
from repro.serving.engine import InferenceEngine

MAX_BATCH = 8
MAX_LEN = 256
PAGE = 16
N_REQ = 24
MAX_NEW = 32
FANOUT = 6
FANOUT_PREFIX = 128          # 8 pages: a typical query+sketch expansion prefix

# request-length-skew settings: (name, prompt-length sampler)
WORKLOADS = [
    ("uniform", lambda rng: int(rng.integers(20, 28))),
    # heavy-tailed: mostly short prompts, a few near-max_len stragglers
    ("skewed", lambda rng: int(rng.integers(160, 200))
               if rng.random() < 0.2 else int(rng.integers(6, 16))),
]


def _prompts(sampler, seed: int, n_req: int):
    rng = np.random.default_rng(seed)
    return [[int(t) for t in rng.integers(1, 250, size=sampler(rng))]
            for _ in range(n_req)]


def _run(engine: InferenceEngine, prompts, max_new: int):
    engine.generate([prompts[0]], max_new=4)       # warmup / compile
    base = engine.tokens_generated
    t0 = time.perf_counter()
    engine.generate(prompts, max_new=max_new)
    dt = time.perf_counter() - t0
    return (engine.tokens_generated - base) / dt, dt


def _run_workloads(cfg, params, kv_bytes_per_tok, n_req, max_new):
    for wi, (name, sampler) in enumerate(WORKLOADS):
        prompts = _prompts(sampler, seed=97 + wi, n_req=n_req)
        demand = sum(min(len(p), MAX_LEN) + max_new for p in prompts)

        dense = InferenceEngine(cfg, params, max_batch=MAX_BATCH,
                                max_len=MAX_LEN)
        tps, dt = _run(dense, prompts, max_new)
        dense_bytes = MAX_BATCH * MAX_LEN * kv_bytes_per_tok
        emit(f"paged_engine/{name}_dense", dt * 1e6,
             f"tok_s={tps:.1f};kv_bytes={dense_bytes:.2e}")

        # pool sized at ~60% of the dense reservation: enough for the mean
        # demand; the skewed tail is absorbed by paging (evict + resume)
        n_pages = int(0.6 * MAX_BATCH * MAX_LEN / PAGE)
        paged = InferenceEngine(cfg, params, max_batch=MAX_BATCH,
                                max_len=MAX_LEN, kv_backend="paged",
                                page_size=PAGE, n_pages=n_pages)
        tps_p, dt_p = _run(paged, prompts, max_new)
        paged_bytes = n_pages * PAGE * kv_bytes_per_tok
        st = paged.memory_stats()
        emit(f"paged_engine/{name}_paged", dt_p * 1e6,
             f"tok_s={tps_p:.1f};kv_bytes={paged_bytes:.2e}"
             f";peak_pages={st['peak_pages']};evictions={st['evictions']}")
        print(f"# {name}: demand={demand} tok; dense reserves "
              f"{MAX_BATCH * MAX_LEN} tok, paged pool {n_pages * PAGE} tok "
              f"({paged_bytes / dense_bytes:.0%}); throughput ratio "
              f"paged/dense={tps_p / tps:.2f}")


def _run_fanout(cfg, params, kv_bytes_per_tok, fanout, prefix_len, max_new):
    """N-way expansion of one shared prefix: independent vs COW fork path."""
    rng = np.random.default_rng(211)
    prefix = [int(t) for t in rng.integers(1, 250, size=prefix_len)]
    kw = dict(max_batch=fanout + 1, max_len=MAX_LEN, kv_backend="paged",
              page_size=PAGE)

    unshared = InferenceEngine(cfg, params, **kw)
    unshared.generate([prefix], max_new=4)         # warmup / compile
    unshared.peak_pages = 0
    t0 = time.perf_counter()
    out_u = unshared.generate([prefix] * fanout, max_new=max_new)
    dt_u = time.perf_counter() - t0
    peak_u = unshared.memory_stats()["peak_pages"]
    emit(f"paged_engine/fanout{fanout}_unshared", dt_u * 1e6,
         f"peak_pages={peak_u};kv_bytes={peak_u * PAGE * kv_bytes_per_tok:.2e}")

    shared = InferenceEngine(cfg, params, **kw)
    shared.generate([prefix], max_new=4)
    shared.peak_pages = 0
    t0 = time.perf_counter()
    out_s = shared.generate_fanout(prefix, [[] for _ in range(fanout)],
                                   max_new=max_new)
    dt_s = time.perf_counter() - t0
    peak_s = shared.memory_stats()["peak_pages"]
    emit(f"paged_engine/fanout{fanout}_shared", dt_s * 1e6,
         f"peak_pages={peak_s};kv_bytes={peak_s * PAGE * kv_bytes_per_tok:.2e}"
         f";ratio={peak_s / max(peak_u, 1):.2f}")
    print(f"# fanout x{fanout}: prefix {prefix_len} tok "
          f"({prefix_len // PAGE} pages); peak pages unshared={peak_u} "
          f"shared={peak_s} ({peak_s / max(peak_u, 1):.0%})")

    # regression guards: the fork path must stay bit-identical to the
    # independent submissions AND far under the unshared reservation —
    # a silent fallback to per-slot prefills would fail here
    assert out_s == out_u, "fan-out diverged from independent submissions"
    assert peak_s < 0.6 * peak_u, \
        f"fan-out peak {peak_s} not < 0.6 x unshared {peak_u}"


def run(smoke: bool = False):
    cfg = TINY_EDGE_A.with_(dtype="float32")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    kv_bytes_per_tok = (2 * cfg.n_layers * cfg.n_kv_heads
                       * cfg.resolved_head_dim * 4)

    n_req, max_new = (6, 8) if smoke else (N_REQ, MAX_NEW)
    _run_workloads(cfg, params, kv_bytes_per_tok, n_req, max_new)
    fanout, prefix_len, fan_new = (4, 80, 8) if smoke else (FANOUT,
                                                            FANOUT_PREFIX,
                                                            MAX_NEW)
    _run_fanout(cfg, params, kv_bytes_per_tok, fanout, prefix_len, fan_new)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config / few steps (CI)")
    run(smoke=ap.parse_args().smoke)
