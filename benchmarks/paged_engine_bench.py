"""Dense vs paged serving-engine throughput under request-length skew.

For each workload the same prompt stream runs through both KV backends of
`InferenceEngine` (greedy decode, so outputs are identical) and we report
tokens/s plus the KV memory each backend actually reserves. The paged
backend's pool is sized to the workload's *mean* demand, not the dense
worst case (max_batch x max_len), which is where its win comes from: at
high length skew most dense slot memory is dead reservation.

  PYTHONPATH=src python -m benchmarks.paged_engine_bench
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.pice_cloud_edge import TINY_EDGE_A
from repro.models import transformer
from repro.serving.engine import InferenceEngine

MAX_BATCH = 8
MAX_LEN = 256
PAGE = 16
N_REQ = 24
MAX_NEW = 32

# request-length-skew settings: (name, prompt-length sampler)
WORKLOADS = [
    ("uniform", lambda rng: int(rng.integers(20, 28))),
    # heavy-tailed: mostly short prompts, a few near-max_len stragglers
    ("skewed", lambda rng: int(rng.integers(160, 200))
               if rng.random() < 0.2 else int(rng.integers(6, 16))),
]


def _prompts(sampler, seed: int):
    rng = np.random.default_rng(seed)
    return [[int(t) for t in rng.integers(1, 250, size=sampler(rng))]
            for _ in range(N_REQ)]


def _run(engine: InferenceEngine, prompts):
    engine.generate([prompts[0]], max_new=4)       # warmup / compile
    base = engine.tokens_generated
    t0 = time.perf_counter()
    engine.generate(prompts, max_new=MAX_NEW)
    dt = time.perf_counter() - t0
    return (engine.tokens_generated - base) / dt, dt


def run():
    cfg = TINY_EDGE_A.with_(dtype="float32")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    kv_bytes_per_tok = (2 * cfg.n_layers * cfg.n_kv_heads
                       * cfg.resolved_head_dim * 4)

    for wi, (name, sampler) in enumerate(WORKLOADS):
        prompts = _prompts(sampler, seed=97 + wi)
        demand = sum(min(len(p), MAX_LEN) + MAX_NEW for p in prompts)

        dense = InferenceEngine(cfg, params, max_batch=MAX_BATCH,
                                max_len=MAX_LEN)
        tps, dt = _run(dense, prompts)
        dense_bytes = MAX_BATCH * MAX_LEN * kv_bytes_per_tok
        emit(f"paged_engine/{name}_dense", dt * 1e6,
             f"tok_s={tps:.1f};kv_bytes={dense_bytes:.2e}")

        # pool sized at ~60% of the dense reservation: enough for the mean
        # demand; the skewed tail is absorbed by paging (evict + resume)
        n_pages = int(0.6 * MAX_BATCH * MAX_LEN / PAGE)
        paged = InferenceEngine(cfg, params, max_batch=MAX_BATCH,
                                max_len=MAX_LEN, kv_backend="paged",
                                page_size=PAGE, n_pages=n_pages)
        tps_p, dt_p = _run(paged, prompts)
        paged_bytes = n_pages * PAGE * kv_bytes_per_tok
        st = paged.memory_stats()
        emit(f"paged_engine/{name}_paged", dt_p * 1e6,
             f"tok_s={tps_p:.1f};kv_bytes={paged_bytes:.2e}"
             f";peak_pages={st['peak_pages']};evictions={st['evictions']}")
        print(f"# {name}: demand={demand} tok; dense reserves "
              f"{MAX_BATCH * MAX_LEN} tok, paged pool {n_pages * PAGE} tok "
              f"({paged_bytes / dense_bytes:.0%}); throughput ratio "
              f"paged/dense={tps_p / tps:.2f}")


if __name__ == "__main__":
    run()
