"""Paper Fig. 12: throughput/latency vs offered load (RPM).

Validation: below cloud capacity PICE ~ cloud-only; past it, cloud-only
saturates (latency blows up) while PICE keeps scaling via edge offload."""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core.simulator import (SimConfig, make_requests,
                                  simulate_cloud_only, simulate_pice,
                                  simulate_routing)


def run(n_requests: int = 250):
    out = {}
    for rpm in (10, 20, 30, 40, 60, 80):
        for name, fn in (("cloud_only", simulate_cloud_only),
                         ("routing", simulate_routing),
                         ("pice", simulate_pice)):
            cfg = SimConfig(cloud_model="llama3-70b", cloud_batch=20,
                            rpm=float(rpm), n_requests=n_requests)
            res, us = timed(fn, cfg, make_requests(n_requests, rpm, cfg.seed))
            out[(rpm, name)] = res
            emit(f"fig12/rpm_{rpm}/{name}", us,
                 f"thr={res.throughput_per_min:.2f};lat={res.avg_latency_s:.1f}s")
    return out


if __name__ == "__main__":
    run()
