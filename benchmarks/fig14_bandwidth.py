"""Paper Fig. 14: impact of cloud<->edge bandwidth.

Validation: minimal sensitivity — only queries/sketches cross the network, a
few tens of ms even at low bandwidth; inference time dominates."""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core.simulator import (SimConfig, make_requests,
                                  simulate_cloud_only, simulate_pice,
                                  simulate_routing)


def run(n_requests: int = 250):
    out = {}
    for bw in (10, 50, 100, 500, 1000):
        for name, fn in (("cloud_only", simulate_cloud_only),
                         ("routing", simulate_routing),
                         ("pice", simulate_pice)):
            cfg = SimConfig(cloud_model="llama3-70b", cloud_batch=20, rpm=30,
                            n_requests=n_requests, bandwidth_mbps=float(bw))
            res, us = timed(fn, cfg, make_requests(n_requests, cfg.rpm,
                                                   cfg.seed))
            out[(bw, name)] = res
            emit(f"fig14/bw_{bw}mbps/{name}", us,
                 f"thr={res.throughput_per_min:.2f};lat={res.avg_latency_s:.1f}s")
    ths = [out[(bw, "pice")].throughput_per_min for bw in (10, 50, 100, 500, 1000)]
    emit("fig14/pice_bw_spread", 0.0,
         f"spread={(max(ths)-min(ths))/max(ths):.1%}")
    return out


if __name__ == "__main__":
    run()
