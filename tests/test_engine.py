"""InferenceEngine unit tests: slot recycling, EOS termination, prompt
bucketing, result ordering, and dense-vs-paged backend equivalence."""
import jax
import numpy as np
import pytest

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.serving.engine import InferenceEngine, _bucket
from repro.serving.sampler import SamplerConfig

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                   max_seq_len=512, dtype="float32", remat=False)


@pytest.fixture(scope="module")
def params():
    return transformer.init_params(TINY, jax.random.PRNGKey(0))


def _engine(params, **kw):
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_len", 128)
    return InferenceEngine(TINY, params, **kw)


# ---------------------------------------------------------------------------
# bucketing
# ---------------------------------------------------------------------------

def test_bucket_is_power_of_two_cover():
    assert _bucket(1) == 32
    assert _bucket(32) == 32
    assert _bucket(33) == 64
    assert _bucket(100) == 128


@pytest.mark.parametrize("backend", ["dense", "paged"])
def test_long_prompt_truncates_to_max_len(params, backend):
    kw = {"kv_backend": "paged", "page_size": 16} if backend == "paged" else {}
    eng = _engine(params, max_len=32, **kw)
    (toks, lps), = eng.generate([list(range(1, 100))], max_new=4)
    assert 1 <= len(toks) <= 4 and len(lps) == len(toks)


def test_context_capacity_terminates_identically(params):
    """A prompt that fills max_len exactly stops after one sampled token in
    both backends (decoding past capacity would overwrite live cache)."""
    prompt = list(range(1, 65))          # bucket 64 == max_len
    outs = {}
    for backend in ("dense", "paged"):
        kw = {"kv_backend": "paged", "page_size": 16} \
            if backend == "paged" else {}
        eng = _engine(params, max_len=64, **kw)
        (toks, _), = eng.generate([prompt], max_new=8)
        outs[backend] = toks
        assert len(toks) == 1
    assert outs["dense"] == outs["paged"]


# ---------------------------------------------------------------------------
# slot lifecycle
# ---------------------------------------------------------------------------

def test_slot_recycling_more_requests_than_slots(params):
    eng = _engine(params, max_batch=2)
    prompts = [[10 + i, 20, 30] for i in range(7)]
    outs = eng.generate(prompts, max_new=5)
    assert len(outs) == 7
    assert all(1 <= len(t) <= 5 for t, _ in outs)
    assert len(eng.free_slots()) == eng.max_batch


def test_add_request_raises_when_full(params):
    eng = _engine(params, max_batch=1)
    eng.add_request(0, [5, 6, 7], max_new=100)
    with pytest.raises(RuntimeError):
        eng.add_request(1, [8, 9], max_new=4)


def test_eos_frees_slot_immediately(params):
    eng = _engine(params, eos_id=0)
    slot = eng.add_request(0, [5, 6, 7], max_new=40)
    while eng.slots[slot].active:
        assert eng.step()
    s = eng.slots[slot]
    assert s.tokens[-1] == eng.eos_id or s.generated == s.max_new
    assert slot in eng.free_slots()
    # EOS anywhere in the stream must have ended generation right there
    if eng.eos_id in s.tokens:
        assert s.tokens.index(eng.eos_id) == len(s.tokens) - 1


def test_max_new_terminates(params):
    eng = _engine(params)
    (toks, _), = eng.generate([[9, 8, 7]], max_new=3)
    assert len(toks) <= 3


# ---------------------------------------------------------------------------
# ordering / isolation
# ---------------------------------------------------------------------------

def test_generate_preserves_order_with_mixed_lengths(params):
    eng = _engine(params, max_batch=2)
    prompts = [[40] * 60, [50, 51], [60] * 33, [70], [80] * 9]
    outs = eng.generate(prompts, max_new=6)
    assert len(outs) == len(prompts)
    # each prompt's result must match a solo run of the same prompt (greedy)
    for i in (1, 3):
        solo = _engine(params, max_batch=1)
        (ref, _), = solo.generate([prompts[i]], max_new=6)
        assert outs[i][0] == ref


def test_greedy_is_deterministic_across_engines(params):
    a = _engine(params).generate([[33, 34, 35]], max_new=8)
    b = _engine(params).generate([[33, 34, 35]], max_new=8)
    assert a == b


# ---------------------------------------------------------------------------
# dense vs paged equivalence
# ---------------------------------------------------------------------------

def test_dense_paged_equivalence_mixed_lengths(params):
    prompts = [[65, 66, 67, 68], [70, 71], [80] * 40, [90]]
    dense = _engine(params)
    paged = _engine(params, kv_backend="paged", page_size=16)
    od = dense.generate(prompts, max_new=16)
    op = paged.generate(prompts, max_new=16)
    for i, ((td, ld), (tp, lp)) in enumerate(zip(od, op)):
        assert td == tp, f"prompt {i}: tokens diverge"
        np.testing.assert_array_equal(np.asarray(ld), np.asarray(lp),
                                      err_msg=f"prompt {i}: logprobs diverge")


def test_dense_paged_equivalence_with_sampling(params):
    """Same PRNG stream + same request order -> identical stochastic samples."""
    sampler = SamplerConfig(temperature=0.8, top_k=16)
    prompts = [[12, 13, 14], [25, 26]]
    od = _engine(params, sampler=sampler).generate(prompts, max_new=10)
    op = _engine(params, sampler=sampler,
                 kv_backend="paged", page_size=16).generate(prompts, max_new=10)
    for (td, _), (tp, _) in zip(od, op):
        assert td == tp


def test_paged_pages_freed_on_completion(params):
    eng = _engine(params, kv_backend="paged", page_size=16)
    eng.generate([[65, 66, 67], [70] * 20], max_new=12)
    assert eng.alloc.pages_in_use == 0
    assert eng.memory_stats()["utilization"] == 0.0
    assert eng.peak_pages > 0
    assert np.all(eng.block_table == -1)


def test_paged_pool_exhaustion_evicts_and_recovers(params):
    """A pool too small for the full batch must preempt (evict + resubmit)
    the youngest request, and still produce dense-identical results."""
    prompts = [[65, 66, 67, 68], [70, 71], [80, 81, 82]]
    paged = _engine(params, kv_backend="paged", page_size=8, n_pages=6,
                    max_len=64)
    dense = _engine(params, max_len=64)
    op = paged.generate(prompts, max_new=24)
    od = dense.generate(prompts, max_new=24)
    assert paged.evictions > 0, "pool of 6 pages must trigger preemption"
    for (td, _), (tp, _) in zip(od, op):
        assert td == tp
    assert paged.alloc.pages_in_use == 0


def test_paged_lone_request_too_big_raises(params):
    eng = _engine(params, kv_backend="paged", page_size=8, n_pages=2,
                  max_len=64)
    with pytest.raises(MemoryError):
        eng.generate([[65, 66, 67]], max_new=40)


def test_memory_stats_shape(params):
    for eng in (_engine(params),
                _engine(params, kv_backend="paged", page_size=16)):
        st = eng.memory_stats()
        assert {"backend", "pages_total", "pages_in_use", "utilization",
                "evictions"} <= set(st)
        assert st["pages_in_use"] == 0


# ---------------------------------------------------------------------------
# copy-on-write prefix sharing (ensemble fan-out)
# ---------------------------------------------------------------------------

FANOUT_PROMPT = [(i % 100) + 1 for i in range(70)]   # 5 pages at page 16


def test_fanout_bit_identical_to_independent_and_under_06x_peak(params):
    """N-way fan-out from one prefix must produce exactly the tokens and
    logprobs of N independent paged submissions while holding the prefix
    once: peak page usage < 0.6x the unshared peak (prefix >= 4 pages)."""
    N = 4
    unshared = _engine(params, max_batch=N + 1, kv_backend="paged",
                       page_size=16)
    shared = _engine(params, max_batch=N + 1, kv_backend="paged",
                     page_size=16)
    ou = unshared.generate([FANOUT_PROMPT] * N, max_new=8)
    os_ = shared.generate_fanout(FANOUT_PROMPT, [[] for _ in range(N)],
                                 max_new=8)
    for i, ((tu, lu), (ts, ls)) in enumerate(zip(ou, os_)):
        assert tu == ts, f"fork {i}: tokens diverge"
        np.testing.assert_array_equal(np.asarray(lu), np.asarray(ls),
                                      err_msg=f"fork {i}: logprobs diverge")
    peak_u = unshared.memory_stats()["peak_pages"]
    peak_s = shared.memory_stats()["peak_pages"]
    assert len(FANOUT_PROMPT) >= 4 * 16            # prefix >= 4 pages
    assert peak_s < 0.6 * peak_u, (peak_s, peak_u)
    # full drain: every refcount back to zero, no page leaked or double-freed
    assert shared.alloc.pages_in_use == 0
    assert all(c == 0 for c in shared.alloc.refcount)
    assert sorted(shared.alloc.free) == list(range(shared.n_pages))


def test_fanout_sampled_bit_identical(params):
    """Stochastic sampling: same max_batch + same PRNG stream -> the fan-out
    draws exactly what independent submissions would (the prefix parks in
    the LAST slot so forks land on the same batch rows)."""
    sampler = SamplerConfig(temperature=0.8, top_k=16)
    N = 3
    ou = _engine(params, max_batch=N + 1, kv_backend="paged", page_size=16,
                 sampler=sampler).generate([FANOUT_PROMPT] * N, max_new=8)
    os_ = _engine(params, max_batch=N + 1, kv_backend="paged", page_size=16,
                  sampler=sampler).generate_fanout(
        FANOUT_PROMPT, [[] for _ in range(N)], max_new=8)
    assert ou == os_
    # distinct forks actually diverge (they are independent samples)
    assert len({tuple(t) for t, _ in os_}) > 1


def test_fanout_suffixes_and_sharing_telemetry(params):
    """Per-group suffixes are teacher-forced on top of the shared prefix;
    the monitor's windowed telemetry must see the sharing."""
    from repro.core.profiler import RuntimeMonitor
    eng = _engine(params, max_batch=4, kv_backend="paged", page_size=16)
    outs = eng.generate_fanout(FANOUT_PROMPT, [[5, 6, 7], [9], [11, 12]],
                               max_new=6)
    assert len(outs) == 3
    for toks, lps in outs:
        assert 1 <= len(toks) <= 6 and len(lps) == len(toks)
    assert eng.alloc.pages_in_use == 0
    mon = RuntimeMonitor()
    mon.observe_engines([eng])
    assert mon.kv_pages_shared > 0
    assert mon.kv_pages_logical > mon.kv_pages_used
    assert mon.kv_sharing_savings > 0.0
    assert 0.0 < mon.kv_shared_fraction <= 1.0


def test_evicting_a_fork_never_frees_sibling_pages(params):
    """A pool too small for the whole fan-out preempts forks; refcounted
    release must leave sibling (and prefix) pages intact, and the resumed
    forks must still produce the unconstrained results (greedy)."""
    N = 3
    big = _engine(params, max_batch=N + 1, kv_backend="paged", page_size=8)
    ref = big.generate([FANOUT_PROMPT] * N, max_new=12)
    small = _engine(params, max_batch=N + 1, kv_backend="paged", page_size=8,
                    n_pages=12)
    out = small.generate_fanout(FANOUT_PROMPT, [[] for _ in range(N)],
                                max_new=12)
    assert small.evictions > 0, "a 12-page pool must preempt"
    for a, b in zip(ref, out):
        assert a == b
    assert small.alloc.pages_in_use == 0
    assert all(c == 0 for c in small.alloc.refcount)
    assert sorted(small.alloc.free) == list(range(small.n_pages))


def test_fanout_dense_backend_falls_back(params):
    a = _engine(params).generate_fanout([1, 2, 3], [[4], [5]], max_new=4)
    b = _engine(params).generate([[1, 2, 3, 4], [1, 2, 3, 5]], max_new=4)
    assert a == b


def test_prefix_sharing_opt_out_is_monolithic(params):
    """prefix_sharing=False restores exact monolithic submissions (the
    pipeline-level dense<->paged A/B escape hatch)."""
    a = _engine(params, kv_backend="paged", page_size=16,
                prefix_sharing=False).generate_fanout(
        FANOUT_PROMPT, [[1], [2]], max_new=4)
    b = _engine(params, kv_backend="paged", page_size=16).generate(
        [FANOUT_PROMPT + [1], FANOUT_PROMPT + [2]], max_new=4)
    assert a == b


def test_release_prefix_frees_parked_pages(params):
    eng = _engine(params, kv_backend="paged", page_size=16)
    slot = eng.prefill_prefix(FANOUT_PROMPT)
    assert eng.slots[slot].parked
    assert slot not in eng.free_slots()
    assert eng.alloc.pages_in_use == 5
    eng.release_prefix(slot)
    assert eng.alloc.pages_in_use == 0
    assert slot in eng.free_slots()


# ---------------------------------------------------------------------------
# priority-aware eviction (SLA class / sketch level ordering)
# ---------------------------------------------------------------------------

def test_eviction_prefers_low_priority_over_youth(params):
    """Victim selection orders by (priority, then youth): a latency-critical
    slot admitted LAST must survive while an older opportunistic one is
    preempted — the pre-priority engine would have evicted the youngest."""
    eng = _engine(params, kv_backend="paged", page_size=16)
    lo = eng.add_request(0, [5, 6, 7], max_new=40, priority=0)
    hi = eng.add_request(1, [8, 9, 10], max_new=40, priority=1)
    assert eng.slots[hi].arrival > eng.slots[lo].arrival
    assert eng._evict_victim(protect=-1)
    assert eng.slots[lo].evicted and not eng.slots[lo].active
    assert eng.slots[hi].active, "high-priority slot must not be evicted"


def test_eviction_equal_priority_falls_back_to_youngest(params):
    eng = _engine(params, kv_backend="paged", page_size=16)
    old = eng.add_request(0, [5, 6, 7], max_new=40)
    young = eng.add_request(1, [8, 9, 10], max_new=40)
    assert eng._evict_victim(protect=-1)
    assert eng.slots[young].evicted
    assert eng.slots[old].active


def test_priority_preserved_across_resume(params):
    """A preempted request resumes with its priority intact (threaded
    through the resume queue), and still completes correctly."""
    prompts = [[65, 66, 67, 68], [70, 71], [80, 81, 82]]
    ref = _engine(params, max_len=64).generate(prompts, max_new=24)
    eng = _engine(params, kv_backend="paged", page_size=8, n_pages=6,
                  max_len=64)
    out = eng.generate(prompts, max_new=24, priorities=[2, 1, 0])
    assert eng.evictions > 0
    for (td, _), (tp, _) in zip(ref, out):
        assert td == tp
    assert eng.alloc.pages_in_use == 0


# ---------------------------------------------------------------------------
# serving-layer bug sweep
# ---------------------------------------------------------------------------

def test_dense_consume_peak_is_windowed(params):
    """Dense fleets drain to zero active slots between synchronous requests;
    consume_peak must report the window's high-water mark, not ~0."""
    eng = _engine(params)                          # dense, max_batch 3
    eng.generate([[1, 2, 3], [4, 5], [6]], max_new=4)
    assert sum(1 for s in eng.slots if s.active) == 0      # drained
    assert eng.consume_peak() == 3
    assert eng.consume_peak() == 0                 # window reset


@pytest.mark.parametrize("backend", ["dense", "paged"])
def test_inactive_slot_lengths_do_not_drift(params, backend):
    """Freed slots must stop advancing their cache lengths: before the fix
    they drifted past max_len and kept issuing clipped writes."""
    kw = {"kv_backend": "paged", "page_size": 16} if backend == "paged" else {}
    eng = _engine(params, max_batch=2, max_len=64, **kw)
    s1 = eng.add_request(1, [8, 9, 10], max_new=40)        # long-running
    s0 = eng.add_request(0, [5, 6, 7], max_new=1)          # done immediately
    assert s0 != s1 and not eng.slots[s0].active
    frozen = eng.slots[s0].ctx_len
    while eng.slots[s1].active:
        eng.step()
    lens = np.asarray(eng.cache["lengths"])
    assert lens[s0] == frozen, "inactive slot length drifted"
    assert lens[s1] <= eng.max_len


def test_monitor_sees_windowed_peak_after_drain(params):
    """The pipeline observes engines between (synchronous) requests, when
    pools have drained to zero — the monitor must still see the high-water
    mark of the window, or memory pressure would always read 0."""
    from repro.core.profiler import RuntimeMonitor
    eng = _engine(params, kv_backend="paged", page_size=16)
    eng.generate([[65, 66, 67], [70] * 20], max_new=12)
    assert eng.alloc.pages_in_use == 0           # drained
    mon = RuntimeMonitor()
    mon.observe_engines([eng])
    assert mon.kv_pages_used > 0
    assert mon.kv_utilization > 0.0
    # window resets: a second observation with no traffic reads current (0)
    mon.observe_engines([eng])
    assert mon.kv_pages_used == 0
