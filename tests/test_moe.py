"""MoE dispatch properties: sort-based == cumsum-based, capacity dropping,
load-balance loss behavior."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-test.txt)")
from hypothesis import given, settings, strategies as st

from repro.models import moe as moe_lib
from repro.models.config import ModelConfig


def _cfg(E, K, cf=1.25, sort=False):
    return ModelConfig(name="m", family="moe", n_layers=1, d_model=32,
                       n_heads=4, n_kv_heads=4, d_ff=64, moe_d_ff=64,
                       vocab_size=64, n_experts=E, experts_per_token=K,
                       capacity_factor=cf, moe_sort_dispatch=sort,
                       dtype="float32")


@given(st.integers(min_value=2, max_value=8),      # experts
       st.integers(min_value=1, max_value=2),      # top-k
       st.integers(min_value=1, max_value=4),      # batch
       st.integers(min_value=2, max_value=16),     # seq
       st.integers(min_value=0, max_value=5))      # seed
@settings(max_examples=30, deadline=None)
def test_sort_dispatch_equals_cumsum(E, K, B, S, seed):
    K = min(K, E)
    key = jax.random.PRNGKey(seed)
    cfg = _cfg(E, K)
    params = moe_lib.init_moe(cfg, key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, cfg.d_model))
    o1, a1 = moe_lib.moe_fwd(cfg, params, x)
    o2, a2 = moe_lib.moe_fwd(cfg.with_(moe_sort_dispatch=True), params, x)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5,
                               atol=1e-6)
    assert float(a1) == float(a2)


def test_capacity_dropping_bounds_work():
    """With capacity_factor -> 0 most tokens drop (output ~ 0); with a huge
    factor nothing drops and outputs differ."""
    key = jax.random.PRNGKey(0)
    cfg = _cfg(4, 2, cf=8.0)
    params = moe_lib.init_moe(cfg, key)
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    full, _ = moe_lib.moe_fwd(cfg, params, x)
    tiny, _ = moe_lib.moe_fwd(cfg.with_(capacity_factor=1e-6), params, x)
    # minimal capacity (floor of 4 slots/expert) keeps some tokens, drops most
    norm_full = float(jnp.linalg.norm(full))
    norm_tiny = float(jnp.linalg.norm(tiny))
    assert norm_tiny < norm_full


def test_aux_loss_favors_balance():
    """Uniform routing logits -> aux ~ 1; collapsed routing -> aux ~ E."""
    key = jax.random.PRNGKey(1)
    cfg = _cfg(4, 1)
    params = moe_lib.init_moe(cfg, key)
    # uniform: zero router weights
    params_u = dict(params, router=jnp.zeros_like(params["router"]))
    x = jax.random.normal(key, (4, 64, cfg.d_model))
    _, aux_u = moe_lib.moe_fwd(cfg, params_u, x)
    # collapsed: expert 0 wins for every token (positive inputs x positive
    # column-0 weights, other columns zero)
    router_c = jnp.zeros_like(params["router"]).at[:, 0].set(1.0)
    x_pos = jnp.abs(x) + 0.1
    _, aux_c = moe_lib.moe_fwd(cfg, dict(params, router=router_c), x_pos)
    assert 0.9 <= float(aux_u) <= 1.6
    assert float(aux_c) > 2.0
    assert float(aux_c) > float(aux_u)


def test_grad_flows_through_dispatch():
    key = jax.random.PRNGKey(2)
    for sort in (False, True):
        cfg = _cfg(4, 2, sort=sort)
        params = moe_lib.init_moe(cfg, key)
        x = jax.random.normal(key, (2, 8, cfg.d_model))

        def loss(p):
            o, aux = moe_lib.moe_fwd(cfg, p, x)
            return jnp.sum(o ** 2) + 0.01 * aux

        g = jax.grad(loss)(params)
        gn = float(sum(jnp.sum(jnp.abs(v)) for v in jax.tree.leaves(g)))
        assert np.isfinite(gn) and gn > 0, f"sort={sort}"
