"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import ops as da_ops
from repro.kernels.decode_attention import ref as da_ref
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.rmsnorm import ops as rn_ops
from repro.kernels.rmsnorm import ref as rn_ref
from repro.kernels.ssm_scan import ops as ssm_ops
from repro.kernels.ssm_scan import ref as ssm_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,Hq,Hkv,hd", [
    (2, 128, 4, 2, 32),
    (1, 256, 8, 8, 64),
    (2, 64, 4, 1, 16),
    # the long-context case adds wall time, not coverage, on CPU interpret
    pytest.param(1, 512, 2, 2, 128, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("window", [0, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, S, Hq, Hkv, hd, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), dtype)
    ref = fa_ref.mha_ref(q, k, v, causal=True, window=window)
    out = fa_ops.flash_attention(q, k, v, causal=True, window=window,
                                 block_q=64, block_kv=64)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 32))
    k = jax.random.normal(ks[1], (2, 128, 4, 32))
    v = jax.random.normal(ks[2], (2, 128, 4, 32))
    ref = fa_ref.mha_ref(q, k, v, causal=False)
    out = fa_ops.flash_attention(q, k, v, causal=False, block_q=32, block_kv=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,Hq,Hkv,hd", [
    (2, 128, 4, 2, 32),
    (3, 256, 8, 8, 64),
    pytest.param(1, 512, 4, 1, 16, marks=pytest.mark.slow),
    (2, 64, 16, 4, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, S, Hq, Hkv, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = jax.random.normal(ks[0], (B, 1, Hq, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), dtype)
    lens = jax.random.randint(ks[3], (B,), 1, S + 1)
    ref = da_ref.decode_attention_ref(q, k, v, lens)
    out = da_ops.decode_attention(q, k, v, lens, block_s=64)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_decode_attention_ragged_lengths():
    """Entries past `lengths` must not influence the output."""
    B, S, H, hd = 2, 128, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    lens = jnp.array([40, 100])
    out1 = da_ops.decode_attention(q, k, v, lens, block_s=32)
    k2 = k.at[0, 40:].set(99.0)
    v2 = v.at[0, 40:].set(-99.0)
    out2 = da_ops.decode_attention(q, k2, v2, lens, block_s=32)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 128), (2, 37, 256), (1, 8, 8, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    x = jax.random.normal(ks[0], shape, dtype)
    s = jax.random.normal(ks[1], (shape[-1],))
    ref = rn_ref.rmsnorm_ref(x, s)
    out = rn_ops.rmsnorm(x, s)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# ssm scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Bb,S,H,P,N,chunk", [
    (2, 64, 3, 8, 16, 16),
    (1, 128, 2, 16, 32, 32),
    (2, 96, 1, 4, 8, 32),     # S not a multiple of chunk -> falls back
])
def test_ssm_scan_vs_sequential(Bb, S, H, P, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = jax.random.normal(ks[0], (Bb, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, S, H))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (Bb, S, N)) * 0.3
    C = jax.random.normal(ks[4], (Bb, S, N)) * 0.3
    y_ref, h_ref = ssm_ref.ssd_sequential_ref(x, dt, A, B, C)
    y_chu, h_chu = ssm_ref.ssd_chunked_ref(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_chu), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    y_pal, h_pal = ssm_ops.ssm_scan(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_pal), np.asarray(h_ref),
                               rtol=1e-4, atol=1e-4)


def test_ssm_scan_initial_state():
    Bb, S, H, P, N = 2, 32, 2, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(6), 6)
    x = jax.random.normal(ks[0], (Bb, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, S, H))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (Bb, S, N)) * 0.3
    C = jax.random.normal(ks[4], (Bb, S, N)) * 0.3
    h0 = jax.random.normal(ks[5], (Bb, H, P, N)) * 0.2
    y_ref, h_ref = ssm_ref.ssd_sequential_ref(x, dt, A, B, C, initial_state=h0)
    y, h = ssm_ops.ssm_scan(x, dt, A, B, C, chunk=16, initial_state=h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), rtol=1e-4,
                               atol=1e-4)
