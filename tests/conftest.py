import os

# Tests run on the single real CPU device (the 512-placeholder flag is ONLY
# set inside repro.launch.dryrun, which tests run as a subprocess if at all).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# Tier-1 wall time is dominated by XLA:CPU compile time of tiny test models;
# dropping the backend optimization level halves the suite with identical
# semantics (fast-math stays off). Honors any user-provided XLA_FLAGS.
os.environ.setdefault("XLA_FLAGS", "--xla_backend_optimization_level=0")

import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
