import os

# Tests run on the single real CPU device (the 512-placeholder flag is ONLY
# set inside repro.launch.dryrun, which tests run as a subprocess if at all).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
