"""Paged KV cache: equivalence with the contiguous cache + allocator
invariants (property-based under hypothesis, fixed examples without it)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.kernels.decode_attention import ref as da_ref
from repro.models import paged_cache as pc


def test_paged_decode_equals_contiguous():
    """Attention over gathered pages == attention over a contiguous cache."""
    key = jax.random.PRNGKey(0)
    B, S, kv, hd, page = 3, 64, 2, 16, 8
    P = S // page
    ks = jax.random.split(key, 4)
    contiguous_k = jax.random.normal(ks[0], (B, S, kv, hd))
    contiguous_v = jax.random.normal(ks[1], (B, S, kv, hd))
    q = jax.random.normal(ks[2], (B, 1, 4, hd))
    lens = jnp.array([13, 40, 64])

    # scatter the contiguous cache into a shuffled page pool
    n_pages = B * P + 5
    pages_k = jnp.zeros((n_pages, page, kv, hd))
    pages_v = jnp.zeros((n_pages, page, kv, hd))
    rng = np.random.default_rng(0)
    ids = rng.permutation(n_pages)[: B * P].reshape(B, P)
    for b in range(B):
        for p in range(P):
            pages_k = pages_k.at[ids[b, p]].set(
                contiguous_k[b, p * page:(p + 1) * page])
            pages_v = pages_v.at[ids[b, p]].set(
                contiguous_v[b, p * page:(p + 1) * page])
    table = jnp.asarray(ids, jnp.int32)

    gk = pc.gather_sequence(pages_k, table)
    gv = pc.gather_sequence(pages_v, table)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(contiguous_k))
    out_pc = da_ref.decode_attention_ref(q, gk, gv, lens)
    out_ct = da_ref.decode_attention_ref(q, contiguous_k, contiguous_v, lens)
    np.testing.assert_allclose(np.asarray(out_pc), np.asarray(out_ct))


def test_write_token_lands_in_right_page():
    B, kv, hd, page, P = 2, 2, 8, 4, 3
    pages_k = jnp.zeros((10, page, kv, hd))
    pages_v = jnp.zeros((10, page, kv, hd))
    table = jnp.asarray([[7, 2, 5], [1, 3, 9]], jnp.int32)
    lens = jnp.asarray([5, 2])          # -> page idx 1 off 1 ; page idx 0 off 2
    nk = jnp.ones((B, 1, kv, hd))
    nv = jnp.full((B, 1, kv, hd), 2.0)
    pages_k, pages_v = pc.write_token(pages_k, pages_v, table, lens, nk, nv)
    assert float(pages_k[2, 1, 0, 0]) == 1.0       # slot 0: table[0,1]=2, off 1
    assert float(pages_k[1, 2, 0, 0]) == 1.0       # slot 1: table[1,0]=1, off 2
    assert float(pages_v[2, 1, 0, 0]) == 2.0


def _check_allocator_conservation(lengths):
    alloc = pc.PageAllocator(n_pages=256, page_size=8, max_pages_per_seq=16)
    total = alloc.n_pages
    for slot, n in enumerate(lengths):
        alloc.alloc_for(slot, n)
    # no page handed out twice
    seen = [p for pages in alloc.owned.values() for p in pages]
    assert len(seen) == len(set(seen))
    assert len(seen) + len(alloc.free) == total
    for slot in range(len(lengths)):
        alloc.release(slot)
    assert len(alloc.free) == total
    assert alloc.utilization == 0.0


if HAVE_HYPOTHESIS:
    @given(st.lists(st.integers(min_value=1, max_value=100), min_size=1,
                    max_size=12))
    @settings(max_examples=40, deadline=None)
    def test_allocator_conservation(lengths):
        _check_allocator_conservation(lengths)
else:
    @pytest.mark.parametrize("lengths", [
        [1], [100], [8, 16, 3], [7] * 12, list(range(1, 13))])
    def test_allocator_conservation(lengths):
        _check_allocator_conservation(lengths)


def test_write_prompt_scatter_and_unmapped_drop():
    """write_prompt lands each position in its page; padding beyond the
    prompt length and unmapped (-1) table rows never touch the pool."""
    kv, hd, page, P = 2, 4, 4, 3
    pages_k = jnp.zeros((8, page, kv, hd))
    pages_v = jnp.zeros((8, page, kv, hd))
    row = jnp.asarray([5, 1, -1], jnp.int32)
    S = 12
    k = jnp.arange(1, S + 1, dtype=jnp.float32)[None, :, None, None] \
        * jnp.ones((1, S, kv, hd))
    pk, pv = pc.write_prompt(pages_k, pages_v, row, k, 2 * k,
                             jnp.asarray(6, jnp.int32))
    # positions 0..3 -> page 5, positions 4..5 -> page 1, rest dropped
    np.testing.assert_allclose(np.asarray(pk[5, :, 0, 0]), [1, 2, 3, 4])
    np.testing.assert_allclose(np.asarray(pk[1, :2, 0, 0]), [5, 6])
    assert float(jnp.abs(pk[1, 2:]).sum()) == 0.0      # beyond prompt_len
    untouched = [p for p in range(8) if p not in (1, 5)]
    for p in untouched:
        assert float(jnp.abs(pk[p]).sum()) == 0.0
    np.testing.assert_allclose(np.asarray(pv[5, :, 0, 0]), [2, 4, 6, 8])


def test_write_token_unmapped_row_is_dropped():
    """A freed slot (block-table row -1) must not corrupt the pool — its
    pages may already belong to another request."""
    kv, hd, page = 1, 2, 4
    pages_k = jnp.ones((4, page, kv, hd))
    pages_v = jnp.ones((4, page, kv, hd))
    table = jnp.asarray([[-1, -1]], jnp.int32)
    nk = jnp.full((1, 1, kv, hd), 9.0)
    pk, pv = pc.write_token(pages_k, pages_v, table, jnp.asarray([2]), nk, nk)
    np.testing.assert_allclose(np.asarray(pk), np.ones((4, page, kv, hd)))
    np.testing.assert_allclose(np.asarray(pv), np.ones((4, page, kv, hd)))


def test_allocator_fork_shares_full_pages_and_copies_tail():
    alloc = pc.PageAllocator(n_pages=16, page_size=4, max_pages_per_seq=8)
    src = alloc.alloc_for(0, 10)                   # 2 full pages + tail
    dst, tail_src, tail_dst = alloc.fork(0, 1, n_tokens=10)
    assert dst[:2] == src[:2]                      # full pages shared
    assert tail_src == src[2] and tail_dst == dst[2] and tail_dst != tail_src
    assert [alloc.refcount[p] for p in src] == [2, 2, 1]
    assert alloc.refcount[tail_dst] == 1
    assert alloc.pages_shared == 2
    assert alloc.logical_pages == 6                # 3 + 3 chains
    assert alloc.pages_in_use == 4                 # 3 + 1 physical
    assert alloc.unique_pages(0) == 1 and alloc.unique_pages(1) == 1
    # releasing the fork must not free pages the source still references
    alloc.release(1)
    assert all(alloc.refcount[p] == 1 for p in src)
    assert alloc.pages_in_use == 3
    alloc.release(0)
    assert alloc.pages_in_use == 0
    assert sorted(alloc.free) == list(range(16))
    assert all(c == 0 for c in alloc.refcount)


def test_allocator_fork_aligned_prefix_needs_no_copy():
    alloc = pc.PageAllocator(n_pages=8, page_size=4, max_pages_per_seq=4)
    src = alloc.alloc_for(0, 8)                    # exactly 2 full pages
    dst, tail_src, tail_dst = alloc.fork(0, 1, n_tokens=8)
    assert dst == src and tail_src == tail_dst     # pure sharing
    assert alloc.pages_in_use == 2
    assert alloc.fork_cost(8) == 0 and alloc.fork_cost(9) == 1


def test_allocator_cow_page_unshares_before_write():
    alloc = pc.PageAllocator(n_pages=8, page_size=4, max_pages_per_seq=4)
    src = alloc.alloc_for(0, 8)
    alloc.fork(0, 1, n_tokens=8)                   # both pages shared
    cow = alloc.cow_page(1, pos=4)                 # page idx 1
    assert cow is not None
    old, new = cow
    assert old == src[1] and alloc.owned[1][1] == new
    assert alloc.refcount[old] == 1 and alloc.refcount[new] == 1
    assert alloc.cow_page(1, pos=4) is None        # already private
    assert alloc.cow_page(0, pos=7) is None        # src side now unique too
    alloc.release(0)
    alloc.release(1)
    assert alloc.pages_in_use == 0
    assert sorted(alloc.free) == list(range(8))


def test_copy_page_device_op():
    pages = jnp.arange(2 * 4 * 3 * 1 * 2, dtype=jnp.float32
                       ).reshape(2, 4, 3, 1, 2)
    out = pc.copy_page(pages, 1, 3)
    np.testing.assert_allclose(np.asarray(out[:, 3]), np.asarray(pages[:, 1]))
    np.testing.assert_allclose(np.asarray(out[:, :3]),
                               np.asarray(pages[:, :3]))
    # src == dst must be a no-op (used when a fork has no partial tail)
    np.testing.assert_allclose(np.asarray(pc.copy_page(pages, 2, 2)),
                               np.asarray(pages))


def test_allocator_extend_and_exhaustion():
    alloc = pc.PageAllocator(n_pages=4, page_size=4, max_pages_per_seq=4)
    alloc.alloc_for(0, 4)                  # 1 page
    assert alloc.extend(0, 5) is not None  # crosses boundary -> new page
    assert alloc.extend(0, 6) is None      # still fits
    alloc.alloc_for(1, 8)                  # 2 more
    try:
        alloc.alloc_for(2, 4)
        assert False, "pool should be exhausted"
    except MemoryError:
        pass
