"""Calibrated-simulator invariants + paper Table III structural claims."""
import pytest

from repro.core.simulator import (METHODS,
                                  SimConfig,
                                  make_requests,
                                  simulate_cloud_only,
                                  simulate_pice)


@pytest.fixture(scope="module")
def saturated():
    cfg = SimConfig(cloud_model="llama3-70b", cloud_batch=20, rpm=30,
                    n_requests=300)
    out = {}
    for name, fn in METHODS.items():
        reqs = make_requests(cfg.n_requests, cfg.rpm, cfg.seed)
        out[name] = fn(cfg, reqs)
    return cfg, out


def test_all_requests_complete(saturated):
    _, res = saturated
    for name, r in res.items():
        assert r.completed == r.offered, name


def test_pice_throughput_band(saturated):
    """Paper claim: 1.5x-2x throughput over cloud-only at saturation."""
    _, res = saturated
    ratio = res["pice"].throughput_per_min / res["cloud_only"].throughput_per_min
    assert 1.3 <= ratio <= 2.5, f"PICE/cloud throughput ratio {ratio:.2f}"


def test_pice_latency_reduction(saturated):
    """Paper claim: up to 43% latency reduction (ours exceeds it)."""
    _, res = saturated
    cut = 1 - res["pice"].avg_latency_s / res["cloud_only"].avg_latency_s
    assert cut >= 0.38, f"latency cut {cut:.0%}"


def test_edge_only_is_worst(saturated):
    _, res = saturated
    assert res["edge_only"].throughput_per_min <= min(
        res["cloud_only"].throughput_per_min,
        res["pice"].throughput_per_min)
    assert res["edge_only"].avg_latency_s >= res["cloud_only"].avg_latency_s


def test_pice_offloads_cloud_tokens(saturated):
    _, res = saturated
    assert res["pice"].cloud_tokens < 0.6 * res["cloud_only"].cloud_tokens
    assert res["pice"].edge_tokens > 0


def test_small_cloud_model_regression_case():
    """Paper: with an 8B cloud model PICE ~ cloud-only (edge too slow to help)."""
    cfg = SimConfig(cloud_model="llama3-8b", cloud_batch=80,
                    edge_models=("qwen2.5-7b", "qwen2.5-1.5b"), rpm=120,
                    n_requests=300)
    c = simulate_cloud_only(cfg, make_requests(300, cfg.rpm, 0))
    p = simulate_pice(cfg, make_requests(300, cfg.rpm, 0))
    ratio = p.throughput_per_min / c.throughput_per_min
    assert 0.9 <= ratio <= 1.15


def test_dynamic_beats_static_scheduling():
    """Paper Fig. 6a: dynamic scheduling adds throughput over static."""
    base = dict(cloud_model="llama3-70b", cloud_batch=20, rpm=60,
                n_requests=300)
    dyn = simulate_pice(SimConfig(**base, dynamic=True),
                        make_requests(300, 60, 0))
    sta = simulate_pice(SimConfig(**base, dynamic=False),
                        make_requests(300, 60, 0))
    assert dyn.throughput_per_min >= sta.throughput_per_min * 1.05, \
        "dynamic scheduling should add throughput over static under load"
    assert dyn.avg_latency_s <= sta.avg_latency_s


def test_rpm_saturation_behavior():
    """Paper Fig. 12: below cloud capacity PICE ~ cloud-only; above it PICE
    keeps scaling while cloud-only saturates."""
    lo = SimConfig(cloud_model="llama3-70b", cloud_batch=20, rpm=8,
                   n_requests=200)
    hi = SimConfig(cloud_model="llama3-70b", cloud_batch=20, rpm=60,
                   n_requests=400)
    c_lo = simulate_cloud_only(lo, make_requests(200, 8, 1))
    p_lo = simulate_pice(lo, make_requests(200, 8, 1))
    assert abs(p_lo.throughput_per_min - c_lo.throughput_per_min) \
        / c_lo.throughput_per_min < 0.15
    c_hi = simulate_cloud_only(hi, make_requests(400, 60, 1))
    p_hi = simulate_pice(hi, make_requests(400, 60, 1))
    assert p_hi.throughput_per_min > 1.3 * c_hi.throughput_per_min


def test_bandwidth_insensitivity():
    """Paper Fig. 14: bandwidth has minimal impact (inference dominates)."""
    res = []
    for bw in (10.0, 100.0, 1000.0):
        cfg = SimConfig(cloud_model="llama3-70b", rpm=30, n_requests=200,
                        bandwidth_mbps=bw)
        res.append(simulate_pice(cfg, make_requests(200, 30, 2)))
    ths = [r.throughput_per_min for r in res]
    assert max(ths) - min(ths) < 0.1 * max(ths)
