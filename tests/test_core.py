"""Unit tests for the PICE core: scheduler Eq.(2), Algorithm 1/2, ensemble
Eq.(3), execution optimizer, metrics, profiler."""

import pytest

from repro.core import metrics as M
from repro.core.dispatch import MultiListQueue
from repro.core.ensemble import Candidate, confidence, select_best
from repro.core.exec_optimizer import merge_once, plan_expansion
from repro.core.profiler import (LatencyModel, RuntimeMonitor,
                                 cost_coefficient, fit_latency_model,
                                 paper_latency_model)
from repro.core.scheduler import (DynamicScheduler, EdgeModelInfo,
                                  lexicographic_select, ScheduleDecision)
from repro.core.selection import select_model
from repro.serving.network import NetworkModel
from repro.serving.requests import SketchTask


def _edge(name, rate, cap):
    return EdgeModelInfo(name=name, latency=LatencyModel(t0=0.5, rate=rate),
                         capability=cap)


def _sched(edges=None, n_dev=4):
    cloud = LatencyModel(t0=0.5, rate=20.0)
    edges = edges or [_edge("small", 25.0, 0.5), _edge("big", 10.0, 0.8)]
    return DynamicScheduler(cloud, edges, NetworkModel(), n_dev)


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def test_eq2_feasibility_monotone_in_sketch_len():
    s = _sched()
    e = s.edges["small"]
    lats = [s.e2e_latency(sk, 500, e, 4) for sk in (50, 100, 200, 400)]
    assert lats == sorted(lats), "longer sketches cannot reduce e2e latency"


def test_scheduler_falls_back_to_cloud_when_edge_too_slow():
    slow = [_edge("sloth", 0.5, 0.9)]
    s = _sched(slow)
    d = s.schedule(500)
    assert d.mode == "cloud_full"


def test_scheduler_progressive_when_feasible():
    s = _sched()
    d = s.schedule(500)
    assert d.mode == "progressive"
    assert 0 < d.sketch_tokens < 500
    assert d.est_latency_s <= s.cloud.f(500) + 1e-6   # Eq.(2) hard constraint


def test_scheduler_respects_capability_floor():
    s = _sched()
    d = s.schedule(500)
    e = s.edges[d.edge_model]
    assert d.sketch_tokens >= e.min_sketch_ratio * 500 - 1


def test_scheduler_queue_backpressure():
    s = _sched()
    d0 = s.schedule(500)
    s.monitor.queued_expected_tokens = 1e7     # enormous backlog
    d1 = s.schedule(500)
    assert d1.mode == "cloud_full", "backlogged edge must push work to cloud"
    assert d0.mode == "progressive"


def test_expand_prompt_splits_additively():
    """The fork path prefills edge_expand_prefix once and teacher-forces
    edge_expand_suffix per group: their concatenation must stay exactly the
    monolithic template (byte-level tokenizer makes encode additive)."""
    from repro.core import sketch as sketch_lib
    from repro.data import tokenizer as tok
    q, s, g = "why is the sky blue", "rayleigh. scattering", ["rayleigh", "blue"]
    prefix = sketch_lib.edge_expand_prefix(q, s)
    suffix = sketch_lib.edge_expand_suffix(g)
    assert prefix + suffix == sketch_lib.edge_expand_prompt(q, s, g)
    assert tok.encode(prefix) + tok.encode(suffix) == \
        tok.encode(prefix + suffix)


def test_memory_pressure_reflects_cow_sharing():
    """Physical occupancy drives Eq.(2)'s 1/(1-rho) inflation: the same
    logical demand served through COW prefix sharing must read as LESS
    pressure, while mostly-shared (hard-to-evict) occupancy reads as more
    pressure than all-unique occupancy at equal utilization."""
    mon = RuntimeMonitor()
    sched = _sched()
    sched.monitor = mon
    mon.update_memory(pages_used=90, pages_total=100, pages_logical=90)
    f_unshared = sched.memory_pressure_factor()
    # the same 90 logical pages, fanned out over shared prefixes
    mon.update_memory(pages_used=30, pages_total=100, pages_shared=20,
                      pages_logical=90)
    f_shared = sched.memory_pressure_factor()
    assert f_shared < f_unshared
    assert mon.kv_sharing_savings == pytest.approx(1.0 - 30 / 90)
    assert mon.kv_shared_fraction == pytest.approx(20 / 30)
    # equal utilization, but pinned (shared) pages shrink evictable headroom
    mon.update_memory(pages_used=60, pages_total=100, pages_shared=60,
                      pages_logical=120)
    f_pinned = sched.memory_pressure_factor()
    mon.update_memory(pages_used=60, pages_total=100, pages_logical=60)
    f_free = sched.memory_pressure_factor()
    assert f_pinned > f_free
    # no telemetry -> factor 1.0 (seed behavior)
    mon.update_memory(pages_used=0, pages_total=0)
    assert sched.memory_pressure_factor() == pytest.approx(1.0)


def test_network_jitter_never_undercuts_rtt():
    """jitter_frac >= 1 could return a delay below rtt_s (even negative)."""
    net = NetworkModel(jitter_frac=1.5)
    delays = [net.delay_s(200) for _ in range(300)]
    assert all(d >= net.rtt_s for d in delays)
    # jitter still actually varies the delay upward
    assert max(delays) > min(delays)
    # jitter-free path unchanged
    calm = NetworkModel()
    assert calm.delay_s(0) == pytest.approx(calm.rtt_s)


def test_lexicographic_order_respected():
    a = ScheduleDecision(mode="progressive",
                         metrics={"error": 0.1, "latency": 10.0})
    b = ScheduleDecision(mode="progressive",
                         metrics={"error": 0.5, "latency": 1.0})
    pick = lexicographic_select([a, b], ("error", "latency"))
    assert pick is a
    pick = lexicographic_select([a, b], ("latency", "error"))
    assert pick is b


# ---------------------------------------------------------------------------
# Algorithm 1: multi-list dispatch
# ---------------------------------------------------------------------------

def _task(l, rid=0):
    return SketchTask(req_id=rid, query="", sketch="", sentences=["a"],
                      expected_length=l, sketch_tokens=l // 3)


def test_multilist_buckets_and_longest_first():
    q = MultiListQueue(boundaries=(100, 200))
    for i, l in enumerate([50, 60, 70, 150, 250]):
        q.push(_task(l, i))
    assert len(q) == 5
    batch = q.pull_batch(8)
    assert [t.expected_length for t in batch] == [50, 60, 70], \
        "batch must come from the longest list (uniform short tasks)"
    assert len(q) == 2


def test_multilist_conservation():
    q = MultiListQueue()
    for i in range(20):
        q.push(_task(10 * (i + 1), i))
    seen = []
    while len(q):
        seen.extend(t.req_id for t in q.pull_batch(3))
    assert sorted(seen) == list(range(20))


# ---------------------------------------------------------------------------
# Algorithm 2: model selection
# ---------------------------------------------------------------------------

def test_selection_downgrades_when_over_budget():
    cloud = LatencyModel(t0=0.5, rate=20.0)
    cands = [_edge("s", 50.0, 0.4), _edge("m", 10.0, 0.6), _edge("l", 2.0, 0.9)]
    r = select_model("l", cands, expected_len=400, sketch_tokens=100,
                     cloud=cloud, queue_len=10, queue_max=8)
    assert r.action == "downgrade" and r.model in ("s", "m")


def test_selection_upgrades_only_when_queue_short():
    cloud = LatencyModel(t0=0.5, rate=20.0)
    cands = [_edge("s", 50.0, 0.4), _edge("m", 30.0, 0.6), _edge("l", 28.0, 0.9)]
    busy = select_model("s", cands, 400, 100, cloud, queue_len=10, queue_max=8)
    idle = select_model("s", cands, 400, 100, cloud, queue_len=0, queue_max=8)
    assert busy.action == "keep"
    assert idle.action == "upgrade" and idle.model == "l"


# ---------------------------------------------------------------------------
# Eq.(3) ensemble confidence
# ---------------------------------------------------------------------------

def test_confidence_prefers_sketch_coverage():
    sketch = "the system stores tokens. a network routes queries."
    good = Candidate(text="the system stores tokens and a network routes "
                          "queries at scale", mean_log2_prob=-2.0, n_tokens=14,
                     model="a")
    bad = Candidate(text="completely unrelated words here", mean_log2_prob=-2.0,
                    n_tokens=14, model="b")
    best, scores = select_best([good, bad], sketch)
    assert best is good and scores[0] > scores[1]


def test_confidence_perplexity_term():
    cands = [Candidate("same text", -1.0, 10, "a"),
             Candidate("same text", -8.0, 10, "b")]
    best, _ = select_best(cands, "same text")
    assert best.model == "a"


def test_confidence_bounded():
    c = Candidate("a b c", -3.0, 3, "m")
    v = confidence(c, "a b c", [c])
    assert 0.0 <= v <= 1.0 + 1e-9


# ---------------------------------------------------------------------------
# execution optimizer
# ---------------------------------------------------------------------------

def test_merge_once_pairs_longest_with_shortest():
    groups = [["aaaa bbbb cccc dddd"], ["a"], ["aa bb"], ["aa bb cc"]]
    merged = merge_once(groups)
    assert len(merged) == 2
    flat = sorted(s for g in merged for s in g)
    assert flat == sorted(s for g in groups for s in g)
    # the longest sentence must be paired with the shortest
    for g in merged:
        if "aaaa bbbb cccc dddd" in g:
            assert "a" in g


def test_plan_expansion_respects_budget():
    sents = [f"sentence number {i} with words" for i in range(8)]
    # infinite budget -> merges all the way down to 1 group
    plan = plan_expansion(sents, lambda p, t: 0.01 * t, latency_budget_s=1e9)
    assert plan.parallelism == 1
    # zero budget -> keeps maximum parallelism (no merging possible)
    plan = plan_expansion(sents, lambda p, t: 0.01 * t, latency_budget_s=0.0)
    assert plan.parallelism == len(sents)


def test_plan_expansion_preserves_sentences():
    sents = [f"s{i} word" for i in range(7)]
    plan = plan_expansion(sents, lambda p, t: 0.1, latency_budget_s=1.0,
                          max_parallelism=4)
    flat = sorted(s for g in plan.groups for s in g)
    assert flat == sorted(sents)
    assert plan.parallelism <= 4


# ---------------------------------------------------------------------------
# metrics / profiler
# ---------------------------------------------------------------------------

def test_rouge_bounds_and_identity():
    p, r, f1 = M.rouge_1("a b c", "a b c")
    assert p == r == f1 == 1.0
    p, r, f1 = M.rouge_1("a b c", "x y z")
    assert f1 == 0.0
    _, _, f = M.rouge_l("the cat sat", "the cat quietly sat")
    assert 0.0 < f <= 1.0


def test_latency_fit_recovers_rate():
    true = LatencyModel(t0=0.3, rate=50.0)
    samples = [(l, true.f(l)) for l in (8, 16, 32, 64, 128)]
    fit = fit_latency_model(samples)
    assert abs(fit.rate - 50.0) / 50.0 < 0.01
    assert abs(fit.t0 - 0.3) < 0.01


def test_cost_coefficient_paper_tables():
    cloud = paper_latency_model("llama3-70b", "cloud")
    edge = paper_latency_model("llama3-8b", "edge")
    c = cost_coefficient(cloud, edge)
    assert c > 1.0, "fp16 8B on Orin is slower than 70B on A100 per token"
