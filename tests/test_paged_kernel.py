"""Paged flash-decode kernel (kernels/paged_decode_attention) vs the
gather oracle: parity across page sizes, ragged lengths (including a
length-0 slot), unmapped tail pages, COW-forked block tables, and the
live-width trim + use_pallas wiring in models/attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_decode_attention import ops as pda_ops
from repro.kernels.paged_decode_attention import ref as pda_ref
from repro.models import attention as attn_lib
from repro.models.config import ModelConfig


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


def _pools(key, n_pages, page, Hkv, hd, dtype):
    k1, k2 = jax.random.split(key)
    return (jax.random.normal(k1, (n_pages, page, Hkv, hd), dtype),
            jax.random.normal(k2, (n_pages, page, Hkv, hd), dtype))


def _chained_table(lens, page, P, start=0):
    """Disjoint page chains covering each row's length; tail stays -1."""
    tbl = np.full((len(lens), P), -1, np.int64)
    nxt = start
    for b, ln in enumerate(lens):
        live = -(-int(ln) // page)
        tbl[b, :live] = np.arange(nxt, nxt + live)
        nxt += live
    return jnp.asarray(tbl, jnp.int32)


# ---------------------------------------------------------------------------
# kernel vs gather oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("page", [8, 32])
@pytest.mark.parametrize("B,Hq,Hkv,hd,P", [
    (3, 8, 2, 32, 6),
    (2, 4, 4, 64, 4),
    # the wide-head case adds compile wall time, not coverage, on CPU
    pytest.param(2, 16, 4, 128, 3, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_parity(page, B, Hq, Hkv, hd, P, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, hd), dtype)
    kp, vp = _pools(ks[1], B * P + 2, page, Hkv, hd, dtype)
    # ragged: always include a length-0 slot and a mid-page partial length
    lens = np.array(jax.random.randint(ks[2], (B,), 1, P * page + 1))
    lens[0] = 0
    lens[-1] = page + page // 2 if P > 1 else page // 2
    table = _chained_table(lens, page, P)
    lens = jnp.asarray(lens, jnp.int32)
    out = pda_ops.paged_decode_attention(q, kp, vp, table, lens)
    ref = pda_ref.paged_decode_attention_ref(q, kp, vp, table, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))
    assert not np.any(np.isnan(np.asarray(out, np.float32)))
    np.testing.assert_array_equal(np.asarray(out[0], np.float32), 0.0)


def test_paged_decode_unmapped_tail_pages():
    """Garbage in unmapped (-1) and past-length pages must not leak."""
    B, Hq, Hkv, hd, page, P = 2, 4, 2, 32, 8, 5
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    q = jax.random.normal(ks[0], (B, 1, Hq, hd))
    kp, vp = _pools(ks[1], B * P, page, Hkv, hd, jnp.float32)
    lens = jnp.array([12, 30], jnp.int32)
    table = _chained_table(np.asarray(lens), page, P)
    out1 = pda_ops.paged_decode_attention(q, kp, vp, table, lens)
    # poison every page no row reads through its chain, and the in-page
    # tail beyond each row's length
    used = set(int(p) for p in np.asarray(table).ravel() if p >= 0)
    kp2, vp2 = np.array(kp), np.array(vp)
    for pg in range(kp2.shape[0]):
        if pg not in used:
            kp2[pg], vp2[pg] = 999.0, -999.0
    kp2[1, 12 % page:], vp2[1, 12 % page:] = 999.0, -999.0   # row 0 tail
    out2 = pda_ops.paged_decode_attention(q, jnp.asarray(kp2),
                                          jnp.asarray(vp2), table, lens)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


def test_paged_decode_cow_forked_table():
    """Two slots whose tables share prefix pages (COW fan-out) must each
    read the shared pages correctly — parity vs the oracle AND vs an
    unshared copy of the same logical layout."""
    Hq, Hkv, hd, page, P = 8, 2, 32, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    q = jax.random.normal(ks[0], (2, 1, Hq, hd))
    kp, vp = _pools(ks[1], 12, page, Hkv, hd, jnp.float32)
    # rows share pages [0,1] (the prefix), then diverge on private tails
    table = jnp.asarray([[0, 1, 2, -1], [0, 1, 3, 4]], jnp.int32)
    lens = jnp.array([20, 28], jnp.int32)
    out = pda_ops.paged_decode_attention(q, kp, vp, table, lens)
    ref = pda_ref.paged_decode_attention_ref(q, kp, vp, table, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    # unshared equivalent: copy the shared prefix pages to fresh ids
    kp2 = kp.at[6].set(kp[0]).at[7].set(kp[1])
    vp2 = vp.at[6].set(vp[0]).at[7].set(vp[1])
    t2 = jnp.asarray([[0, 1, 2, -1], [6, 7, 3, 4]], jnp.int32)
    out2 = pda_ops.paged_decode_attention(q, kp2, vp2, t2, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=1e-6)


def test_paged_decode_trimmed_table_identical():
    """Reading through a live-trimmed table is exactly the full-width read
    (trimmed columns carry zero attention weight)."""
    B, Hq, Hkv, hd, page, P = 3, 4, 2, 32, 8, 6
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    q = jax.random.normal(ks[0], (B, 1, Hq, hd))
    kp, vp = _pools(ks[1], B * P, page, Hkv, hd, jnp.float32)
    lens_np = np.array([5, 16, 9])
    table = _chained_table(lens_np, page, P)
    lens = jnp.asarray(lens_np, jnp.int32)
    live = max(1, -(-int(lens_np.max()) // page))
    full = pda_ops.paged_decode_attention(q, kp, vp, table, lens)
    trim = pda_ops.paged_decode_attention(q, kp, vp, table[:, :live], lens)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(trim))
    # same for the oracle read path
    rfull = pda_ref.paged_decode_attention_ref(q, kp, vp, table, lens)
    rtrim = pda_ref.paged_decode_attention_ref(q, kp, vp, table[:, :live],
                                               lens)
    np.testing.assert_array_equal(np.asarray(rfull), np.asarray(rtrim))


# ---------------------------------------------------------------------------
# wiring: attention_decode_paged keyed on use_pallas
# ---------------------------------------------------------------------------

def _paged_attn_setup(use_pallas, seed=4):
    cfg = ModelConfig(n_layers=1, d_model=128, n_heads=4, n_kv_heads=2,
                      head_dim=32, d_ff=256, vocab_size=64,
                      dtype="float32", use_pallas=use_pallas)
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    params = attn_lib.init_attention(cfg, ks[0])
    return cfg, params, ks


@pytest.mark.parametrize("live_pages", [None, 4])
def test_attention_decode_paged_kernel_matches_oracle(live_pages):
    """cfg.use_pallas routes the paged decode read through the kernel;
    outputs match the gather oracle within the dense decode kernel's
    tolerance, at full and trimmed read widths."""
    B, page, P, n_pages = 2, 8, 6, 16
    cfg, params, ks = _paged_attn_setup(False)
    hd, Hkv = cfg.resolved_head_dim, cfg.n_kv_heads
    x = jax.random.normal(ks[1], (B, 1, cfg.d_model), jnp.float32)
    kp, vp = _pools(ks[2], n_pages, page, Hkv, hd, jnp.float32)
    lens_np = np.array([13, 25])
    table = _chained_table(lens_np, page, P, start=1)
    lens = jnp.asarray(lens_np, jnp.int32)

    out_ref, kr, vr, _, _ = attn_lib.attention_decode_paged(
        cfg, params, x, kp, vp, table, lens, live_pages=live_pages)
    out_pal, kk, vk, _, _ = attn_lib.attention_decode_paged(
        cfg.with_(use_pallas=True), params, x, kp, vp, table, lens,
        live_pages=live_pages)
    np.testing.assert_allclose(np.asarray(out_pal), np.asarray(out_ref),
                               rtol=2e-5, atol=2e-5)
    # both paths write the token identically
    np.testing.assert_array_equal(np.asarray(kr), np.asarray(kk))
    np.testing.assert_array_equal(np.asarray(vr), np.asarray(vk))


def test_attention_decode_paged_trim_bit_identical():
    """The default (oracle) read path must stay bit-identical under the
    live-width trim — this is what keeps the engine's dense<->paged
    equivalence suite exact."""
    B, page, P, n_pages = 2, 8, 8, 20
    cfg, params, ks = _paged_attn_setup(False, seed=5)
    hd, Hkv = cfg.resolved_head_dim, cfg.n_kv_heads
    x = jax.random.normal(ks[1], (B, 1, cfg.d_model), jnp.float32)
    kp, vp = _pools(ks[2], n_pages, page, Hkv, hd, jnp.float32)
    lens_np = np.array([9, 21])
    table = _chained_table(lens_np, page, P)
    lens = jnp.asarray(lens_np, jnp.int32)
    full, _, _, _, _ = attn_lib.attention_decode_paged(cfg, params, x, kp, vp,
                                                       table, lens)
    live = -(-int(lens_np.max() + 1) // page)
    trim, _, _, _, _ = attn_lib.attention_decode_paged(cfg, params, x, kp, vp,
                                                       table, lens,
                                                       live_pages=live)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(trim))


def test_validate_paged_alignment():
    cfg = ModelConfig()
    cfg.validate_paged(16, 256)
    with pytest.raises(AssertionError):
        cfg.validate_paged(24, 100)          # max_len not page-aligned
    with pytest.raises(AssertionError):
        cfg.with_(use_pallas=True).validate_paged(12, 240)  # sublane align
    cfg.with_(use_pallas=True).validate_paged(16, 256)
