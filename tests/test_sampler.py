"""Sampler properties: greedy / top-k / top-p (hypothesis when available,
fixed examples otherwise, per the PR 1 convention)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.serving.sampler import SamplerConfig, sample, token_logprob

V = 11


def _logits(seed: int, B: int = 3, vocab: int = V) -> jax.Array:
    return jax.random.normal(jax.random.PRNGKey(seed), (B, vocab)) * 3.0


def test_greedy_is_argmax():
    logits = _logits(0)
    toks = sample(logits, jax.random.PRNGKey(1), SamplerConfig())
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, axis=-1)))


def test_top_k_ge_vocab_does_not_crash():
    """top_k >= vocab used to index sorted[:, -top_k] out of bounds; it must
    behave as plain temperature sampling."""
    logits = _logits(2)
    for k in (V, V + 1, 1000):
        cfg = SamplerConfig(temperature=1.0, top_k=k)
        toks = np.asarray(sample(logits, jax.random.PRNGKey(3), cfg))
        assert ((0 <= toks) & (toks < V)).all()
    # and it equals the untruncated distribution draw under the same key
    full = sample(logits, jax.random.PRNGKey(3),
                  SamplerConfig(temperature=1.0))
    capped = sample(logits, jax.random.PRNGKey(3),
                    SamplerConfig(temperature=1.0, top_k=1000))
    np.testing.assert_array_equal(np.asarray(full), np.asarray(capped))


def test_top_k_one_is_greedy():
    logits = _logits(4)
    cfg = SamplerConfig(temperature=1.0, top_k=1)
    toks = sample(logits, jax.random.PRNGKey(5), cfg)
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, axis=-1)))


def test_tiny_top_p_is_greedy():
    logits = _logits(6)
    cfg = SamplerConfig(temperature=1.0, top_p=1e-6)
    toks = sample(logits, jax.random.PRNGKey(7), cfg)
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, axis=-1)))


def test_token_logprob_is_log_softmax_entry():
    logits = _logits(8)
    toks = jnp.asarray([0, 4, V - 1], jnp.int32)
    lps = np.asarray(token_logprob(logits, toks))
    ref = np.asarray(jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1))
    np.testing.assert_allclose(lps, ref[np.arange(3), np.asarray(toks)],
                               rtol=1e-6)
    assert (lps <= 0.0).all()


def _check_topk_support(k: int, seed: int):
    """Sampled tokens must come from the top-k set (ties included)."""
    logits = _logits(seed)
    cfg = SamplerConfig(temperature=0.7, top_k=k)
    toks = np.asarray(sample(logits, jax.random.PRNGKey(seed + 1), cfg))
    arr = np.asarray(logits)
    kth = np.sort(arr, axis=-1)[:, -min(k, V)]
    for b, t in enumerate(toks):
        assert arr[b, t] >= kth[b]


def _check_topp_support(p: float, seed: int):
    """Sampled tokens must survive the nucleus cutoff."""
    logits = np.asarray(_logits(seed))
    cfg = SamplerConfig(temperature=1.0, top_p=p)
    toks = np.asarray(sample(jnp.asarray(logits), jax.random.PRNGKey(seed),
                             cfg))
    for b, t in enumerate(toks):
        srt = np.sort(logits[b])[::-1]
        probs = np.exp(srt - srt.max())
        probs /= probs.sum()
        cutoff = srt[min(int((np.cumsum(probs) < p).sum()), V - 1)]
        assert logits[b, t] >= cutoff


def _nucleus_size(p: float, logits: np.ndarray) -> int:
    """Smallest k whose top-k cumulative mass reaches p."""
    srt = np.sort(logits)[::-1]
    probs = np.exp(srt - srt.max())
    probs /= probs.sum()
    return int((np.cumsum(probs) < p).sum()) + 1


def _check_topp_tied(p: float, n_tied: int, seed: int):
    """A many-way tie AT the nucleus boundary must not widen the nucleus:
    the value-based cutoff (`logits < cutoff`) kept every tied token —
    with an all-tied vocab that degenerated to full-vocab sampling under
    any top_p. Exactly the first k ranked tokens may be drawn."""
    rng = np.random.default_rng(seed)
    logits = np.full((1, V), 1.0, np.float32)
    untied = rng.permutation(V)[:V - n_tied]
    logits[0, untied] += rng.uniform(0.5, 3.0, len(untied)).astype(np.float32)
    k = _nucleus_size(p, logits[0])
    cfg = SamplerConfig(temperature=1.0, top_p=p)
    # the kept set under rank masking: the k highest-ranked tokens (ties
    # broken deterministically); every draw must land at or above the k-th
    # sorted VALUE, and across many draws the nucleus must hold exactly k
    # distinct tokens, not k + (extra tied copies)
    seen = set()
    for i in range(64):
        t = int(np.asarray(sample(jnp.asarray(logits),
                                  jax.random.PRNGKey(seed * 131 + i),
                                  cfg))[0])
        seen.add(t)
        assert logits[0, t] >= np.sort(logits[0])[::-1][k - 1]
    assert len(seen) <= k, \
        f"nucleus widened by boundary ties: {len(seen)} tokens drawn, k={k}"


def test_topp_all_tied_is_not_full_vocab():
    """The degenerate case of the old cutoff: a uniform vocab made every
    token 'tied with the boundary' so top_p never truncated anything."""
    logits = jnp.zeros((2, V), jnp.float32)
    cfg = SamplerConfig(temperature=1.0, top_p=0.3)
    k = _nucleus_size(0.3, np.zeros(V))          # ceil(0.3 * V) ranks
    seen = set()
    for i in range(128):
        toks = np.asarray(sample(logits, jax.random.PRNGKey(i), cfg))
        seen.update(toks.tolist())
    assert len(seen) <= k, \
        f"uniform logits: drew {len(seen)} distinct tokens, nucleus is {k}"


def test_topp_untied_unchanged_by_rank_masking():
    """With no boundary ties the rank nucleus IS the value nucleus: the fix
    must not change which tokens survive for generic logits."""
    for seed in range(8):
        logits = np.asarray(_logits(seed, B=1))
        k = _nucleus_size(0.7, logits[0])
        masked = logits[0] >= np.sort(logits[0])[::-1][k - 1]
        for i in range(32):
            t = int(np.asarray(sample(
                jnp.asarray(logits), jax.random.PRNGKey(seed * 977 + i),
                SamplerConfig(temperature=1.0, top_p=0.7)))[0])
            assert masked[t]


def test_topp_ties_below_boundary_survive():
    """Ties strictly INSIDE the nucleus are untouched: rank masking only
    trims at the boundary."""
    logits = np.array([[5.0, 5.0, -10.0, -10.0, -10.0, -10.0, -10.0,
                        -10.0, -10.0, -10.0, -10.0]], np.float32)
    cfg = SamplerConfig(temperature=1.0, top_p=0.9)
    seen = set()
    for i in range(64):
        seen.add(int(np.asarray(sample(jnp.asarray(logits),
                                       jax.random.PRNGKey(i), cfg))[0]))
    assert seen == {0, 1}


if HAVE_HYPOTHESIS:
    @given(st.integers(min_value=1, max_value=2 * V),
           st.integers(min_value=0, max_value=50))
    @settings(max_examples=25, deadline=None)
    def test_topk_support(k, seed):
        _check_topk_support(k, seed)

    @given(st.floats(min_value=0.05, max_value=0.999),
           st.integers(min_value=0, max_value=50))
    @settings(max_examples=25, deadline=None)
    def test_topp_support(p, seed):
        _check_topp_support(p, seed)

    @given(st.floats(min_value=0.1, max_value=0.95),
           st.integers(min_value=2, max_value=V),
           st.integers(min_value=0, max_value=50))
    @settings(max_examples=25, deadline=None)
    def test_topp_tied(p, n_tied, seed):
        _check_topp_tied(p, n_tied, seed)
else:
    @pytest.mark.parametrize("k,seed", [(1, 0), (3, 7), (V, 11), (2 * V, 13)])
    def test_topk_support(k, seed):
        _check_topk_support(k, seed)

    @pytest.mark.parametrize("p,seed", [(0.1, 0), (0.5, 7), (0.9, 11),
                                        (0.999, 13)])
    def test_topp_support(p, seed):
        _check_topp_support(p, seed)

    @pytest.mark.parametrize("p,n_tied,seed", [(0.3, V, 0), (0.5, 4, 7),
                                               (0.9, 2, 11), (0.2, 8, 13)])
    def test_topp_tied(p, n_tied, seed):
        _check_topp_tied(p, n_tied, seed)
