"""Sampler properties: greedy / top-k / top-p (hypothesis when available,
fixed examples otherwise, per the PR 1 convention)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.serving.sampler import SamplerConfig, sample, token_logprob

V = 11


def _logits(seed: int, B: int = 3, vocab: int = V) -> jax.Array:
    return jax.random.normal(jax.random.PRNGKey(seed), (B, vocab)) * 3.0


def test_greedy_is_argmax():
    logits = _logits(0)
    toks = sample(logits, jax.random.PRNGKey(1), SamplerConfig())
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, axis=-1)))


def test_top_k_ge_vocab_does_not_crash():
    """top_k >= vocab used to index sorted[:, -top_k] out of bounds; it must
    behave as plain temperature sampling."""
    logits = _logits(2)
    for k in (V, V + 1, 1000):
        cfg = SamplerConfig(temperature=1.0, top_k=k)
        toks = np.asarray(sample(logits, jax.random.PRNGKey(3), cfg))
        assert ((0 <= toks) & (toks < V)).all()
    # and it equals the untruncated distribution draw under the same key
    full = sample(logits, jax.random.PRNGKey(3),
                  SamplerConfig(temperature=1.0))
    capped = sample(logits, jax.random.PRNGKey(3),
                    SamplerConfig(temperature=1.0, top_k=1000))
    np.testing.assert_array_equal(np.asarray(full), np.asarray(capped))


def test_top_k_one_is_greedy():
    logits = _logits(4)
    cfg = SamplerConfig(temperature=1.0, top_k=1)
    toks = sample(logits, jax.random.PRNGKey(5), cfg)
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, axis=-1)))


def test_tiny_top_p_is_greedy():
    logits = _logits(6)
    cfg = SamplerConfig(temperature=1.0, top_p=1e-6)
    toks = sample(logits, jax.random.PRNGKey(7), cfg)
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, axis=-1)))


def test_token_logprob_is_log_softmax_entry():
    logits = _logits(8)
    toks = jnp.asarray([0, 4, V - 1], jnp.int32)
    lps = np.asarray(token_logprob(logits, toks))
    ref = np.asarray(jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1))
    np.testing.assert_allclose(lps, ref[np.arange(3), np.asarray(toks)],
                               rtol=1e-6)
    assert (lps <= 0.0).all()


def _check_topk_support(k: int, seed: int):
    """Sampled tokens must come from the top-k set (ties included)."""
    logits = _logits(seed)
    cfg = SamplerConfig(temperature=0.7, top_k=k)
    toks = np.asarray(sample(logits, jax.random.PRNGKey(seed + 1), cfg))
    arr = np.asarray(logits)
    kth = np.sort(arr, axis=-1)[:, -min(k, V)]
    for b, t in enumerate(toks):
        assert arr[b, t] >= kth[b]


def _check_topp_support(p: float, seed: int):
    """Sampled tokens must survive the nucleus cutoff."""
    logits = np.asarray(_logits(seed))
    cfg = SamplerConfig(temperature=1.0, top_p=p)
    toks = np.asarray(sample(jnp.asarray(logits), jax.random.PRNGKey(seed),
                             cfg))
    for b, t in enumerate(toks):
        srt = np.sort(logits[b])[::-1]
        probs = np.exp(srt - srt.max())
        probs /= probs.sum()
        cutoff = srt[min(int((np.cumsum(probs) < p).sum()), V - 1)]
        assert logits[b, t] >= cutoff


if HAVE_HYPOTHESIS:
    @given(st.integers(min_value=1, max_value=2 * V),
           st.integers(min_value=0, max_value=50))
    @settings(max_examples=25, deadline=None)
    def test_topk_support(k, seed):
        _check_topk_support(k, seed)

    @given(st.floats(min_value=0.05, max_value=0.999),
           st.integers(min_value=0, max_value=50))
    @settings(max_examples=25, deadline=None)
    def test_topp_support(p, seed):
        _check_topp_support(p, seed)
else:
    @pytest.mark.parametrize("k,seed", [(1, 0), (3, 7), (V, 11), (2 * V, 13)])
    def test_topk_support(k, seed):
        _check_topk_support(k, seed)

    @pytest.mark.parametrize("p,seed", [(0.1, 0), (0.5, 7), (0.9, 11),
                                        (0.999, 13)])
    def test_topp_support(p, seed):
        _check_topp_support(p, seed)
