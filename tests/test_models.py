"""Per-architecture smoke tests (reduced variants): one forward + one train
step on CPU, asserting output shapes and no NaNs; plus decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ALIASES, all_configs
from repro.launch import steps as steps_lib
from repro.models import transformer
from repro.training import optimizer as opt_lib

CONFIGS = all_configs()

# init_params costs seconds per arch on CPU; the three per-arch test families
# use shape-identical reduced configs, so share one init per (arch, overrides
# that change param shapes — here: none do).
_PARAMS_CACHE = {}


def _params_for(arch, r, key):
    if arch not in _PARAMS_CACHE:
        _PARAMS_CACHE[arch] = transformer.init_params(r, key)
    return _PARAMS_CACHE[arch]


def _batch_for(r, key, B=2, S=32):
    toks = jax.random.randint(key, (B, S), 0, r.vocab_size)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1)}
    if r.family == "encdec":
        batch["enc_frames"] = jax.random.normal(
            key, (B, r.encoder.n_ctx, r.encoder.d_model), jnp.bfloat16)
    if r.family == "vlm":
        batch["prefix_embeds"] = jax.random.normal(
            key, (B, r.n_prefix_tokens, r.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", sorted(ALIASES))
def test_arch_forward_shapes_no_nan(arch, rng_key):
    r = CONFIGS[arch].reduced(remat=False)
    params = _params_for(arch, r, rng_key)
    batch = _batch_for(r, rng_key)
    logits, aux = jax.jit(lambda p, b: transformer.forward(
        r, p, b["tokens"],
        prefix_embeds=b.get("prefix_embeds"),
        enc_frames=b.get("enc_frames")))(params, batch)
    B, S = batch["tokens"].shape
    extra = r.n_prefix_tokens if r.family == "vlm" else 0
    assert logits.shape == (B, S + extra, r.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
    assert np.isfinite(float(aux))


# One representative per family in tier-1 (train-step compiles are the most
# expensive thing in this file); the remaining archs run under -m slow.
_TRAIN_FAMILY_REPS = {"qwen2-1.5b", "mixtral-8x7b", "xlstm-1.3b",
                      "zamba2-2.7b", "whisper-tiny", "internvl2-2b"}


@pytest.mark.parametrize("arch", [
    a if a in _TRAIN_FAMILY_REPS else pytest.param(a, marks=pytest.mark.slow)
    for a in sorted(ALIASES)])
def test_arch_train_step(arch, rng_key):
    r = CONFIGS[arch].reduced(remat=False)
    params = _params_for(arch, r, rng_key)
    opt_state = opt_lib.init_opt_state(params)
    step = jax.jit(steps_lib.make_train_step(r, opt_lib.AdamWConfig(lr=1e-3)))
    batch = _batch_for(r, rng_key, B=2, S=16)
    params2, opt_state2, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.sum(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), params, params2))
    assert delta > 0
    assert int(opt_state2.step) == 1


@pytest.mark.parametrize("arch", ["qwen3-8b", "mixtral-8x7b", "xlstm-1.3b",
                                  "zamba2-2.7b", "whisper-tiny",
                                  "internvl2-2b", "qwen2-1.5b"])
def test_decode_matches_forward(arch, rng_key):
    """Step-by-step decode with cache == teacher-forced forward (f32)."""
    over = dict(remat=False, dtype="float32")
    if CONFIGS[arch].is_moe:
        over["capacity_factor"] = 8.0        # no token dropping
    r = CONFIGS[arch].reduced(**over)
    params = _params_for(arch, r, rng_key)
    B, S0, N, MAX = 2, 8, 5, 64
    toks = jax.random.randint(rng_key, (B, S0 + N), 0, r.vocab_size)
    kw = {}
    if r.family == "encdec":
        kw["enc_frames"] = jax.random.normal(
            rng_key, (B, r.encoder.n_ctx, r.encoder.d_model), jnp.float32)
    if r.family == "vlm":
        kw["prefix_embeds"] = jax.random.normal(
            rng_key, (B, r.n_prefix_tokens, r.d_model), jnp.float32)
    cache = transformer.init_cache(r, B, MAX)
    logits, cache = jax.jit(
        lambda p, t, c: transformer.prefill(r, p, t, c, **kw))(
        params, toks[:, :S0], cache)
    decode = jax.jit(lambda p, t, c: transformer.decode_step(r, p, t, c))
    outs = [logits]
    for i in range(N):
        logits, cache = decode(params, toks[:, S0 + i:S0 + i + 1], cache)
        outs.append(logits)
    dec = jnp.stack(outs[:-1], 1)
    fw, _ = transformer.forward(r, params, toks, **kw)
    extra = r.n_prefix_tokens if r.family == "vlm" else 0
    ref = fw[:, extra + S0 - 1: extra + S0 + N - 1]
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)


def test_sliding_window_cache_ring_buffer(rng_key):
    """Windowed decode must equal full-cache decode restricted to the window."""
    r = CONFIGS["mixtral-8x7b"].reduced(remat=False, dtype="float32",
                                        sliding_window=8, capacity_factor=8.0)
    params = _params_for("mixtral-8x7b", r, rng_key)
    B, S0, N = 1, 12, 8              # crosses the window boundary
    toks = jax.random.randint(rng_key, (B, S0 + N), 0, r.vocab_size)
    cache = transformer.init_cache(r, B, 64)
    logits, cache = jax.jit(
        lambda p, t, c: transformer.prefill(r, p, t, c))(
        params, toks[:, :S0], cache)
    decode = jax.jit(lambda p, t, c: transformer.decode_step(r, p, t, c))
    outs = [logits]
    for i in range(N):
        logits, cache = decode(params, toks[:, S0 + i:S0 + i + 1], cache)
        outs.append(logits)
    dec = jnp.stack(outs[:-1], 1)
    fw, _ = transformer.forward(r, params, toks)
    ref = fw[:, S0 - 1: S0 + N - 1]
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref), rtol=5e-4,
                               atol=5e-4)


def test_variable_prompt_lengths(rng_key):
    """Right-padded prefill must match per-request unpadded prefill."""
    r = CONFIGS["qwen2-1.5b"].reduced(remat=False, dtype="float32")
    params = _params_for("qwen2-1.5b", r, rng_key)
    toks = jax.random.randint(rng_key, (2, 12), 0, r.vocab_size)
    lens = jnp.array([7, 12])
    cache = transformer.init_cache(r, 2, 32)
    padded = toks.at[0, 7:].set(0)
    logits, cache2 = transformer.prefill(r, params, padded, cache,
                                         prompt_lengths=lens)
    # reference: prefill request 0 alone at its true length
    cache1 = transformer.init_cache(r, 1, 32)
    ref_logits, _ = transformer.prefill(r, params, toks[:1, :7], cache1)
    np.testing.assert_allclose(np.asarray(logits[0]), np.asarray(ref_logits[0]),
                               rtol=2e-4, atol=2e-4)
    assert int(cache2["lengths"][0]) == 7 and int(cache2["lengths"][1]) == 12


def test_param_counts_match_assignment():
    """Full-size configs should land near their nameplate parameter counts."""
    expect = {"qwen3-8b": (7, 10), "mixtral-8x7b": (40, 50),
              "qwen3-moe-30b-a3b": (27, 33), "granite-3-8b": (7, 10),
              "minitron-8b": (7, 11), "qwen2-1.5b": (1.2, 2.2)}
    for arch, (lo, hi) in expect.items():
        b = CONFIGS[arch].param_count() / 1e9
        assert lo <= b <= hi, f"{arch}: {b:.2f}B outside [{lo},{hi}]"
