"""Regression tests for the repro.analysis static checkers.

Bad fixtures under tests/fixtures/analysis/bad/ carry `# expect: CODE[,CODE]`
markers on the exact line each violation must be reported at; the tests
assert the reported (file, line, code) set equals the marker set, per pass.
The good fixture tree and the live src/repro tree must both be clean under
--strict.
"""
import json
import re
from pathlib import Path

import pytest

from repro.analysis import PASSES, package_root, run_all, rules
from repro.analysis.__main__ import main as analysis_main
from repro.analysis.common import SourceFile
from repro.analysis.rules import SUBLANE_MULTIPLE, parse_pragmas

FIXTURES = Path(__file__).parent / "fixtures" / "analysis"
BAD = FIXTURES / "bad"
GOOD = FIXTURES / "good"

_EXPECT_RE = re.compile(r"#\s*expect:\s*([A-Z0-9,]+)")


def _expected(root: Path, code_prefix: str):
    """(rel_file, line, code) triples from `# expect:` markers, filtered to
    one pass's code family (RA1, RA2, ...)."""
    out = set()
    for p in sorted(root.rglob("*.py")):
        rel = str(p.relative_to(root))
        for i, line in enumerate(p.read_text().splitlines(), 1):
            m = _EXPECT_RE.search(line)
            if not m:
                continue
            for code in m.group(1).split(","):
                if code.startswith(code_prefix):
                    out.add((rel, i, code))
    return out


@pytest.mark.parametrize("pass_name,prefix", [
    ("host-sync", "RA1"),
    ("recompile", "RA2"),
    ("donation", "RA3"),
    ("pallas-spec", "RA4"),
    ("exceptions", "RA5"),
    ("async-blocking", "RA6"),
])
def test_bad_fixtures_exact_codes_and_lines(pass_name, prefix):
    found = {(v.file, v.line, v.code)
             for v in PASSES[pass_name](BAD) if not v.waived}
    assert found == _expected(BAD, prefix), (
        f"{pass_name}: reported violations do not match fixture markers")


def test_bad_fixture_waiver_is_counted_not_reported():
    # host_sync_bad.waived_step carries a pragma'd float() sync
    waived = [v for v in PASSES["host-sync"](BAD) if v.waived]
    assert len(waived) == 1
    assert waived[0].code == "RA101"
    assert "waiver" in waived[0].waive_reason


def test_good_fixtures_are_clean():
    violations = run_all(GOOD)
    unwaived = [v for v in violations if not v.waived]
    assert unwaived == [], [v.render() for v in unwaived]
    # the one deliberate waiver in host_sync_good must carry its reason
    assert all(v.waive_reason for v in violations if v.waived)


def test_live_tree_passes_strict(tmp_path):
    report = tmp_path / "analysis_report.json"
    rc = analysis_main(["--strict", "--report", str(report)])
    assert rc == 0, "src/repro must stay clean under --strict"
    data = json.loads(report.read_text())
    assert data["ok"]
    assert data["counts"]["active"] == 0
    assert data["counts"]["waived_without_reason"] == 0


def test_strict_cli_fails_on_bad_fixtures(tmp_path):
    rc = analysis_main(["--strict", "--root", str(BAD),
                        "--report", str(tmp_path / "r.json")])
    assert rc == 1


def test_pragma_parsing():
    src = (
        "x = 1\n"
        "y = float(z)  # repro-analysis: disable=RA101 reason=because\n"
        "# repro-analysis: disable=RA102,RA103\n"
        "q = np.asarray(z)\n"
    )
    pragmas = parse_pragmas(src)
    assert pragmas[2] == ({"RA101"}, "because")
    # a standalone comment waives the following line; no reason given
    assert pragmas[4] == ({"RA102", "RA103"}, None)


def test_sublane_constant_shared_with_validate_paged():
    from repro.models.config import ModelConfig
    assert SUBLANE_MULTIPLE == 8
    cfg = ModelConfig(name="tiny", family="dense", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=2, use_pallas=True)
    with pytest.raises(AssertionError):
        cfg.validate_paged(SUBLANE_MULTIPLE + 4, 240)   # 12: not sublane-aligned
    cfg.validate_paged(SUBLANE_MULTIPLE * 2, 256)


def test_engine_harvest_is_the_only_unwaived_device_get():
    # the allowlist pins the one-readback-per-step contract to _harvest
    assert rules.HOST_SYNC_ALLOWLIST == {("serving/engine.py", "_harvest")}
    engine = package_root() / "serving" / "engine.py"
    sf = SourceFile.load(engine, package_root())
    from repro.analysis import host_sync
    unwaived = [v for v in host_sync.check_file(sf) if not v.waived]
    assert unwaived == [], [v.render() for v in unwaived]
