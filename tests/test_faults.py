"""Fault injection, cancellation, retry/backoff, and graceful degradation.

Covers the serving fault model (docs/serving.md "Fault model and degradation
ladder"): `InferenceEngine.cancel`/`abort_all` invariants (survivor streams
bit-identical, no leaked pages or host snapshots), deadline-driven drains,
`NetworkModel.transfer_with_retry` accounting, swap-loss degradation to
evict-and-replay, queue shedding, and the seeded `FaultInjector` hooks.
Property-based chaos sequences run under hypothesis when available.
"""
import time

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core.dispatch import MultiListQueue
from repro.core.profiler import LatencyModel, RuntimeMonitor
from repro.core.progressive import PICEConfig, PICEPipeline
from repro.core.scheduler import DynamicScheduler, EdgeModelInfo
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.serving.engine import InferenceEngine
from repro.serving.faults import EngineCrash, FaultInjector, FaultPlan
from repro.serving.network import NetworkModel
from repro.serving.requests import SketchTask

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                   max_seq_len=512, dtype="float32", remat=False)

PROMPTS = [[7, 8, 9, 10], [20, 21, 22], [30, 31, 32, 33, 34]]


@pytest.fixture(scope="module")
def params():
    return transformer.init_params(TINY, jax.random.PRNGKey(0))


def _engine(params, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_len", 128)
    kw.setdefault("kv_backend", "paged")
    kw.setdefault("page_size", 8)
    return InferenceEngine(TINY, params, **kw)


def _assert_drained(eng):
    """No leaked pages, snapshots, or queued work after a run."""
    assert not any(s.active for s in eng.slots)
    assert not eng._resume_queue
    assert eng.alloc.pages_in_use == 0
    assert len(eng.alloc.free) == eng.n_pages
    assert not eng.alloc.hosted
    assert all(c == 0 for c in eng.alloc.refcount)


# ---------------------------------------------------------------------------
# cancellation
# ---------------------------------------------------------------------------

def test_cancel_mid_decode_survivors_bit_identical(params):
    """Cancelling one request mid-decode must leave the other requests'
    greedy streams bitwise equal to the fault-free run — per-row attention
    is independent, decode writes are active-masked, and the PRNG key
    advances per step regardless of active rows."""
    baseline = _engine(params).generate(PROMPTS, max_new=16)

    eng = _engine(params)
    steps = []

    def hook(e):
        steps.append(1)
        if len(steps) == 6:          # mid-decode for every admitted request
            assert e.cancel(1)
    eng.step_hook = hook
    out = eng.generate(PROMPTS, max_new=16)

    assert eng.cancels == 1
    assert len(out[1][0]) < 16, "cancelled request must return a partial"
    for i in (0, 2):
        assert out[i][0] == baseline[i][0]
        np.testing.assert_array_equal(out[i][1], baseline[i][1])
    _assert_drained(eng)


def test_cancel_prunes_pending_decode_commit(params):
    """A cancelled slot must vanish from the deferred-harvest commit list:
    a request admitted into the freed slot before the next harvest would
    otherwise absorb the cancelled request's in-flight token."""
    eng = _engine(params)
    eng.add_request(0, [5, 6, 7], max_new=8)
    for _ in range(3):
        eng.step()
    if eng._pending_decode is not None:
        slot = next(i for i, s in enumerate(eng.slots) if s.req_id == 0)
        eng.cancel(0)
        commits, _, _ = eng._pending_decode
        assert slot not in commits
    else:
        eng.cancel(0)
    eng._harvest()
    _assert_drained(eng)


def test_cancel_drops_hosted_snapshot(params):
    """Cancelling a demoted (host-tier) request must drop its snapshot."""
    eng = _engine(params, host_swap=True, max_len=64)
    eng.add_request(0, [5, 6, 7, 8, 9, 10], max_new=8)
    for _ in range(3):
        eng.step()
    eng._harvest()
    assert eng._evict_victim(protect=-1)
    assert eng._resume_queue and eng._resume_queue[0].swap is not None
    assert 0 in eng.alloc.hosted
    assert eng.cancel(0)
    assert not eng._resume_queue
    _assert_drained(eng)


def test_cancel_unknown_request_is_noop(params):
    eng = _engine(params)
    assert not eng.cancel(12345)
    assert eng.cancels == 0


def test_abort_all_scrubs_engine(params):
    eng = _engine(params)
    for i, p in enumerate(PROMPTS):
        eng.add_request(i, p, max_new=32)
    for _ in range(4):
        eng.step()
    n = eng.abort_all()
    assert n == len(PROMPTS)
    assert eng._pending_decode is None
    _assert_drained(eng)


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------

def test_deadline_cancels_and_returns_partials(params):
    eng = _engine(params)
    out = eng.generate(PROMPTS, max_new=200,
                       deadline_s=time.perf_counter() + 0.05)
    assert len(out) == len(PROMPTS)
    assert all(len(t) < 200 for t, _ in out), "deadline must cut decode short"
    assert eng.deadline_cancels > 0
    _assert_drained(eng)


def test_no_deadline_matches_seed_behavior(params):
    """deadline_s=None takes the exact seed path (no drain, full output)."""
    eng = _engine(params)
    out = eng.generate(PROMPTS, max_new=12)
    assert all(len(t) == 12 for t, _ in out)
    assert eng.deadline_cancels == 0


# ---------------------------------------------------------------------------
# transfer retry/backoff
# ---------------------------------------------------------------------------

def test_transfer_with_retry_clean_matches_transfer_s():
    net = NetworkModel()
    r = net.transfer_with_retry(1000.0)
    assert r.ok and r.attempts == 1 and r.failure == ""
    assert r.latency_s == pytest.approx(net.transfer_s(1000.0))
    assert net.transfers == 1 and net.retries == 0


def test_transfer_with_retry_recovers_after_losses():
    verdicts = iter([("loss", 0.0), ("timeout", 0.25), None])
    net = NetworkModel(fault_hook=lambda n: next(verdicts))
    r = net.transfer_with_retry(1000.0, max_attempts=4, base_backoff_s=0.05)
    assert r.ok and r.attempts == 3
    # one RTT (loss) + the stall (timeout) + two backoffs + the clean pass
    assert r.latency_s > net.rtt_s + 0.25 + net.transfer_s(1000.0)
    assert net.retries == 2 and net.transfer_failures == 0


def test_transfer_with_retry_exhausts_and_reports():
    net = NetworkModel(fault_hook=lambda n: ("loss", 0.0))
    r = net.transfer_with_retry(1000.0, max_attempts=3)
    assert not r.ok and r.attempts == 3 and r.failure == "loss"
    assert net.transfer_failures == 1 and net.retries == 2


def test_transfer_backoff_grows_and_caps():
    """Backoff between attempts is base*2^k capped, jittered [0.5, 1.5)."""
    net = NetworkModel(fault_hook=lambda n: ("loss", 0.0))
    r = net.transfer_with_retry(0.0, max_attempts=5, base_backoff_s=0.1,
                                max_backoff_s=0.2)
    # waits drawn for k=1..4: 0.1, 0.2, 0.2, 0.2 jittered to at least 0.5x
    assert r.latency_s >= 5 * net.rtt_s + 0.5 * (0.1 + 0.2 + 0.2 + 0.2)
    assert r.latency_s <= 5 * net.rtt_s + 1.5 * (0.1 + 0.2 + 0.2 + 0.2)


def test_bandwidth_collapse_is_degraded_success():
    net = NetworkModel(fault_hook=lambda n: ("collapse", 0.1))
    r = net.transfer_with_retry(10_000.0)
    assert r.ok and r.failure == "collapse" and r.attempts == 1
    assert r.latency_s > net.transfer_s(10_000.0)


# ---------------------------------------------------------------------------
# swap-upload loss -> evict-and-replay degrade
# ---------------------------------------------------------------------------

def test_swap_loss_degrades_to_replay_bit_identical(params):
    """When every promote upload is lost, the engine must fall back to the
    evict-and-replay resume and still produce the fault-free streams."""
    prompts = [[65, 66, 67, 68], [70, 71], [80, 81, 82]]
    ref = _engine(params, max_len=64).generate(prompts, max_new=24)
    eng = _engine(params, n_pages=6, max_len=64, host_swap=True)
    eng.swap_fault_hook = lambda rid: True
    out = eng.generate(prompts, max_new=24)
    assert eng.evictions > 0, "a 6-page pool must preempt"
    assert eng.swap_losses > 0, "the swap path must have been faulted"
    for (td, ld), (tp, lp) in zip(ref, out):
        assert td == tp
        np.testing.assert_array_equal(ld, lp)
    _assert_drained(eng)


# ---------------------------------------------------------------------------
# fault injector hooks
# ---------------------------------------------------------------------------

def test_injector_is_deterministic():
    plan = FaultPlan(seed=4, transfer_loss_p=0.3, transfer_timeout_p=0.2,
                     bandwidth_collapse_p=0.1)
    i1, i2 = FaultInjector(plan), FaultInjector(plan)
    s1 = [i1.on_transfer(100.0) for _ in range(32)]
    s2 = [i2.on_transfer(100.0) for _ in range(32)]
    assert s1 == s2
    assert i1.events == i2.events
    assert sum(i1.events.values()) > 0, "the plan must inject something"


def test_partition_window_loses_every_attempt():
    inj = FaultInjector(FaultPlan(seed=0, partition_windows=((2, 5),)))
    verdicts = [inj.on_transfer(10.0) for _ in range(7)]
    assert verdicts[:2] == [None, None]
    assert all(v == ("loss", 0.0) for v in verdicts[2:5])
    assert verdicts[5:] == [None, None]
    assert inj.events["partition"] == 3


def test_injector_slot_crash_cancels_lowest_priority(params):
    eng = _engine(params, name="crashme")
    inj = FaultInjector(FaultPlan(seed=0, crash_steps=(2,)))
    inj.attach(engines=[eng])
    out = eng.generate(PROMPTS, max_new=12, priorities=[1, 0, 1])
    inj.detach()
    assert inj.events["slot_crash"] == 1
    assert eng.cancels == 1
    assert len(out[1][0]) < 12, "the priority-0 request was crashed"
    assert eng.step_hook is None and eng.swap_fault_hook is None
    _assert_drained(eng)


def test_injector_engine_crash_raises_and_abort_recovers(params):
    eng = _engine(params, name="crashhard")
    inj = FaultInjector(FaultPlan(seed=0, engine_crash_steps=(3,)))
    inj.attach(engines=[eng])
    with pytest.raises(EngineCrash):
        eng.generate(PROMPTS, max_new=12)
    inj.detach()
    assert eng.abort_all() == len(PROMPTS)
    _assert_drained(eng)
    # the engine is reusable after the scrub
    out = eng.generate([[5, 6, 7]], max_new=4)
    assert len(out[0][0]) == 4


def test_injector_pool_squeeze_steals_then_returns(params):
    eng = _engine(params, name="squeezed", n_pages=12)
    inj = FaultInjector(FaultPlan(seed=0, pool_squeeze_step=1,
                                  pool_squeeze_pages=6,
                                  pool_squeeze_duration=3))
    inj.attach(engines=[eng])
    out = eng.generate(PROMPTS, max_new=8)
    inj.detach()
    assert inj.events["pool_squeeze"] == 1
    assert all(len(t) == 8 for t, _ in out)
    _assert_drained(eng)


# ---------------------------------------------------------------------------
# queue shedding
# ---------------------------------------------------------------------------

def _task(rid, l):
    return SketchTask(req_id=rid, query="q", sketch="s", sentences=["s"],
                      expected_length=l, sketch_tokens=8)


def test_queue_sheds_longest_when_full():
    mon = RuntimeMonitor()
    q = MultiListQueue(max_size=2, monitor=mon)
    assert q.push(_task(0, 100)) and q.push(_task(1, 500))
    assert q.push(_task(2, 50)), "shorter task must displace the longest"
    assert len(q) == 2
    assert q.shed_count == 1 and mon.queue_shed == 1
    lens = sorted(t.expected_length for ql in q.lists for t in ql)
    assert lens == [50, 100], "the 500-token task was shed"


def test_queue_rejects_incoming_when_it_is_longest():
    q = MultiListQueue(max_size=2)
    q.push(_task(0, 100))
    q.push(_task(1, 200))
    assert not q.push(_task(2, 900))
    assert len(q) == 2 and q.shed_count == 1


# ---------------------------------------------------------------------------
# pipeline satellites
# ---------------------------------------------------------------------------

def _bare_pipeline(**kw):
    infos = kw.pop("infos", [
        EdgeModelInfo("a", LatencyModel(0.05, 100.0), capability=0.5),
        EdgeModelInfo("b", LatencyModel(0.05, 100.0), capability=0.7),
    ])
    return PICEPipeline(None, {}, LatencyModel(0.5, 20.0), infos,
                        n_edge_devices=1, **kw)


def test_pipeline_cfg_default_is_not_shared():
    p1, p2 = _bare_pipeline(), _bare_pipeline()
    assert p1.cfg is not p2.cfg
    p1.cfg.ensemble_size = 99
    assert p2.cfg.ensemble_size == PICEConfig().ensemble_size


def test_edge_info_fallback_for_unknown_primary():
    p = _bare_pipeline()
    info = p._edge_info_for("no-such-model")
    assert info.name == "b", "must fall back to the most capable edge"
    assert p.monitor.fallback_primaries == 1
    assert p._edge_info_for("a").name == "a"
    assert p.monitor.fallback_primaries == 1


def test_scheduler_inflates_eq2_with_edge_failure_rate():
    mon = RuntimeMonitor()
    edge = EdgeModelInfo("a", LatencyModel(0.05, 100.0), capability=0.5)
    sched = DynamicScheduler(LatencyModel(0.5, 20.0), [edge], NetworkModel(),
                             1, monitor=mon)
    base = sched.e2e_latency(32, 128, edge, 1)
    for _ in range(2):
        mon.record_edge_result(True)
        mon.record_edge_result(False)            # 50% failure rate
    inflated = sched.e2e_latency(32, 128, edge, 1)
    assert inflated > base
    cloud_side = sched.cloud.f(32) + sched.network.delay_s(32)
    assert inflated - cloud_side == pytest.approx(2 * (base - cloud_side))


# ---------------------------------------------------------------------------
# property-based chaos (hypothesis optional)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(
        cancel_step=st.integers(min_value=1, max_value=20),
        victims=st.sets(st.integers(min_value=0, max_value=2), max_size=2),
        squeeze=st.booleans(),
    )
    def test_chaos_sequences_conserve_pages_and_survivors(
            chaos_engine, chaos_baseline, cancel_step, victims, squeeze):
        """Random (cancel-set, step, squeeze) schedules: page refcounts
        conserved, no leaked pages or host snapshots, and surviving greedy
        streams bitwise equal to the fault-free baseline."""
        eng = chaos_engine
        inj = FaultInjector(FaultPlan(
            seed=1, pool_squeeze_step=cancel_step + 1 if squeeze else -1,
            pool_squeeze_pages=4, pool_squeeze_duration=2))
        steps = []

        def hook(e):
            inj.on_step(e)
            steps.append(1)
            if len(steps) == cancel_step:
                for v in victims:
                    e.cancel(v)
        eng.step_hook = hook
        try:
            out = eng.generate(PROMPTS, max_new=16)
        finally:
            eng.step_hook = None
            # a squeeze window that outlives the run still holds its pages:
            # return them before checking conservation
            hold = FaultInjector._hold_key(eng.name)
            if hold in eng.alloc.owned:
                eng.alloc.release(hold)
        for i in range(len(PROMPTS)):
            if i in victims and len(out[i][0]) < 16:
                continue                     # cancelled mid-run: partial
            assert out[i][0] == chaos_baseline[i][0]
            np.testing.assert_array_equal(out[i][1], chaos_baseline[i][1])
        _assert_drained(eng)

    @pytest.fixture(scope="module")
    def chaos_engine(params):
        return _engine(params, n_pages=24, max_len=64)

    @pytest.fixture(scope="module")
    def chaos_baseline(params):
        return _engine(params, max_len=64).generate(PROMPTS, max_new=16)
else:
    def test_chaos_sequences_conserve_pages_and_survivors():
        pytest.skip("hypothesis not installed; fixed-seed coverage lives in "
                    "test_cancel_mid_decode_survivors_bit_identical")
