"""Plan/run engine step: batched ragged ingest vs the serial fallback vs
monolithic prefill (three-way bit-identity), the one-table-push-per-step
contract, admission-stamp pruning under churn, surfaced prompt truncation,
and the bounded score buffer."""
import jax
import numpy as np
import pytest

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.serving.engine import InferenceEngine
from repro.serving.sampler import SamplerConfig

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                   max_seq_len=512, dtype="float32", remat=False)

PROMPTS = [[65 + i for i in range(43)], [70, 71], [80] * 40, [90] * 17,
           [5] * 64]


@pytest.fixture(scope="module")
def params():
    return transformer.init_params(TINY, jax.random.PRNGKey(0))


def _engine(params, chunk=0, **kw):
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_len", 128)
    kw.setdefault("kv_backend", "paged")
    kw.setdefault("page_size", 16)
    cfg = kw.pop("cfg", TINY).with_(prefill_chunk=chunk)
    return InferenceEngine(cfg, params, **kw)


def _assert_same(a, b):
    for i, ((ta, la), (tb, lb)) in enumerate(zip(a, b)):
        assert ta == tb, f"request {i}: tokens diverge"
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=f"request {i}: logprobs diverge")


def _assert_same_replay(a, b):
    for i, ((ta, la), (tb, lb)) in enumerate(zip(a, b)):
        assert ta == tb, f"request {i}: tokens diverge"
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=1e-5, atol=1e-6,
                                   err_msg=f"request {i}: logprobs diverge")


# ---------------------------------------------------------------------------
# three-way bit-identity: batched ragged == serial one-chunk == monolithic
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [16, 48])
@pytest.mark.parametrize("page", [8, 16])
def test_three_way_greedy(params, chunk, page):
    mono = _engine(params, chunk=0, page_size=page)
    serial = _engine(params, chunk=chunk, page_size=page,
                     ragged_ingest=False)
    batched = _engine(params, chunk=chunk, page_size=page)
    om = mono.generate(PROMPTS, max_new=12)
    os_ = serial.generate(PROMPTS, max_new=12)
    ob = batched.generate(PROMPTS, max_new=12)
    _assert_same(om, os_)
    _assert_same(om, ob)
    assert batched.alloc.pages_in_use == 0
    assert serial.alloc.pages_in_use == 0


def test_three_way_sampled_serialized(params):
    """One slot serializes the PRNG stream position-for-position: all three
    schedulers take identical draws."""
    sampler = SamplerConfig(temperature=0.9, top_k=20)
    outs = [_engine(params, chunk=c, max_batch=1, ragged_ingest=r,
                    sampler=sampler).generate(PROMPTS[:3], max_new=10)
            for c, r in ((0, True), (16, False), (16, True))]
    _assert_same(outs[0], outs[1])
    _assert_same(outs[0], outs[2])


def test_three_way_fork_suffixes(params):
    """Fork fan-out: suffix replay rides the (batched) chunk path; serial
    and batched must agree bitwise, and both match monolithic up to the
    documented (1, V)-vs-(B, V) unembed ulp on the post-replay logprob."""
    prefix = [(i % 100) + 1 for i in range(70)]
    suffixes = [[5, 6, 7], [9], [11] * 20]
    mono = _engine(params, chunk=0, max_batch=4)
    serial = _engine(params, chunk=16, max_batch=4, ragged_ingest=False)
    batched = _engine(params, chunk=16, max_batch=4)
    om = mono.generate_fanout(prefix, suffixes, max_new=8)
    os_ = serial.generate_fanout(prefix, suffixes, max_new=8)
    ob = batched.generate_fanout(prefix, suffixes, max_new=8)
    _assert_same(os_, ob)
    _assert_same_replay(om, ob)
    assert batched.alloc.pages_in_use == 0


def test_three_way_eviction_resume(params):
    """A starved pool preempts and resumes; every scheduler converges to
    the unconstrained result. Serial and batched may preempt at different
    step boundaries (batched ingest moves the pressure point), so the one
    post-resume logprob carries the documented replay ulp — tokens are
    still bitwise."""
    prompts = [[65, 66, 67, 68], [70, 71], [80, 81, 82]]
    ref = _engine(params, chunk=16, max_len=64,
                  page_size=8).generate(prompts, max_new=24)
    serial = _engine(params, chunk=16, max_len=64, page_size=8, n_pages=6,
                     ragged_ingest=False)
    batched = _engine(params, chunk=16, max_len=64, page_size=8, n_pages=6)
    os_ = serial.generate(prompts, max_new=24)
    ob = batched.generate(prompts, max_new=24)
    assert serial.evictions > 0 and batched.evictions > 0
    _assert_same_replay(os_, ob)
    _assert_same_replay(ref, ob)
    assert batched.alloc.pages_in_use == 0


# ---------------------------------------------------------------------------
# plan/run step contract
# ---------------------------------------------------------------------------

def test_push_table_at_most_once_per_step(params, monkeypatch):
    """The step loop batches all host block-table edits (growth, COW,
    eviction, frees) into at most ONE device push per step."""
    pushes = []
    orig_push = InferenceEngine._push_table
    orig_step = InferenceEngine.step

    def spy_push(self):
        pushes.append("push")
        return orig_push(self)

    def spy_step(self):
        before = len(pushes)
        out = orig_step(self)
        assert len(pushes) - before <= 1, \
            "step() pushed the block table more than once"
        return out

    monkeypatch.setattr(InferenceEngine, "_push_table", spy_push)
    monkeypatch.setattr(InferenceEngine, "step", spy_step)
    # eviction pressure + mixed ingest/decode exercises every table-dirtying
    # path inside the step loop
    eng = _engine(params, chunk=16, max_len=64, page_size=8, n_pages=6)
    eng.generate([[65, 66, 67, 68], [70, 71], [80, 81, 82]], max_new=24)
    assert eng.evictions > 0
    assert pushes, "scenario never pushed the table at all"


def test_step_defers_decode_harvest(params):
    """Dispatch and readback are split across steps: after a decode-only
    step the engine holds an in-flight bundle, and the next step commits
    it before planning."""
    eng = _engine(params, chunk=16)
    eng.add_request(0, [1, 2, 3], max_new=4)
    while eng.slots[0].prefill_toks:
        eng.step()
    n0 = len(eng.slots[0].tokens)       # first token (eager finish draw)
    assert eng.step()                   # dispatches decode, commits nothing
    assert eng._pending_decode is not None
    assert len(eng.slots[0].tokens) == n0
    assert eng.step()                   # harvests the deferred commit
    assert len(eng.slots[0].tokens) >= n0 + 1
    while eng.slots[0].active:
        assert eng.step()
    assert eng._pending_decode is None


def test_warmup_is_state_neutral(params):
    """warmup() precompiles decode/ingest variants without touching the
    PRNG stream or cache contents: a warmed engine's outputs are bitwise a
    cold engine's."""
    sampler = SamplerConfig(temperature=0.8, top_k=16)
    cold = _engine(params, chunk=16, sampler=sampler)
    warm = _engine(params, chunk=16, sampler=sampler)
    key_before = np.asarray(warm.key).copy()
    assert warm.warmup(ingest_rows=(1, warm.max_batch)) > 0
    np.testing.assert_array_equal(np.asarray(warm.key), key_before)
    _assert_same(cold.generate(PROMPTS, max_new=8),
                 warm.generate(PROMPTS, max_new=8))


def test_warmup_refuses_busy_engine(params):
    eng = _engine(params, chunk=16)
    eng.add_request(0, [1, 2, 3], max_new=4)
    with pytest.raises(AssertionError):
        eng.warmup()


# ---------------------------------------------------------------------------
# S1: admission-stamp pruning must not drop live requests' TTFT
# ---------------------------------------------------------------------------

def test_stamp_pruning_spares_live_references(params):
    eng = _engine(params, chunk=16)
    eng._admit_stamp_cap = 2
    eng._t_admit = {i: float(i) for i in range(8)}
    eng.slots[0].active, eng.slots[0].req_id = True, 3
    eng._inflight = {5}
    eng._resume_queue = []
    eng._prune_admit_stamps()
    assert 3 in eng._t_admit and 5 in eng._t_admit
    assert len(eng._t_admit) == 2
    eng.slots[0].active, eng.slots[0].req_id = False, -1
    eng._inflight = set()


def test_ttft_survives_stamp_churn_under_eviction(params):
    """With a tiny stamp cap and eviction churn, every request must still
    get its TTFT recorded (the old cap popped the OLDEST stamp — exactly
    the preempted request still waiting in the resume queue)."""
    prompts = [[65, 66, 67, 68], [70, 71], [80, 81, 82]]
    eng = _engine(params, chunk=16, max_len=64, page_size=8, n_pages=6)
    eng._admit_stamp_cap = 1
    eng.ttft.clear()
    eng.generate(prompts, max_new=24)
    assert eng.evictions > 0
    assert set(eng.ttft) == {0, 1, 2}, \
        f"lost TTFT stamps under churn: {sorted(eng.ttft)}"


# ---------------------------------------------------------------------------
# S2: prompt truncation is surfaced and replayed identically on resume
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [0, 16])
def test_truncation_surfaced(params, chunk):
    long_prompt = [(i % 100) + 1 for i in range(200)]
    eng = _engine(params, chunk=chunk, max_len=64)
    (toks, _), = eng.generate([long_prompt], max_new=4)
    assert eng.truncations[0] == 200 - 64
    short = _engine(params, chunk=chunk, max_len=64)
    short.generate([[1, 2, 3]], max_new=4)
    assert 0 not in short.truncations


def test_truncation_replayed_identically_on_resume(params):
    """A truncated request evicted MID-INGEST must resume with the SAME
    kept tail (the resume queue carries the full prompt; re-admission
    re-truncates deterministically) — outputs bitwise match an
    unconstrained engine's. The grower's decode pressure preempts the
    truncated prompt while its chunks are still streaming in."""
    grower = [(i % 50) + 1 for i in range(30)]      # 4 pages, then grows
    long_p = [(i % 90) + 1 for i in range(150)]     # truncates to 64 = 8 pages
    prompts = [grower, long_p]
    ref_eng = _engine(params, chunk=8, max_len=64, max_batch=2, page_size=8)
    ref = ref_eng.generate(prompts, max_new=40)
    small = _engine(params, chunk=8, max_len=64, max_batch=2, page_size=8,
                    n_pages=12)
    out = small.generate(prompts, max_new=40)
    assert small.evictions > 0, "pool must preempt to test the replay"
    assert 0 not in small.truncations
    assert small.truncations[1] == 150 - 64
    _assert_same_replay(ref, out)


# ---------------------------------------------------------------------------
# S4: score() buffer is clamped to max_len
# ---------------------------------------------------------------------------

def test_score_clamps_to_max_len(params):
    eng = _engine(params, chunk=0, max_len=64)
    seq = [(i % 100) + 1 for i in range(300)]
    mean_long, gold_long = eng.score(seq)
    mean_tail, gold_tail = eng.score(seq[-64:])
    assert gold_long.shape == (63,)
    np.testing.assert_array_equal(gold_long, gold_tail)
    assert mean_long == mean_tail


def test_score_short_sequences_unchanged(params):
    eng = _engine(params, chunk=0, max_len=64)
    mean, gold = eng.score([3, 1, 4, 1, 5])
    assert gold.shape == (4,)
    assert np.isfinite(mean)
