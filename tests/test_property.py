"""Property-based tests (hypothesis) for the system's invariants."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-test.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import metrics as M
from repro.core.dispatch import MultiListQueue
from repro.core.ensemble import Candidate, confidence
from repro.core.exec_optimizer import merge_once, plan_expansion
from repro.core.profiler import LatencyModel, fit_latency_model
from repro.core.scheduler import DynamicScheduler, EdgeModelInfo
from repro.serving.network import NetworkModel
from repro.serving.requests import SketchTask
from repro.serving.sampler import SamplerConfig, sample

words = st.text(alphabet="abcdefg ", min_size=1, max_size=30)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

@given(words, words)
@settings(max_examples=60, deadline=None)
def test_rouge1_bounds_and_symmetry_of_overlap(a, b):
    p, r, f1 = M.rouge_1(a, b)
    assert 0.0 <= p <= 1.0 and 0.0 <= r <= 1.0 and 0.0 <= f1 <= 1.0
    p2, r2, _ = M.rouge_1(b, a)
    # precision/recall swap under argument swap
    assert math.isclose(p, r2, abs_tol=1e-12)
    assert math.isclose(r, p2, abs_tol=1e-12)


@given(words)
@settings(max_examples=30, deadline=None)
def test_rouge_identity(a):
    if a.split():
        _, _, f1 = M.rouge_1(a, a)
        assert math.isclose(f1, 1.0)
        _, _, fl = M.rouge_l(a, a)
        assert math.isclose(fl, 1.0)


# ---------------------------------------------------------------------------
# execution optimizer
# ---------------------------------------------------------------------------

@given(st.lists(words.filter(lambda s: s.strip()), min_size=1, max_size=16))
@settings(max_examples=60, deadline=None)
def test_merge_once_halves_and_preserves(sentences):
    groups = [[s] for s in sentences]
    merged = merge_once(groups)
    assert len(merged) == math.ceil(len(groups) / 2)
    assert sorted(s for g in merged for s in g) == sorted(sentences)


@given(st.lists(words.filter(lambda s: s.strip()), min_size=1, max_size=12),
       st.floats(min_value=0.0, max_value=100.0))
@settings(max_examples=60, deadline=None)
def test_plan_expansion_invariants(sentences, budget):
    plan = plan_expansion(sentences, lambda p, t: 0.05 * t, budget)
    flat = sorted(s for g in plan.groups for s in g)
    assert flat == sorted(s for s in sentences if s.strip()) or not flat
    assert 1 <= plan.parallelism <= max(len(flat), 1)


# ---------------------------------------------------------------------------
# dispatch queue
# ---------------------------------------------------------------------------

@given(st.lists(st.integers(min_value=1, max_value=2000), min_size=0,
                max_size=40),
       st.integers(min_value=1, max_value=8))
@settings(max_examples=60, deadline=None)
def test_multilist_queue_conservation(lengths, batch):
    q = MultiListQueue()
    for i, l in enumerate(lengths):
        q.push(SketchTask(req_id=i, query="", sketch="", sentences=[],
                          expected_length=l, sketch_tokens=1))
    out = []
    guard = 0
    while len(q) and guard < 1000:
        b = q.pull_batch(batch)
        assert 0 < len(b) <= batch
        # uniformity: a batch comes from a single length bucket
        idxs = {q._index(t.expected_length) for t in b}
        assert len(idxs) == 1
        out.extend(t.req_id for t in b)
        guard += 1
    assert sorted(out) == list(range(len(lengths)))


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

@given(st.integers(min_value=60, max_value=2000),
       st.floats(min_value=5.0, max_value=100.0),
       st.floats(min_value=1.0, max_value=100.0))
@settings(max_examples=60, deadline=None)
def test_scheduler_decision_always_meets_hard_constraint(l, cloud_rate,
                                                         edge_rate):
    cloud = LatencyModel(t0=0.5, rate=cloud_rate)
    edges = [EdgeModelInfo("e", LatencyModel(t0=0.5, rate=edge_rate), 0.7)]
    s = DynamicScheduler(cloud, edges, NetworkModel(), 4)
    d = s.schedule(l)
    if d.mode == "progressive":
        assert d.est_latency_s <= cloud.f(l) + 1e-6
        assert 0 < d.sketch_tokens <= l


# ---------------------------------------------------------------------------
# ensemble confidence
# ---------------------------------------------------------------------------

@given(st.floats(min_value=-20.0, max_value=0.0),
       st.integers(min_value=1, max_value=500))
@settings(max_examples=60, deadline=None)
def test_confidence_bounded_01(mlp, n):
    c = Candidate("some words here", mlp, n, "m")
    v = confidence(c, "some words", [c])
    assert 0.0 <= v <= 1.0 + 1e-9


@given(st.floats(min_value=-10.0, max_value=-0.1))
@settings(max_examples=30, deadline=None)
def test_confidence_monotone_in_logprob(mlp):
    base = Candidate("same words", mlp, 10, "a")
    better = Candidate("same words", mlp + 0.05, 10, "b")
    pool = [base, better]
    assert confidence(better, "same words", pool) >= confidence(
        base, "same words", pool)


# ---------------------------------------------------------------------------
# profiler fit
# ---------------------------------------------------------------------------

@given(st.floats(min_value=0.0, max_value=5.0),
       st.floats(min_value=1.0, max_value=500.0))
@settings(max_examples=40, deadline=None)
def test_latency_fit_roundtrip(t0, rate):
    true = LatencyModel(t0=t0, rate=rate)
    fit = fit_latency_model([(l, true.f(l)) for l in (8, 32, 128, 512)])
    assert abs(fit.f(256) - true.f(256)) <= 1e-6 + 0.01 * true.f(256)


# ---------------------------------------------------------------------------
# sampler
# ---------------------------------------------------------------------------

@given(st.integers(min_value=1, max_value=16),
       st.integers(min_value=0, max_value=3))
@settings(max_examples=20, deadline=None)
def test_topk_sampling_stays_in_topk(k, seed):
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (4, 32))
    toks = sample(logits, key, SamplerConfig(temperature=1.0, top_k=k))
    top = jnp.argsort(logits, axis=-1)[:, -k:]
    for b in range(4):
        assert int(toks[b]) in np.asarray(top[b])
