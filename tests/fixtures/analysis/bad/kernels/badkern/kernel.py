"""Known-bad Pallas block specs: wrong index_map arity, wrong return rank,
misaligned literal dims, and a VMEM footprint far over the cap."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(s_ref, x_ref, y_ref, o_ref, acc_ref):
    o_ref[...] = x_ref[...] + y_ref[...]


def bad_call(x, y):
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(4, 4),
        in_specs=[
            pl.BlockSpec((8, 12), lambda i, j: (i, j)),         # expect: RA401,RA403
            pl.BlockSpec((8, 128), lambda i, j, s_ref: (i,)),   # expect: RA402
        ],
        out_specs=pl.BlockSpec((4096, 4096),
                               lambda i, j, s_ref: (i, j)),
        scratch_shapes=[
            pltpu.VMEM((4, 128), jnp.float32),                  # expect: RA403
        ],
    )
    return pl.pallas_call(                                      # expect: RA404
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x, y)
