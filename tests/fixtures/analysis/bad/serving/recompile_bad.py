"""Known-bad recompile-budget snippets (see tests/test_analysis.py)."""
import jax


def _bucket(n):
    b = 1
    while b < n:
        b *= 2
    return b


def make_step(cfg):                     # expect: RA204
    return jax.jit(lambda x: x + 1)     # expect: RA202


def rogue_jit(x):
    f = jax.jit(lambda y: y * 2)        # expect: RA202
    return f(x)


class Engine:
    def score(self, tokens):
        return _bucket(len(tokens))     # expect: RA201

    def admit(self, req):
        self._prefill_chunk(len(req.prompt), req.prompt)    # expect: RA203


import functools                                            # noqa: E402


@functools.lru_cache(maxsize=None)
def _jitted(cfg, kind):
    if kind == "decode":
        return jax.jit(lambda x: x)
    return jax.jit(lambda x: x * 2)


class ColdEngine:
    """warmup() exists but skips one registry entry point."""

    def __init__(self, cfg):
        self._decode = _jitted(cfg, "decode")   # expect: RA205
        self._prefill = _jitted(cfg, "prefill")

    def warmup(self):
        self._prefill(0)
