"""Known-bad host-sync snippets. Lines marked `# expect: CODE` are asserted
by tests/test_analysis.py with their exact line numbers."""
import jax
import jax.numpy as jnp
import numpy as np


def leaky_step(x):
    y = jnp.sum(x)
    a = float(y)                        # expect: RA101
    b = y.item()                        # expect: RA101
    c = np.asarray(y)                   # expect: RA102
    d = jax.device_get(y)               # expect: RA103
    y.block_until_ready()               # expect: RA104
    return a, b, c, d


def leaky_loop(xs):
    outs = jnp.stack(xs)
    return [int(v) for v in outs]       # expect: RA101


def waived_step(x):
    y = jnp.sum(x)
    # repro-analysis: disable=RA101 reason=demonstrates a documented waiver
    return float(y)
