"""Bad async front-end fixture: blocking calls on the serving event loop.

One driver coroutine serves every stream here, so each of these stalls all
in-flight requests at once. The device syncs double-report with the
host-sync pass (RA1xx), which also scopes serving/.
"""
import time
from time import sleep

import jax


async def drive_blocking(engine):
    time.sleep(0.01)                        # expect: RA601
    toks = jax.device_get(engine.buf)       # expect: RA103,RA602
    engine.out.block_until_ready()          # expect: RA104,RA602
    return toks


def tick_between_steps():
    sleep(0.5)                              # expect: RA601
