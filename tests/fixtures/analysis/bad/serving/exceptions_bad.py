"""Bad fixture for the exceptions pass (RA501): handlers that swallow."""


def swallow_pass(engine):
    try:
        engine.step()
    except RuntimeError:                      # expect: RA501
        pass


def swallow_with_work(engine):
    try:
        engine.step()
    except (ValueError, KeyError):            # expect: RA501
        engine.reset()


def swallow_bare(engine):
    try:
        engine.step()
    except Exception:                         # expect: RA501
        return None


def swallow_return_default(xs):
    try:
        return xs[0]
    except IndexError:                        # expect: RA501
        return 0
