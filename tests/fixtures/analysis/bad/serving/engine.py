"""Known-bad donation snippets: the donated cache is neither rebound by the
donating statement nor left unread afterwards."""
import functools

import jax


@functools.lru_cache(maxsize=None)
def _jitted(cfg, kind):
    if kind == "decode":
        return jax.jit(lambda p, c: (p, c), donate_argnums=(1,))
    return jax.jit(lambda p, c: (p, c))


class Engine:
    def __init__(self, cfg):
        self._decode = _jitted(cfg, "decode")               # expect: RA205

    def step(self):
        toks, _ = self._decode(self.params, self.cache)     # expect: RA301
        stale = self.cache                                  # expect: RA302
        return toks, stale
