"""Good fixture for the exceptions pass (RA501): every caught fault is
re-raised or recorded where telemetry can see it."""


def reraise(engine):
    try:
        engine.step()
    except MemoryError:
        engine.abort_all()
        raise


def record_to_monitor(engine, monitor):
    try:
        engine.step()
    except RuntimeError:
        monitor.record_edge_result(False)


def bump_counter(self, engine):
    try:
        engine.step()
    except RuntimeError:
        self.crash_events += 1


def bump_stats_dict(self, engine):
    try:
        engine.step()
    except ValueError:
        self.stats["faults"] = self.stats.get("faults", 0) + 1


def waived_swallow(xs):
    try:
        return xs[0]
    # repro-analysis: disable=RA501 reason=absence of a value IS the result
    except IndexError:
        return None
