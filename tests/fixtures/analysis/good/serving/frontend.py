"""Good async front-end fixture: cooperative yields only.

The engine's own step() is the sanctioned blocking boundary; between steps
the driver yields with awaited asyncio sleeps (an awaited bare `sleep` must
not be mistaken for time.sleep).
"""
import asyncio
from asyncio import sleep


async def drive(engine):
    while engine.has_work():
        engine.step()
        await asyncio.sleep(0)


async def backoff_briefly():
    await sleep(0.01)
