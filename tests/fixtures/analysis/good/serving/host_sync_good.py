"""Clean host-sync patterns: host planning stays numpy, the one readback is
explicitly waived with a reason."""
import jax
import jax.numpy as jnp
import numpy as np


def plan(rows):
    table = np.zeros((len(rows), 4), np.int32)
    return [r for r in rows if r]


def harvest_like(x):
    y = jnp.sum(x)
    # repro-analysis: disable=RA103 reason=the single sanctioned readback of this module
    host = jax.device_get(y)
    return float(host)
