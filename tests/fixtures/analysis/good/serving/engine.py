"""Clean donation pattern: the donated cache is rebound by the donating
statement, so the stale reference is never reachable."""
import functools

import jax


@functools.lru_cache(maxsize=None)
def _jitted(cfg, kind):
    if kind == "decode":
        return jax.jit(lambda p, c: (p, c), donate_argnums=(1,))
    return jax.jit(lambda p, c: (p, c))


class Engine:
    def __init__(self, cfg):
        self._decode = _jitted(cfg, "decode")

    def warmup(self):
        # every registry entry point precompiles here (RA205)
        toks, self.cache = self._decode(self.params, self.cache)
        return toks

    def step(self):
        toks, self.cache = self._decode(self.params, self.cache)
        return toks
