"""Clean recompile-budget patterns: clamped buckets, lru_cache registry,
bucketed static arguments."""
import functools

import jax


def _bucket(n):
    b = 1
    while b < n:
        b *= 2
    return b


def _chunk_live(n, cap):
    return min(_bucket(n), cap)


@functools.lru_cache(maxsize=None)
def _jitted(cfg, kind):
    if kind == "decode":
        return jax.jit(lambda x: x)
    return jax.jit(lambda x: x + 1)


class Engine:
    max_len = 256

    def __init__(self, cfg):
        self._decode = _jitted(cfg, "decode")
        self._prefill = _jitted(cfg, "prefill")

    def warmup(self):
        # the precompile list covers every registry entry point (RA205)
        self._decode(0)
        self._prefill(0)

    def score(self, tokens):
        return min(_bucket(len(tokens)), self.max_len)

    def admit(self, req):
        live = min(_bucket(len(req.prompt)), self.max_len)
        self._prefill_chunk(live, req.prompt)
