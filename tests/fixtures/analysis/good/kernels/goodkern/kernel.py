"""Clean Pallas spec: arity matches grid + prefetch, aligned dims, small
VMEM footprint."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(s_ref, x_ref, o_ref, acc_ref):
    o_ref[...] = x_ref[...]


def good_call(x):
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(4, 4),
        in_specs=[
            pl.BlockSpec((8, 128), lambda i, j, s_ref: (i, j)),
        ],
        out_specs=pl.BlockSpec((8, 128), lambda i, j, *_: (i, j)),
        scratch_shapes=[
            pltpu.VMEM((8, 128), jnp.float32),
        ],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
