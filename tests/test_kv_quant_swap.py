"""Quantized KV pages (`cfg.kv_dtype`) and the host-tier page swap
(`host_swap`): write/read roundtrips and the layered tolerance contract,
quant kernels vs the dequant oracle, allocator demote/promote invariants
(property-based under hypothesis, fixed seeds without it), engine
end-to-end behavior, and the predicted-occupancy admission signal."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core.profiler import RuntimeMonitor
from repro.kernels.paged_decode_attention import ops as pda_ops
from repro.kernels.paged_decode_attention import ref as pda_ref
from repro.kernels.paged_prefill_attention import ops as ppa_ops
from repro.kernels.paged_prefill_attention import ref as ppa_ref
from repro.models import paged_cache as pc
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.serving.engine import InferenceEngine

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                   max_seq_len=512, dtype="float32", remat=False)


@pytest.fixture(scope="module")
def params():
    return transformer.init_params(TINY, jax.random.PRNGKey(0))


def _engine(params, **kw):
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_len", 128)
    cfg = TINY.with_(kv_dtype=kw.pop("kv_dtype")) if "kv_dtype" in kw \
        else TINY
    return InferenceEngine(cfg, params, **kw)


# ---------------------------------------------------------------------------
# quantize-on-write / dequantize-on-read roundtrip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_prompt_quant_roundtrip_error_bounded(kv_dtype):
    """Bulk write then dequant-gather: every element lands within one
    quantization step of the original (round -> half a step, plus fp8
    mantissa rounding)."""
    page, P, kv, hd = 8, 4, 2, 16
    n_pages = P + 1
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, P * page, kv, hd)) * 3.0
    pages = jnp.zeros((n_pages, page, kv, hd), pc.kv_storage_dtype(kv_dtype))
    scales = jnp.ones((n_pages, kv), jnp.float32)
    row = jnp.asarray(list(range(P)) + [-1], jnp.int32)
    pages, pages2, scales, scales2 = pc.write_prompt_quant(
        pages, pages, scales, scales, row, x, x, P * page, kv_dtype)
    dq = pc.gather_sequence_dequant(pages, scales, row[None])[:, :P * page]
    # per-(page, head) step = scale; error <= step (int8: half a step from
    # the round, doubled for slack; fp8 adds relative mantissa error)
    step = np.asarray(scales)[np.asarray(row[:P])]           # (P, kv)
    step = np.repeat(step[:, None, :], page, axis=1).reshape(
        1, P * page, kv)[..., None]                          # (1, S, kv, 1)
    err = np.abs(np.asarray(dq) - np.asarray(x))
    bound = step * (0.75 if kv_dtype == "int8" else 1.0) \
        + 0.1 * np.abs(np.asarray(x))
    assert (err <= bound).all(), float((err - bound).max())


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_incremental_writes_match_bulk_within_requant_bound(kv_dtype):
    """Token-by-token `write_token_quant` re-rounds the tail page against a
    growing abs-max; the final page must stay within a couple of
    quantization steps of the bulk-written one (docs/serving.md bound)."""
    page, kv, hd = 8, 2, 16
    n_pages = 3
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (1, page, kv, hd)) * 2.0
    row = jnp.asarray([0, -1, -1], jnp.int32)

    zp = jnp.zeros((n_pages, page, kv, hd), pc.kv_storage_dtype(kv_dtype))
    zs = jnp.ones((n_pages, kv), jnp.float32)
    bk, bv, bks, bvs = pc.write_prompt_quant(zp, zp, zs, zs, row, x, x,
                                             page, kv_dtype)
    ik, iv, iks, ivs = zp, zp, zs, zs
    table = row[None]
    for t in range(page):
        ik, iv, iks, ivs = pc.write_token_quant(
            ik, iv, iks, ivs, table, jnp.asarray([t], jnp.int32),
            x[:, t:t + 1], x[:, t:t + 1], kv_dtype)
    # after the full page both paths saw the same abs-max
    np.testing.assert_allclose(np.asarray(iks[0]), np.asarray(bks[0]),
                               rtol=1e-6)
    dq_b = pc.gather_sequence_dequant(bk, bks, table)[:, :page]
    dq_i = pc.gather_sequence_dequant(ik, iks, table)[:, :page]
    step = np.asarray(bks)[0][None, None, :, None]           # (1,1,kv,1)
    # int8's step is uniform (scale); fp8's is relative (3-bit mantissa,
    # ~12.5% spacing), so re-rounding drift scales with the value
    rel = 0.0 if kv_dtype == "int8" else 0.30
    assert (np.abs(np.asarray(dq_i) - np.asarray(dq_b))
            <= 2.0 * step + rel * np.abs(np.asarray(dq_b)) + 1e-6).all()


def test_quant_write_respects_unmapped_and_inactive_rows():
    """Quantized token writes drop unmapped (-1) rows and active-masked
    rows exactly like the float writer — a stale table row must never
    requantize a page a COW sibling owns."""
    page, kv, hd = 8, 2, 4
    pages = jnp.zeros((4, page, kv, hd), jnp.int8)
    scales = jnp.ones((4, kv), jnp.float32)
    table = jnp.asarray([[2], [3]], jnp.int32)
    lens = jnp.asarray([0, 0], jnp.int32)
    new = jnp.full((2, 1, kv, hd), 5.0)
    k, v, ks, vs = pc.write_token_quant(
        pages, pages, scales, scales, table, lens, new, new,
        "int8", active=jnp.asarray([True, False]))
    assert np.asarray(k[2]).any(), "active row must write its page"
    assert not np.asarray(k[3]).any(), "inactive row must be dropped"
    np.testing.assert_array_equal(np.asarray(ks[3]), np.ones((kv,)))


# ---------------------------------------------------------------------------
# quant kernels vs dequant oracle (tight) vs float oracle (loose)
# ---------------------------------------------------------------------------

def _quant_pool(key, n_pages, page, kv, hd, kv_dtype, n_rows, lens):
    """Float pool + its quantized counterpart written through the real
    prompt writer, sharing one chained block table."""
    P = max(-(-int(ln) // page) for ln in lens)
    tbl = np.full((n_rows, P), -1, np.int64)
    nxt = 0
    for b, ln in enumerate(lens):
        live = -(-int(ln) // page)
        tbl[b, :live] = np.arange(nxt, nxt + live)
        nxt += live
    table = jnp.asarray(tbl, jnp.int32)
    kf = jax.random.normal(key, (n_pages, page, kv, hd)) * 1.5
    vf = jax.random.normal(jax.random.split(key)[0],
                           (n_pages, page, kv, hd)) * 1.5
    kq = jnp.zeros((n_pages, page, kv, hd), pc.kv_storage_dtype(kv_dtype))
    vq = jnp.zeros_like(kq)
    ks = jnp.ones((n_pages, kv), jnp.float32)
    vs = jnp.ones((n_pages, kv), jnp.float32)
    for b, ln in enumerate(lens):
        if not ln:
            continue
        seq_k = pc.gather_sequence(kf, table[b:b + 1])[:, :int(ln)]
        seq_v = pc.gather_sequence(vf, table[b:b + 1])[:, :int(ln)]
        kq, vq, ks, vs = pc.write_prompt_quant(
            kq, vq, ks, vs, table[b], seq_k, seq_v, int(ln), kv_dtype)
    return kf, vf, kq, vq, ks, vs, table


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_paged_decode_quant_kernel_matches_dequant_oracle(kv_dtype):
    page, P, kv, Hq, hd = 8, 4, 2, 8, 32
    lens = [0, 13, P * page]
    key = jax.random.PRNGKey(2)
    kf, vf, kq, vq, ks, vs, table = _quant_pool(
        key, 3 * P + 2, page, kv, hd, kv_dtype, 3, lens)
    q = jax.random.normal(jax.random.PRNGKey(3), (3, 1, Hq, hd))
    lens = jnp.asarray(lens, jnp.int32)
    out = pda_ops.paged_decode_attention_quant(q, kq, vq, ks, vs, table,
                                               lens)
    ref = pda_ref.paged_decode_attention_quant_ref(q, kq, vq, ks, vs,
                                                   table, lens)
    # same quantized pool, two reduction orders: tight
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    # vs the float pool: quantization error only — loose contract
    ref_f = pda_ref.paged_decode_attention_ref(q, kf, vf, table, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_f),
                               rtol=0.15, atol=0.1)


@pytest.mark.parametrize("kv_dtype", ["int8"])
def test_paged_prefill_quant_kernel_matches_dequant_oracle(kv_dtype):
    page, P, kv, Hq, hd = 8, 4, 2, 8, 32
    ctx, C = 11, 8                       # chunk starts mid-page
    key = jax.random.PRNGKey(4)
    kf, vf, kq, vq, ks, vs, table = _quant_pool(
        key, P + 2, page, kv, hd, kv_dtype, 1, [ctx + C])
    q = jax.random.normal(jax.random.PRNGKey(5), (1, C, Hq, hd))
    out = ppa_ops.paged_prefill_attention_quant(
        q, kq, vq, ks, vs, table[0], ctx, C)
    ref = ppa_ref.paged_prefill_attention_quant_ref(
        q, kq, vq, ks, vs, table[0], ctx, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    ref_f = ppa_ref.paged_prefill_attention_ref(q, kf, vf, table[0], ctx, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_f),
                               rtol=0.15, atol=0.1)


def test_paged_prefill_ragged_quant_rows_match_single():
    """Each ragged row is bitwise the single-slot quant kernel on the same
    pool (batching adds rows, never changes a row's reduction order)."""
    page, P, kv, Hq, hd = 8, 3, 2, 4, 32
    lens_total = [19, 8]
    chunk = [8, 8]
    offs = [11, 0]
    key = jax.random.PRNGKey(6)
    _, _, kq, vq, ks, vs, table = _quant_pool(
        key, 2 * P + 2, page, kv, hd, "int8", 2, lens_total)
    C = max(chunk)
    q = jax.random.normal(jax.random.PRNGKey(7), (2, C, Hq, hd))
    out = ppa_ops.paged_prefill_attention_ragged_quant(
        q, kq, vq, ks, vs, table, jnp.asarray(offs, jnp.int32),
        jnp.asarray(chunk, jnp.int32))
    for r in range(2):
        single = ppa_ops.paged_prefill_attention_quant(
            q[r:r + 1], kq, vq, ks, vs, table[r], offs[r], chunk[r])
        np.testing.assert_array_equal(
            np.asarray(out[r:r + 1, :chunk[r]]),
            np.asarray(single[:, :chunk[r]]))


# ---------------------------------------------------------------------------
# allocator host tier: demote / promote / drop
# ---------------------------------------------------------------------------

def test_demote_frees_unique_pages_and_pins_shared():
    alloc = pc.PageAllocator(n_pages=16, page_size=8, max_pages_per_seq=8)
    alloc.alloc_for(0, 24)                         # 3 pages
    alloc.fork(0, 1, 20)                           # shares 2, copies tail
    free0 = len(alloc.free)
    swapped = alloc.demote(1, req_id="r1")
    # only the private tail page was uniquely owned by the fork
    assert [i for i, _ in swapped] == [2]
    assert len(alloc.free) == free0 + 1
    ent = alloc.hosted["r1"]
    assert [i for i, _ in ent["resident"]] == [0, 1]
    for _, p in ent["resident"]:
        assert alloc.refcount[p] == 2, "demoted chain must hold its ref"
    assert alloc.hosted_pages("r1") == 1
    # the parent can still release without freeing the pinned prefix
    alloc.release(0)
    for _, p in ent["resident"]:
        assert alloc.refcount[p] == 1


def test_promote_rebuilds_chain_in_logical_order():
    alloc = pc.PageAllocator(n_pages=16, page_size=8, max_pages_per_seq=8)
    pages = alloc.alloc_for(0, 30)                 # 4 pages
    alloc.fork(0, 1, 16)                           # pages[0:2] shared
    swapped = alloc.demote(0, req_id="q")
    assert [i for i, _ in swapped] == [2, 3]
    uploads = alloc.promote("q", slot=5)
    assert [i for i, _ in uploads] == [2, 3]
    chain = alloc.owned[5]
    assert len(chain) == 4
    assert chain[:2] == pages[:2], "shared prefix pages rejoin in place"
    assert chain[2:] == [p for _, p in uploads]
    assert "q" not in alloc.hosted
    # conservation: every page accounted exactly once per reference
    for p in range(alloc.n_pages):
        refs = sum(1 for ch in alloc.owned.values() for x in ch if x == p)
        assert alloc.refcount[p] == refs


def test_promote_when_dry_raises_and_drop_hosted_releases():
    alloc = pc.PageAllocator(n_pages=4, page_size=8, max_pages_per_seq=4)
    alloc.alloc_for(0, 32)                         # whole pool
    alloc.demote(0, req_id="a")                    # all 4 swapped
    alloc.alloc_for(1, 32)                         # pool refilled elsewhere
    with pytest.raises(MemoryError):
        alloc.promote("a", slot=2)
    assert "a" in alloc.hosted, "failed promote must keep the host entry"
    alloc.release(1)
    alloc.alloc_for(1, 8)
    alloc.fork(1, 2, 8)                            # page-aligned: shared
    alloc.demote(2, req_id="b")                    # nothing unique: resident
    assert alloc.hosted_pages("b") == 0
    shared = alloc.owned[1][0]
    assert alloc.refcount[shared] == 2
    alloc.drop_hosted("b")
    assert alloc.refcount[shared] == 1, "drop must release the pinned ref"
    alloc.drop_hosted("missing")                   # no-op


def _alloc_invariants(alloc):
    """Refcount conservation across device chains, host pins, free list."""
    assert len(set(alloc.free)) == len(alloc.free)
    for p in alloc.free:
        assert alloc.refcount[p] == 0
    for p in range(alloc.n_pages):
        refs = sum(1 for ch in alloc.owned.values() for x in ch if x == p)
        refs += sum(1 for ent in alloc.hosted.values()
                    for _, x in ent["resident"] if x == p)
        assert alloc.refcount[p] == refs, f"page {p}: rc != references"
    assert alloc.pages_in_use == alloc.n_pages - len(alloc.free)


def _run_op_sequence(codes):
    """Interpret a flat int list as allocator ops; invariants hold after
    every step regardless of order (MemoryError is a legal outcome)."""
    alloc = pc.PageAllocator(n_pages=24, page_size=8, max_pages_per_seq=6)
    next_slot, next_req = 0, 0
    for code in codes:
        op = code % 6
        arg = code // 6
        try:
            if op == 0:                            # alloc a fresh slot
                alloc.alloc_for(next_slot, 1 + arg % 40)
                next_slot += 1
            elif op == 1 and alloc.owned:          # fork an existing chain
                src = sorted(alloc.owned)[arg % len(alloc.owned)]
                n_tok = 1 + arg % (len(alloc.owned[src]) * alloc.page_size)
                alloc.fork(src, next_slot, n_tok)
                next_slot += 1
            elif op == 2 and alloc.owned:          # cow guard
                s = sorted(alloc.owned)[arg % len(alloc.owned)]
                alloc.cow_page(s, arg % (len(alloc.owned[s])
                                         * alloc.page_size))
            elif op == 3 and alloc.owned:          # release
                s = sorted(alloc.owned)[arg % len(alloc.owned)]
                alloc.release(s)
            elif op == 4 and alloc.owned:          # demote
                s = sorted(alloc.owned)[arg % len(alloc.owned)]
                alloc.demote(s, f"req{next_req}")
                next_req += 1
            elif op == 5 and alloc.hosted:         # promote or drop
                r = sorted(alloc.hosted)[arg % len(alloc.hosted)]
                if arg % 2:
                    alloc.drop_hosted(r)
                else:
                    alloc.promote(r, next_slot)
                    next_slot += 1
        except MemoryError:
            pass
        _alloc_invariants(alloc)


if HAVE_HYPOTHESIS:
    @given(st.lists(st.integers(min_value=0, max_value=2 ** 16),
                    min_size=1, max_size=40))
    @settings(max_examples=60, deadline=None)
    def test_allocator_swap_invariants(codes):
        _run_op_sequence(codes)
else:
    @pytest.mark.parametrize("seed", range(8))
    def test_allocator_swap_invariants(seed):
        rng = np.random.default_rng(seed)
        _run_op_sequence([int(c) for c in rng.integers(0, 2 ** 16, 60)])


# ---------------------------------------------------------------------------
# engine end-to-end
# ---------------------------------------------------------------------------

def test_engine_int8_pool_generates_and_tracks_read_bytes(params):
    eng = _engine(params, kv_dtype="int8", kv_backend="paged", page_size=16)
    for seg in eng.cache["segments"]:
        if "k_pages" in seg:
            assert seg["k_pages"].dtype == jnp.int8
            assert seg["k_scale"].dtype == jnp.float32
    outs = eng.generate([[3, 4, 5, 6], [9, 8]], max_new=6)
    assert all(len(t) >= 1 for t, _ in outs)
    assert all(np.isfinite(lp) for _, lps in outs for lp in lps)
    assert eng.kv_bytes_read > 0, "decode must account its KV traffic"


def test_engine_int8_tracks_float_reference(params):
    """Greedy decode over an int8 pool follows the float engine closely —
    quantization error, not divergence (tokens may legitimately differ at
    near-ties, so the assert is on prompt-conditioned logprobs)."""
    prompts = [[7, 8, 9, 10, 11], [20, 21, 22]]
    ref = _engine(params, kv_backend="paged", page_size=16)
    out_f = ref.generate(prompts, max_new=4)
    eng = _engine(params, kv_dtype="int8", kv_backend="paged", page_size=16)
    out_q = eng.generate(prompts, max_new=4)
    for (tf, lf), (tq, lq) in zip(out_f, out_q):
        assert abs(lf[0] - lq[0]) < 0.15, "first-token logprob drifted"


def test_dense_backend_rejects_quantized_kv(params):
    with pytest.raises(AssertionError):
        _engine(params, kv_dtype="int8")


def test_swap_eviction_is_bit_identical_to_dense(params):
    """Forced preemption under host_swap: the demote/promote path restores
    KV byte-exactly and re-enters decode without a PRNG draw, so greedy
    outputs stay bitwise the dense engine's."""
    prompts = [[65, 66, 67, 68], [70, 71], [80, 81, 82]]
    ref = _engine(params, max_len=64).generate(prompts, max_new=24)
    eng = _engine(params, kv_backend="paged", page_size=8, n_pages=6,
                  max_len=64, host_swap=True)
    out = eng.generate(prompts, max_new=24)
    assert eng.evictions > 0, "a 6-page pool must preempt"
    assert eng.swap_outs > 0 and eng.swap_ins > 0
    assert eng.swap_bytes > 0
    for (td, ld), (tp, lp) in zip(ref, out):
        assert td == tp
        np.testing.assert_array_equal(ld, lp)


def test_swap_resume_skips_prefill_replay(params):
    """An explicit evict/resume cycle: the swap path must re-enter decode
    directly (no pending prefill chunks) and continue the exact token
    stream an uninterrupted engine produces."""
    prompt = [5, 6, 7, 8, 9, 10]
    ref = _engine(params, kv_backend="paged", page_size=8,
                  max_len=64).generate([prompt], max_new=8)
    eng = _engine(params, kv_backend="paged", page_size=8, max_len=64,
                  host_swap=True)
    eng.add_request(0, prompt, max_new=8)
    for _ in range(3):
        eng.step()
    eng._harvest()
    n_before = len(eng.slots[0].tokens)
    assert eng._evict_victim(protect=-1)
    r = eng._resume_queue.pop(0)
    # the newest sampled token's KV is written on the NEXT step, so the
    # snapshotted context is one short of the visible token count
    assert r.swap is not None
    assert r.swap["ctx_len"] == len(prompt) + n_before - 1
    slot = eng._admit_swapped(r)
    assert not eng.slots[slot].prefill_toks, "swap resume must not replay"
    assert len(eng.slots[slot].tokens) == n_before
    while eng.slots[slot].active:
        eng.step()
    (t_ref, l_ref), = ref
    assert eng.slots[slot].tokens == t_ref
    np.testing.assert_array_equal(eng.slots[slot].logprobs, l_ref)


def test_replay_engine_still_bit_identical(params):
    """host_swap=False keeps the legacy evict-and-replay semantics."""
    prompts = [[65, 66, 67, 68], [70, 71], [80, 81, 82]]
    ref = _engine(params, max_len=64).generate(prompts, max_new=24)
    eng = _engine(params, kv_backend="paged", page_size=8, n_pages=6,
                  max_len=64, host_swap=False)
    out = eng.generate(prompts, max_new=24)
    assert eng.evictions > 0 and eng.swap_outs == 0
    for (td, _), (tp, _) in zip(ref, out):
        assert td == tp


def test_swap_eviction_with_int8_pool_recovers(params):
    """Quantized pool + host swap composes: the snapshot moves quantized
    bytes + scales, and the byte-exact restore keeps the quantized stream
    self-consistent (same tokens as an uninterrupted int8 engine)."""
    prompts = [[65, 66, 67, 68], [70, 71], [80, 81, 82]]
    big = _engine(params, kv_dtype="int8", kv_backend="paged", page_size=8,
                  max_len=64)
    ref = big.generate(prompts, max_new=24)
    eng = _engine(params, kv_dtype="int8", kv_backend="paged", page_size=8,
                  n_pages=6, max_len=64, host_swap=True)
    out = eng.generate(prompts, max_new=24)
    assert eng.swap_outs > 0
    for (td, _), (tp, _) in zip(ref, out):
        assert td == tp


# ---------------------------------------------------------------------------
# predicted occupancy tightens admission (Eq.(2) feedback)
# ---------------------------------------------------------------------------

def test_predicted_occupancy_tightens_admission():
    """The length-predictor forecast must raise memory pressure BEFORE the
    pool fills: same physical occupancy, growing queued_expected_tokens ->
    monotonically rising pressure factor."""
    from repro.core.profiler import LatencyModel
    from repro.core.scheduler import DynamicScheduler, EdgeModelInfo
    from repro.serving.network import NetworkModel
    cloud = LatencyModel(t0=0.5, rate=20.0)
    edges = [EdgeModelInfo(name="e", latency=LatencyModel(t0=0.5, rate=25.0),
                           capability=0.5)]
    sched = DynamicScheduler(cloud, edges, NetworkModel(), 4)
    mon = sched.monitor
    mon.update_memory(pages_used=40, pages_total=100)
    mon.kv_page_tokens = 16
    factors = []
    for queued in (0.0, 400.0, 700.0):
        mon.queued_expected_tokens = queued
        factors.append(sched.memory_pressure_factor())
    assert factors[0] < factors[1] < factors[2]
    # forecast occupancy is physical pages + ceil(queued tokens / page)
    mon.queued_expected_tokens = 400.0
    assert mon.kv_predicted_utilization == pytest.approx(
        (40 + np.ceil(400 / 16)) / 100)
    # no geometry observed -> forecast collapses to the physical signal
    mon.kv_page_tokens = 0
    assert mon.kv_predicted_utilization == mon.kv_utilization
    # and an empty queue reproduces the seed behavior exactly
    mon.queued_expected_tokens = 0.0
    mon.kv_page_tokens = 16
    assert mon.kv_predicted_utilization == mon.kv_utilization


def test_monitor_learns_page_geometry_from_engines(params):
    eng = _engine(params, kv_backend="paged", page_size=16)
    mon = RuntimeMonitor()
    mon.observe_engines([eng])
    assert mon.kv_page_tokens == 16
