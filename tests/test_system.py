"""End-to-end behaviour tests for the PICE system (real compute, tiny models).

Trains the tiny cloud + edge models briefly on the synthetic corpus, then
drives the full progressive pipeline: length prediction -> scheduling ->
sketch -> dispatch -> parallel edge expansion -> ensemble -> response.
"""
import pytest

pytestmark = pytest.mark.slow        # trains real engines: minutes on CPU

from repro.data import corpus as corpus_lib
from repro.data import tokenizer as tok
from repro.launch.serve import build_engines, build_pipeline
from repro.serving.requests import Request


@pytest.fixture(scope="module")
def pipeline():
    engines, caps = build_engines(
        train_steps=90, seed=0, log_fn=lambda s: None,
        names=["tiny-cloud", "tiny-edge-a", "tiny-edge-b"])
    return build_pipeline(engines, caps, log_fn=lambda s: None)


def test_progressive_end_to_end(pipeline):
    ex = corpus_lib.corpus(1, seed=11)[0]
    resp = pipeline.handle(Request(query=ex.query, category=ex.category))
    assert resp.mode in ("progressive", "cloud_full")
    assert isinstance(resp.text, str) and len(resp.text) > 0
    assert resp.latency_s > 0


def test_progressive_mode_engages_for_long_answers(pipeline):
    n_prog0 = pipeline.stats["progressive"]
    for ex in corpus_lib.corpus(3, seed=21, category="writing"):
        pipeline.handle(Request(query=ex.query, category="writing"))
    assert pipeline.stats["progressive"] > n_prog0, \
        "long-answer categories should trigger progressive inference"


def test_short_answers_stay_on_cloud(pipeline):
    n_cloud0 = pipeline.stats["cloud_full"]
    resp = pipeline.handle(Request(query="why", category="math"))
    assert pipeline.stats["cloud_full"] > n_cloud0
    assert resp.mode == "cloud_full"


def test_progressive_offloads_cloud_tokens(pipeline):
    ex = corpus_lib.corpus(1, seed=41, category="writing")[0]
    resp = pipeline.handle(Request(query=ex.query, category="writing"))
    if resp.mode == "progressive":
        assert resp.edge_tokens > 0
        assert 0.0 <= resp.confidence <= 1.0


def test_trained_cloud_model_generates_corpus_grammar(pipeline):
    """After brief training, cloud output should share vocabulary with the
    corpus grammar (sanity check that quality is measurable, not noise)."""
    cloud = pipeline.cloud
    prompt = tok.encode("Q: explain how the system stores tokens works\nA:")
    (out, _), = cloud.generate([prompt], max_new=48)
    text = tok.decode(out)
    ex = corpus_lib.corpus(50, seed=0)
    vocab = set(w for e in ex for w in e.answer.split())
    hits = sum(1 for w in text.split() if w in vocab)
    assert hits >= 2, f"expected corpus-like words, got {text!r}"
