"""Serving engine tests: continuous batching, cache insertion, scoring."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.pice_cloud_edge import TINY_EDGE_A
from repro.models import transformer
from repro.serving.engine import InferenceEngine
from repro.serving.sampler import SamplerConfig


@pytest.fixture(scope="module")
def engine():
    cfg = TINY_EDGE_A.with_(dtype="float32")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    return InferenceEngine(cfg, params, max_batch=4, max_len=256,
                           name="test"), cfg, params


def test_generate_lengths(engine):
    eng, _, _ = engine
    outs = eng.generate([[65, 66, 67], [70, 71]], max_new=12)
    assert len(outs) == 2
    for toks, lps in outs:
        assert 1 <= len(toks) <= 12
        assert len(lps) == len(toks)
        assert all(lp <= 0.0 for lp in lps)


def test_continuous_batching_slot_reuse(engine):
    eng, _, _ = engine
    # more requests than slots forces recycling
    prompts = [[65 + i, 66, 67] for i in range(9)]
    outs = eng.generate(prompts, max_new=6)
    assert len(outs) == 9
    assert all(len(t) >= 1 for t, _ in outs)
    assert len(eng.free_slots()) == eng.max_batch


def test_batched_equals_single(engine):
    """Greedy decode of a request must be identical whether it shares the
    batch with other requests or runs alone (continuous-batching isolation)."""
    eng, cfg, params = engine
    a = [65, 66, 67, 68]
    b = [80, 81]
    solo = InferenceEngine(cfg, params, max_batch=1, max_len=256)
    (ref, _), = solo.generate([a], max_new=8)
    outs = eng.generate([b, a, b], max_new=8)
    assert outs[1][0] == ref


def test_score_is_teacher_forced_logprob(engine):
    eng, cfg, params = engine
    seq = [65, 66, 67, 68, 69]
    mean_lp, per = eng.score(seq)
    assert per.shape[0] == len(seq) - 1
    assert mean_lp <= 0.0
    logits, _ = transformer.forward(cfg, params,
                                    jnp.asarray([seq[:-1]], jnp.int32))
    logp = jax.nn.log_softmax(logits[0].astype(jnp.float32), -1)
    want = np.asarray([float(logp[i, seq[i + 1]]) for i in range(len(seq) - 1)])
    np.testing.assert_allclose(per, want, rtol=1e-4, atol=1e-4)


def test_sampler_greedy_vs_temperature(engine):
    eng, cfg, params = engine
    hot = InferenceEngine(cfg, params, max_batch=1, max_len=256,
                          sampler=SamplerConfig(temperature=1.0, top_k=8))
    (g1, _), = eng.generate([[65, 66]], max_new=10)
    (g2, _), = eng.generate([[65, 66]], max_new=10)
    assert g1 == g2, "greedy must be deterministic"
    (h1, _), = hot.generate([[65, 66]], max_new=10)
    assert len(h1) >= 1
