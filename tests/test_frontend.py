"""Multiplexed serving front-end (serving/frontend.py) and the trace-driven
load generator (serving/loadgen.py).

The load-bearing claim: because decoding is greedy, a stream served
MULTIPLEXED — interleaved with other roles, preempted, resumed, faulted —
is bit-identical to the same request run alone on a fresh engine. Batch
composition changes when tokens arrive, never which tokens. The tests here
drive mixed-priority co-tenancy with forced eviction, a mid-run cancel, and
a seeded FaultPlan on the SHARED engine, checking every survivor against
its isolated run; plus streaming deltas, deadlines, backpressure shedding,
trace determinism, arrival-relative TTFT, and the scheduler's
forecast-memory admission gate (ISSUE satellites S1-S3).
"""
import asyncio
import time

import jax
import pytest

from repro.core.profiler import LatencyModel, RuntimeMonitor
from repro.core.scheduler import DynamicScheduler, EdgeModelInfo
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.serving import loadgen
from repro.serving.engine import InferenceEngine
from repro.serving.faults import FaultInjector, FaultPlan
from repro.serving.frontend import (CompletionRequest, EngineFrontend,
                                    as_frontend)
from repro.serving.network import NetworkModel

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                   max_seq_len=512, dtype="float32", remat=False)


@pytest.fixture(scope="module")
def params():
    return transformer.init_params(TINY, jax.random.PRNGKey(0))


def _engine(params, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_len", 128)
    kw.setdefault("kv_backend", "paged")
    kw.setdefault("page_size", 8)
    return InferenceEngine(TINY, params, **kw)


def _isolated(params, prompt, max_new):
    """The reference stream: the same request alone on a fresh engine."""
    (toks, lps), = _engine(params).generate([list(prompt)], max_new=max_new)
    return toks, lps


def _assert_drained(eng):
    assert not any(s.active for s in eng.slots)
    assert not eng._resume_queue
    assert eng.alloc.pages_in_use == 0
    assert not eng.alloc.hosted


# ---------------------------------------------------------------------------
# S3: multiplexed bit-identity under contention, eviction, and cancel
# ---------------------------------------------------------------------------

def test_multiplexed_streams_bit_identical_with_eviction_and_cancel(params):
    """Six mixed-priority requests multiplexed onto a 3-slot engine with a
    page pool too small for the working set (forcing priority eviction +
    resume), plus one mid-run cancel. Every request that ran to completion
    must be bit-identical to its isolated run; the cancelled one must be a
    strict prefix of its isolated run."""
    prompts = [[7, 8, 9, 10], [20, 21, 22], [30, 31, 32, 33],
               [40, 41, 42], [50, 51, 52, 53], [60, 61, 62]]
    roles = ["sketch", "sketch", "expansion_primary", "expansion_primary",
             "expansion_extra", "expansion_extra"]
    max_new = 12
    eng = _engine(params, max_batch=3, max_len=64, n_pages=5)
    fe = EngineFrontend(eng)

    async def main():
        handles = [fe.submit(CompletionRequest(prompt=p, max_tokens=max_new,
                                               role=r), sheddable=False)
                   for p, r in zip(prompts, roles)]
        victim = handles[4]               # an expansion_extra, priority 0

        async def cancel_after_two():
            seen = 0
            async for d in victim.stream():
                if d.finish_reason:
                    return
                seen += 1
                if seen == 2:
                    victim.cancel()
                    return

        results = await asyncio.gather(
            *[h.wait() for h in handles], cancel_after_two())
        return handles, results[:len(handles)]

    handles, _ = asyncio.run(main())
    assert eng.evictions >= 1, "scenario must actually exercise eviction"
    for i, h in enumerate(handles):
        ref_toks, ref_lps = _isolated(params, prompts[i], max_new)
        if i == 4 and h.state == "cancelled":
            assert 1 <= len(h.tokens) < len(ref_toks)
            assert h.tokens == ref_toks[:len(h.tokens)]
        else:
            assert h.state == "done", (i, h.state, h.finish_reason)
            assert h.tokens == ref_toks, f"request {i} diverged multiplexed"
            assert h.logprobs == pytest.approx(ref_lps)
    _assert_drained(eng)


def test_fault_plan_on_shared_engine_keeps_survivors_bit_identical(params):
    """A seeded FaultPlan attached THROUGH the front-end (hook assignments
    must forward to the wrapped engine): the injected slot crash cancels
    exactly one request; every other stream stays bit-identical to its
    isolated run, and every handle settles — availability 1.0, nothing
    fails or hangs."""
    prompts = [[7, 8, 9, 10], [20, 21, 22], [30, 31, 32, 33], [40, 41, 42]]
    max_new = 10
    eng = _engine(params)
    fe = EngineFrontend(eng)
    inj = FaultInjector(FaultPlan(seed=0, crash_steps=(3,)))
    inj.attach(engines=[fe])
    assert eng.step_hook == inj.on_step, "hook must land on the raw engine"

    async def main():
        handles = [fe.submit(
            CompletionRequest(prompt=p, max_tokens=max_new, priority=pr),
            sheddable=False)
            for p, pr in zip(prompts, [1, 1, 1, 0])]
        await asyncio.gather(*[h.wait() for h in handles])
        return handles

    handles = asyncio.run(main())
    inj.detach()
    assert inj.events["slot_crash"] == 1
    crashed = [h for h in handles if h.state == "cancelled"]
    assert len(crashed) == 1
    assert crashed[0] is handles[3], "lowest-priority slot takes the crash"
    for h, p in zip(handles, prompts):
        assert h.done, "availability: every request must settle"
        if h.state == "done":
            assert h.tokens == _isolated(params, p, max_new)[0]
    _assert_drained(eng)


def test_sync_facade_matches_engine_generate(params):
    prompts = [[5, 6, 7], [11, 12, 13, 14], [21, 22]]
    ref = _engine(params).generate(prompts, max_new=8)
    out = EngineFrontend(_engine(params)).generate(prompts, max_new=8)
    assert out == ref


def test_fanout_facade_matches_engine_and_stamps_arrival(params):
    """The COW fan-out facade forks enqueue directly (not via submit): it
    must still stamp arrival so TTFT accounting works, and must match the
    engine's own generate_fanout bit for bit."""
    prefix, suffixes = [5, 6, 7, 8], [[10], [11], [12]]
    ref = _engine(params).generate_fanout(prefix, suffixes, max_new=6)
    mon = RuntimeMonitor()
    fe = EngineFrontend(_engine(params), monitor=mon)
    out = fe.generate_fanout(prefix, suffixes, max_new=6)
    assert out == ref
    assert len(mon.ttft_window) == len(suffixes)


# ---------------------------------------------------------------------------
# streaming deltas
# ---------------------------------------------------------------------------

def test_stream_yields_contiguous_deltas_and_terminal_marker(params):
    fe = EngineFrontend(_engine(params))
    req = CompletionRequest(prompt=[9, 10, 11], max_tokens=6)

    async def main():
        deltas = []
        async for d in fe.stream(req, sheddable=False):
            deltas.append(d)
        return deltas

    deltas = asyncio.run(main())
    body, last = deltas[:-1], deltas[-1]
    assert [d.index for d in body] == list(range(len(body)))
    assert all(d.finish_reason == "" for d in body)
    assert last.token == -1 and last.finish_reason in ("stop", "length")
    ref_toks, _ = _isolated(params, req.prompt, 6)
    assert [d.token for d in body] == ref_toks


# ---------------------------------------------------------------------------
# deadlines and backpressure
# ---------------------------------------------------------------------------

def test_deadline_cancels_midrun_with_partial_tokens(params):
    eng = _engine(params)
    fe = EngineFrontend(eng)
    fe.step_hook = lambda e: time.sleep(0.01)   # pace steps for the sweep

    async def main():
        doomed = fe.submit(CompletionRequest(
            prompt=[5, 6, 7], max_tokens=64,
            deadline_s=time.perf_counter() + 0.05), sheddable=False)
        calm = fe.submit(CompletionRequest(prompt=[20, 21, 22],
                                           max_tokens=8), sheddable=False)
        await asyncio.gather(doomed.wait(), calm.wait())
        return doomed, calm

    doomed, calm = asyncio.run(main())
    assert doomed.finish_reason == "deadline"
    assert doomed.state == "cancelled"
    assert 0 < len(doomed.tokens) < 64
    assert eng.deadline_cancels == 1
    # the co-tenant is untouched and bit-identical
    assert calm.state == "done"
    assert calm.tokens == _isolated(params, [20, 21, 22], 8)[0]
    _assert_drained(eng)


def test_full_queue_sheds_and_survivors_complete(params):
    fe = EngineFrontend(_engine(params), queue_max=2)
    handles = [fe.submit(CompletionRequest(prompt=[10 + i, 3], max_tokens=6))
               for i in range(6)]          # no loop yet: nothing drains
    assert fe.shed == 4, "queue_max=2 must shed 4 of 6 sheddable submits"
    shed = [h for h in handles if h.state == "shed"]
    assert len(shed) == 4
    assert all(h.finish_reason == "shed" and h.done for h in shed)

    async def main():
        await asyncio.gather(*[h.wait() for h in handles])

    asyncio.run(main())
    assert fe.completed == 2
    survivors = [h for h in handles if h.state == "done"]
    assert len(survivors) == 2
    for h in survivors:
        assert h.tokens == _isolated(params, h.req.prompt, 6)[0]


# ---------------------------------------------------------------------------
# load generator: determinism, replay, arrival-relative metrics
# ---------------------------------------------------------------------------

def test_trace_synthesis_deterministic_and_roundtrips(tmp_path):
    a = loadgen.synthesize_trace(50.0, 20, seed=3)
    b = loadgen.synthesize_trace(50.0, 20, seed=3)
    c = loadgen.synthesize_trace(50.0, 20, seed=4)
    assert a == b, "(seed, rate) must name ONE workload"
    assert a != c
    arrivals = [e.arrival_s for e in a]
    assert arrivals == sorted(arrivals)
    assert all(e.tier in ("interactive", "standard", "batch") for e in a)
    p = tmp_path / "trace.jsonl"
    loadgen.save_trace(str(p), a)
    assert loadgen.load_trace(str(p)) == a
    # prompt content derives from (seed, index) alone
    assert loadgen.trace_prompt(3, 5, 8, 128) == \
        loadgen.trace_prompt(3, 5, 8, 128)
    assert all(0 <= t < 128 for t in loadgen.trace_prompt(3, 5, 8, 128))


def test_replay_reports_outcomes_and_arrival_relative_ttft(params):
    mon = RuntimeMonitor()
    fe = EngineFrontend(_engine(params), monitor=mon, queue_max=32)
    trace = loadgen.synthesize_trace(200.0, 6, seed=1, prompt_len=(3, 8),
                                     max_new=(4, 8),
                                     tier_mix={"batch": 1.0})
    report = loadgen.replay_sync(fe, trace, seed=1, offered_rps=200.0)
    assert report.n_requests == 6
    assert report.completed == 6 and report.shed == 0 and report.failed == 0
    assert report.sla_attainment == 1.0   # batch tier: completing meets it
    assert report.good_tokens == report.total_tokens > 0
    assert report.goodput_tps > 0
    # TTFT/latency are measured from arrival and flow through the monitor
    assert len(mon.ttft_window) == 6
    assert report.ttft_p95_s >= report.ttft_p50_s > 0
    assert report.latency_p95_s >= report.ttft_p50_s


def test_as_frontend_wraps_once_and_passes_none(params):
    assert as_frontend(None) is None
    fe = as_frontend(_engine(params))
    assert isinstance(fe, EngineFrontend)
    assert as_frontend(fe) is fe


# ---------------------------------------------------------------------------
# S1: scheduler admission on forecast memory
# ---------------------------------------------------------------------------

def _sched():
    cloud = LatencyModel(t0=0.5, rate=20.0)
    edges = [EdgeModelInfo(name="small",
                           latency=LatencyModel(t0=0.5, rate=25.0),
                           capability=0.5),
             EdgeModelInfo(name="big",
                           latency=LatencyModel(t0=0.5, rate=10.0),
                           capability=0.8)]
    return DynamicScheduler(cloud, edges, NetworkModel(), 4)


def test_admission_tightens_as_queued_expected_tokens_grow():
    """The progressive path admits on max(physical, kv-predicted)
    utilization plus the request's own footprint: growing the backlog's
    predicted lengths (on_enqueue) tightens admission until schedule()
    refuses the progressive path outright."""
    s = _sched()
    s.monitor.kv_pages_total = 100
    s.monitor.kv_pages_used = 40
    s.monitor.kv_page_tokens = 16
    f0 = s.forecast_utilization(500)
    assert s.admit_progressive(500)
    d0 = s.schedule(500)
    assert d0.mode == "progressive"
    assert s.monitor.admission_rejects == 0

    s.monitor.on_enqueue(800.0)           # predicted backlog: +50 pages
    assert s.forecast_utilization(500) > f0, "forecast must tighten"
    assert not s.admit_progressive(500)
    d1 = s.schedule(500)
    assert d1.mode == "cloud_full"
    assert s.monitor.admission_rejects == 1


def test_admission_inert_without_page_telemetry():
    s = _sched()                          # dense backend: no kv geometry
    assert s.forecast_utilization(10 ** 6) == 0.0
    assert s.admit_progressive(10 ** 6)
    assert s.schedule(500).mode == "progressive"
    assert s.monitor.admission_rejects == 0
