"""Training substrate: loss goes down, checkpoints round-trip, optimizer
behaviors."""
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow        # real training loops: ~20 s on CPU

from repro.configs.pice_cloud_edge import TINY_EDGE_B
from repro.data import corpus as corpus_lib
from repro.data.pipeline import PackedDataset
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt_lib
from repro.training.losses import cross_entropy
from repro.training.train_loop import init_train_state, train


def test_loss_decreases_on_synthetic_corpus():
    cfg = TINY_EDGE_B
    text = corpus_lib.lm_text(300, seed=1)
    ds = PackedDataset(text, seq_len=128, batch_size=8, seed=1)
    state = init_train_state(cfg, seed=1)
    losses = []
    opt_cfg = opt_lib.AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=40)
    state = train(cfg, state, iter(ds), opt_cfg, 40, log_every=1000,
                  log_fn=lambda s: losses.append(s))
    # evaluate before/after on a fixed batch
    it = iter(ds)
    tokens, targets = next(it)
    from repro.models import transformer
    logits, _ = transformer.forward(cfg, state.params, jnp.asarray(tokens))
    final_loss, _ = cross_entropy(logits, jnp.asarray(targets))
    fresh = init_train_state(cfg, seed=1)
    logits0, _ = transformer.forward(cfg, fresh.params, jnp.asarray(tokens))
    init_loss, _ = cross_entropy(logits0, jnp.asarray(targets))
    assert float(final_loss) < float(init_loss) * 0.8, \
        f"loss {float(init_loss):.3f} -> {float(final_loss):.3f} too small a drop"


def test_adamw_grad_clip_and_lr_schedule():
    cfg = opt_lib.AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=10,
                              total_steps=100, schedule="cosine")
    assert float(opt_lib.lr_at(cfg, jnp.asarray(0))) < 0.2
    assert abs(float(opt_lib.lr_at(cfg, jnp.asarray(10))) - 1.0) < 0.2
    assert float(opt_lib.lr_at(cfg, jnp.asarray(99))) <= 0.2
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.full((4, 4), 100.0)}
    st = opt_lib.init_opt_state(params)
    p2, st2, m = opt_lib.adamw_update(cfg, params, grads, st)
    assert float(m["grad_norm"]) > 1.0
    assert np.isfinite(np.asarray(p2["w"])).all()


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": [jnp.ones((4,), jnp.bfloat16),
                  {"c": jnp.asarray(3, jnp.int32)}]}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 7, tree)
        assert ckpt.latest_step(d) == 7
        out = ckpt.restore(d, None, tree)
        assert out["a"].dtype == jnp.float32
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))
        np.testing.assert_array_equal(
            np.asarray(out["b"][0], np.float32),
            np.asarray(tree["b"][0], np.float32))


def test_checkpoint_shape_mismatch_raises():
    tree = {"a": jnp.ones((2, 2))}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, tree)
        bad = {"a": jnp.ones((3, 3))}
        try:
            ckpt.restore(d, 1, bad)
            assert False, "should raise"
        except ValueError:
            pass
