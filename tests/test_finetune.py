"""Fine-tuning pipeline (§IV-D): preference labeling, reward model, RLAIF."""
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow        # trains a reward model: ~25 s on CPU

from repro.configs.pice_cloud_edge import TINY_EDGE_B
from repro.data import corpus as corpus_lib
from repro.finetune.preference import (PreferenceTriple, label_pair,
                                       sketch_score)
from repro.finetune.reward_model import (bt_loss, encode_pair,
                                         init_reward_model, reward_fwd,
                                         train_reward_model)


def test_sketch_score_prefers_concise_faithful():
    y = "in practice the system carefully stores tokens at scale for every user"
    short_good = "the system stores tokens"
    long_good = ("the system stores tokens and also many other words that add "
                 "nothing at all to the content of this sketch")
    s1 = sketch_score(short_good, y, y)
    s2 = sketch_score(long_good, y, y)
    assert s1 > s2, "shorter sketch with same fidelity must score higher"


def test_label_pair_orders_by_score():
    y = "in practice the model carefully predicts scores at scale"
    t = label_pair("doc", y, "the model predicts scores",
                   "zzz qqq unrelated words entirely",
                   expand_fn=lambda x, r: r)    # identity expansion
    assert t.r_w == "the model predicts scores"
    assert t.score_w >= t.score_l


@pytest.fixture(scope="module")
def triples():
    out = []
    for ex in corpus_lib.corpus(64, seed=3):
        # gold sketch vs a corrupted sketch: measurable preference signal
        bad = " ".join(reversed(ex.answer.split()[:30]))
        out.append(PreferenceTriple(x=ex.answer[:120], r_w=ex.sketch,
                                    r_l=bad, score_w=1.0, score_l=0.0))
    return out


def test_reward_model_learns_preferences(triples):
    cfg = TINY_EDGE_B.with_(dtype="float32")
    params = train_reward_model(cfg, triples, n_steps=60, batch=8,
                                seq_len=128, log_fn=lambda s: None)
    tw = jnp.asarray(np.stack([encode_pair(t.x, t.r_w, 128)
                               for t in triples[:32]]))
    tl = jnp.asarray(np.stack([encode_pair(t.x, t.r_l, 128)
                               for t in triples[:32]]))
    rw = reward_fwd(cfg, params, tw)
    rl = reward_fwd(cfg, params, tl)
    acc = float(jnp.mean((rw > rl).astype(jnp.float32)))
    assert acc >= 0.7, f"reward model pair accuracy {acc:.2f}"


def test_bt_loss_gradient_direction(triples):
    cfg = TINY_EDGE_B.with_(dtype="float32")
    params = init_reward_model(cfg, seed=0)
    tw = jnp.asarray(np.stack([encode_pair(t.x, t.r_w, 64)
                               for t in triples[:8]]))
    tl = jnp.asarray(np.stack([encode_pair(t.x, t.r_l, 64)
                               for t in triples[:8]]))
    loss, acc = bt_loss(cfg, params, tw, tl)
    assert np.isfinite(float(loss)) and float(loss) > 0
