"""Chunked, batched multi-token prefill into the paged-KV engine.

Bit-identity contract: a chunked engine must reproduce the monolithic
engine's outputs — greedy tokens AND logprobs — across chunk sizes, page
sizes, fork-suffix replay, and eviction-resume (including mid-prefill
preemption); sampled decode matches wherever the PRNG streams align (one
slot, or fan-out from a parked prefix). Plus kernel-vs-oracle parity for
kernels/paged_prefill_attention at ragged chunk boundaries.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_prefill_attention import ops as ppa_ops
from repro.kernels.paged_prefill_attention import ref as ppa_ref
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.serving.engine import InferenceEngine
from repro.serving.sampler import SamplerConfig

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                   max_seq_len=512, dtype="float32", remat=False)

# mixed prompt lengths: shorter than any chunk, page-unaligned, one chunk
# exactly, spanning several chunks and pages
PROMPTS = [[65 + i for i in range(43)], [70, 71], [80] * 40, [90] * 17,
           [5] * 64]


@pytest.fixture(scope="module")
def params():
    return transformer.init_params(TINY, jax.random.PRNGKey(0))


def _engine(params, chunk=0, **kw):
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_len", 128)
    kw.setdefault("kv_backend", "paged")
    kw.setdefault("page_size", 16)
    cfg = kw.pop("cfg", TINY).with_(prefill_chunk=chunk)
    return InferenceEngine(cfg, params, **kw)


def _assert_same(a, b):
    for i, ((ta, la), (tb, lb)) in enumerate(zip(a, b)):
        assert ta == tb, f"request {i}: tokens diverge"
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=f"request {i}: logprobs diverge")


def _assert_same_replay(a, b):
    """Replay scenarios (fork suffix / eviction resume): tokens must be
    bitwise identical — the chunk-rebuilt KV is — but the one logprob read
    right after a replay comes from (1, V) chunk logits where the
    monolithic path read a (B, V) decode row, and XLA lowers the unembed
    matvec differently by shape (~1 ulp; same precedent as the monolithic
    resume path, whose eviction test also asserts tokens). Every other
    logprob is asserted bitwise via a tight allclose."""
    for i, ((ta, la), (tb, lb)) in enumerate(zip(a, b)):
        assert ta == tb, f"request {i}: tokens diverge"
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=1e-5, atol=1e-6,
                                   err_msg=f"request {i}: logprobs diverge")


# ---------------------------------------------------------------------------
# config contract
# ---------------------------------------------------------------------------

def test_prefill_chunk_validation():
    cfg = TINY.with_(prefill_chunk=256)
    with pytest.raises(AssertionError):
        cfg.validate_paged(16, 128)          # chunk > max_len
    TINY.with_(prefill_chunk=48).validate_paged(16, 128)
    with pytest.raises(AssertionError):
        TINY.with_(prefill_chunk=20, use_pallas=True).validate_paged(16, 128)
    TINY.with_(prefill_chunk=24, use_pallas=True).validate_paged(16, 128)


def test_recurrent_family_falls_back_to_monolithic(params):
    """SSM stacks cannot resume their scan state mid-prompt: the engine must
    silently keep the monolithic path (prefill_chunk forced to 0)."""
    ssm = ModelConfig(name="t-ssm", family="ssm", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                      ssm_state=16, dtype="float32", remat=False,
                      prefill_chunk=16)
    p = transformer.init_params(ssm, jax.random.PRNGKey(0))
    eng = InferenceEngine(ssm, p, max_batch=2, max_len=64,
                          kv_backend="paged", page_size=16)
    assert eng.prefill_chunk == 0
    (toks, _), = eng.generate([[5, 6, 7]], max_new=4)
    assert len(toks) >= 1


# ---------------------------------------------------------------------------
# chunked vs monolithic bit-identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [16, 48])
@pytest.mark.parametrize("page", [8, 16])
def test_chunked_matches_monolithic_greedy(params, chunk, page):
    mono = _engine(params, chunk=0, page_size=page)
    chunked = _engine(params, chunk=chunk, page_size=page)
    _assert_same(mono.generate(PROMPTS, max_new=12),
                 chunked.generate(PROMPTS, max_new=12))
    assert chunked.alloc.pages_in_use == 0


def test_chunked_matches_dense_backend(params):
    """Transitively: chunked paged == monolithic paged == dense."""
    dense = InferenceEngine(TINY, params, max_batch=3, max_len=128)
    chunked = _engine(params, chunk=32)
    _assert_same(dense.generate(PROMPTS, max_new=12),
                 chunked.generate(PROMPTS, max_new=12))


def test_chunked_sampled_bit_identical_serialized(params):
    """With one slot the PRNG stream is position-for-position identical:
    the chunk path takes the same single (1, V) first-token draw a
    monolithic add_request takes, and no draw happens during ingestion."""
    sampler = SamplerConfig(temperature=0.9, top_k=20)
    a = _engine(params, chunk=0, max_batch=1,
                sampler=sampler).generate(PROMPTS[:3], max_new=10)
    b = _engine(params, chunk=16, max_batch=1,
                sampler=sampler).generate(PROMPTS[:3], max_new=10)
    _assert_same(a, b)


def test_chunked_context_capacity_terminates_identically(params):
    prompt = list(range(1, 65))
    mono = _engine(params, chunk=0, max_len=64)
    chunked = _engine(params, chunk=16, max_len=64)
    om = mono.generate([prompt], max_new=8)
    oc = chunked.generate([prompt], max_new=8)
    assert len(oc[0][0]) == 1
    _assert_same(om, oc)


def test_chunked_empty_prompt_does_not_crash(params):
    """A degenerate empty prompt must still produce a token (one
    zero-length chunk supplies the sampling logits, mirroring the
    monolithic path's zero-padded prefill)."""
    eng = _engine(params, chunk=16)
    (toks, lps), = eng.generate([[]], max_new=4)
    assert 1 <= len(toks) <= 4 and len(lps) == len(toks)
    assert eng.alloc.pages_in_use == 0


def test_generate_rejects_mismatched_priorities(params):
    eng = _engine(params, chunk=16)
    with pytest.raises(AssertionError):
        eng.generate([[1, 2], [3, 4]], max_new=2, priorities=[1])


def test_chunk_larger_than_prompt_single_padded_chunk(params):
    """A prompt shorter than one chunk takes exactly one padded ingest."""
    mono = _engine(params, chunk=0)
    chunked = _engine(params, chunk=64)
    _assert_same(mono.generate([[9, 8, 7]], max_new=6),
                 chunked.generate([[9, 8, 7]], max_new=6))


# ---------------------------------------------------------------------------
# fork-suffix replay through chunks (PR 2 nuance folded in)
# ---------------------------------------------------------------------------

FANOUT_PREFIX = [(i % 100) + 1 for i in range(70)]


def test_chunked_fanout_suffix_replay_matches_token_by_token(params):
    """Fork suffixes ingest through multi-token chunks instead of
    token-by-token teacher forcing; greedy tokens AND logprobs must match
    the monolithic engine's pending-token path bitwise (the grouped-SDPA
    chunk read reproduces C decode steps exactly)."""
    suffixes = [[5, 6, 7], [9], [11] * 20]
    mono = _engine(params, chunk=0, max_batch=4)
    chunked = _engine(params, chunk=16, max_batch=4)
    _assert_same_replay(
        mono.generate_fanout(FANOUT_PREFIX, suffixes, max_new=8),
        chunked.generate_fanout(FANOUT_PREFIX, suffixes, max_new=8))
    assert chunked.alloc.pages_in_use == 0
    assert all(c == 0 for c in chunked.alloc.refcount)


def test_chunked_fanout_sampled_empty_suffix(params):
    """Empty-suffix fan-out: every fork samples its first token at
    admission in both engines, so even stochastic draws line up."""
    sampler = SamplerConfig(temperature=0.8, top_k=16)
    a = _engine(params, chunk=0, max_batch=4,
                sampler=sampler).generate_fanout(
        FANOUT_PREFIX, [[] for _ in range(3)], max_new=8)
    b = _engine(params, chunk=16, max_batch=4,
                sampler=sampler).generate_fanout(
        FANOUT_PREFIX, [[] for _ in range(3)], max_new=8)
    assert a == b


def test_chunked_fanout_under_pressure_evicts_and_recovers(params):
    """Preempted forks resume by re-forking and chunk-replaying suffix +
    carry; results must match the unconstrained fan-out."""
    N = 3
    big = _engine(params, chunk=8, max_batch=N + 1, page_size=8)
    ref = big.generate_fanout(FANOUT_PREFIX, [[] for _ in range(N)],
                              max_new=12)
    small = _engine(params, chunk=8, max_batch=N + 1, page_size=8,
                    n_pages=12)
    out = small.generate_fanout(FANOUT_PREFIX, [[] for _ in range(N)],
                                max_new=12)
    assert small.evictions > 0
    _assert_same_replay(ref, out)
    assert small.alloc.pages_in_use == 0
    assert sorted(small.alloc.free) == list(range(small.n_pages))


# ---------------------------------------------------------------------------
# eviction-resume through chunks
# ---------------------------------------------------------------------------

def test_chunked_eviction_resume_matches_dense(params):
    prompts = [[65, 66, 67, 68], [70, 71], [80, 81, 82]]
    dense = InferenceEngine(TINY, params, max_batch=3, max_len=64)
    od = dense.generate(prompts, max_new=24)
    chunked = _engine(params, chunk=16, max_len=64, page_size=8, n_pages=6)
    oc = chunked.generate(prompts, max_new=24)
    assert chunked.evictions > 0, "a 6-page pool must preempt"
    _assert_same_replay(od, oc)
    assert chunked.alloc.pages_in_use == 0


def test_eviction_mid_prefill_restarts_chunks(params, monkeypatch):
    """A slot preempted while still ingesting chunks must restart its
    prompt from scratch on resume and still match the unconstrained run."""
    prompts = [[7] * 8, [9] * 8, [33] * 40]
    big = _engine(params, chunk=8, max_len=64, page_size=8)
    ref = big.generate(prompts, max_new=20)

    mid_prefill_evictions = []
    orig = InferenceEngine._evict_victim

    def spy(self, protect):
        ingesting = [i for i, s in enumerate(self.slots)
                     if s.active and s.prefill_toks]
        ok = orig(self, protect)
        if ok:
            mid_prefill_evictions.extend(
                i for i in ingesting if self.slots[i].evicted)
        return ok

    monkeypatch.setattr(InferenceEngine, "_evict_victim", spy)
    small = _engine(params, chunk=8, max_len=64, page_size=8, n_pages=8)
    out = small.generate(prompts, max_new=20)
    assert small.evictions > 0
    assert mid_prefill_evictions, \
        "scenario must preempt a slot while it is still ingesting chunks"
    _assert_same_replay(ref, out)
    assert small.alloc.pages_in_use == 0


# ---------------------------------------------------------------------------
# oracle vs Pallas kernel parity at ragged chunk boundaries
# ---------------------------------------------------------------------------

def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("Hq,Hkv,hd,ps,C,offset,clen", [
    (4, 2, 64, 16, 32, 0, 32),     # first chunk, exact fill
    (4, 2, 64, 16, 32, 32, 20),    # ragged final chunk
    (8, 2, 32, 8, 16, 23, 9),      # page-unaligned offset, partial chunk
    (4, 4, 64, 16, 24, 40, 24),    # q_per_kv == 1
])
def test_paged_prefill_kernel_parity(dtype, Hq, Hkv, hd, ps, C, offset, clen):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    n_pages, P = 14, 8
    q = jax.random.normal(ks[0], (1, C, Hq, hd), dtype)
    kp = jax.random.normal(ks[1], (n_pages, ps, Hkv, hd), dtype)
    vp = jax.random.normal(ks[2], (n_pages, ps, Hkv, hd), dtype)
    need = -(-(offset + clen) // ps)
    row = np.full((P,), -1, np.int32)
    row[:need] = np.asarray(
        jax.random.permutation(ks[3], n_pages)[:need])
    row = jnp.asarray(row)
    out = ppa_ops.paged_prefill_attention(q, kp, vp, row,
                                          jnp.int32(offset), jnp.int32(clen))
    ref = ppa_ref.paged_prefill_attention_ref(q, kp, vp, row, offset, clen)
    np.testing.assert_allclose(
        np.asarray(out[:, :clen], np.float32),
        np.asarray(ref[:, :clen], np.float32), **_tol(dtype))
    assert not np.any(np.isnan(np.asarray(out[:, :clen], np.float32)))


def test_paged_prefill_kernel_ignores_poisoned_pages():
    """NaN in unmapped pages and in positions past offset+chunk_len must
    never reach the output (zero-masked before the MXU)."""
    Hq, Hkv, hd, ps, C = 4, 2, 32, 8, 16
    offset, clen = 10, 12
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    n_pages, P = 8, 6
    q = jax.random.normal(ks[0], (1, C, Hq, hd))
    kp = np.array(jax.random.normal(ks[1], (n_pages, ps, Hkv, hd)))
    vp = np.array(jax.random.normal(ks[2], (n_pages, ps, Hkv, hd)))
    total = offset + clen
    need = -(-total // ps)
    row = np.full((P,), -1, np.int32)
    row[:need] = np.arange(need)
    clean = ppa_ops.paged_prefill_attention(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(row),
        jnp.int32(offset), jnp.int32(clen))
    kp[need:], vp[need:] = np.nan, np.nan                 # unmapped pages
    tail = total - (need - 1) * ps
    kp[need - 1, tail:], vp[need - 1, tail:] = np.nan, np.nan   # past total
    out = ppa_ops.paged_prefill_attention(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(row),
        jnp.int32(offset), jnp.int32(clen))
    np.testing.assert_array_equal(np.asarray(out[:, :clen]),
                                  np.asarray(clean[:, :clen]))


def test_use_pallas_chunked_engine_matches_oracle(params):
    """cfg.use_pallas routes the chunk read through the kernel; greedy
    tokens must agree with the oracle engine (flash reassociation is not a
    bitwise guarantee, but greedy argmax agrees in practice)."""
    oracle = _engine(params, chunk=16)
    kern = _engine(params, chunk=16, cfg=TINY.with_(use_pallas=True))
    oo = oracle.generate(PROMPTS[:3], max_new=10)
    ok = kern.generate(PROMPTS[:3], max_new=10)
    for (to, _), (tk, _) in zip(oo, ok):
        assert to == tk


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------

def test_ttft_recorded_per_request(params):
    eng = _engine(params, chunk=16)
    eng.generate(PROMPTS[:3], max_new=6)
    assert sorted(eng.ttft) == [0, 1, 2]
    assert all(v > 0 for v in eng.ttft.values())
