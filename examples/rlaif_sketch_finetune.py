"""§IV-D fine-tuning example: SFT -> preference labeling -> reward model ->
RLAIF, producing a cloud model that emits concise, semantically complete
sketches.

Run:  PYTHONPATH=src python examples/rlaif_sketch_finetune.py
"""
import argparse

from repro.configs.pice_cloud_edge import TINY_CLOUD
from repro.data import corpus as corpus_lib
from repro.data import tokenizer as tok
from repro.finetune.preference import PreferenceTriple, label_pair
from repro.finetune.reward_model import train_reward_model
from repro.finetune.rlaif import RLAIFConfig, run_rlaif
from repro.finetune.sft import run_sft
from repro.serving.engine import InferenceEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sft-steps", type=int, default=200)
    ap.add_argument("--rm-steps", type=int, default=80)
    ap.add_argument("--rl-steps", type=int, default=20)
    args = ap.parse_args()
    cfg = TINY_CLOUD.with_(dtype="float32")

    print("== step 1: supervised fine-tuning (document -> sketch)")
    state = run_sft(cfg, n_steps=args.sft_steps)

    print("== step 2: preference labeling + reward model")
    sft_engine = InferenceEngine(cfg, state.params, max_batch=4, max_len=768)

    def expand(x: str, r: str) -> str:
        (out, _), = sft_engine.generate(
            [tok.encode(f"Q: {x[:80]}\nS: {r}\nE:")], max_new=96)
        return tok.decode(out)

    triples = []
    for ex in corpus_lib.corpus(32, seed=9):
        # candidate sketches: the gold one and a verbose prefix of the answer
        triples.append(label_pair(ex.answer[:160], ex.answer, ex.sketch,
                                  ex.answer[: 2 * len(ex.sketch)], expand))
    wins = sum(t.r_w != t.x for t in triples)
    print(f"labeled {len(triples)} pairs "
          f"(concise sketch preferred in {wins})")
    rm_params = train_reward_model(cfg, triples, n_steps=args.rm_steps)

    print("== step 3: RLAIF (REINFORCE + KL to SFT policy)")
    policy, hist = run_rlaif(cfg, state.params, state.params, cfg, rm_params,
                             RLAIFConfig(n_steps=args.rl_steps, batch=2))
    print(f"reward: {hist[0]['mean_reward']:.4f} -> "
          f"{hist[-1]['mean_reward']:.4f}, final KL={hist[-1]['kl']:.4f}")


if __name__ == "__main__":
    main()
