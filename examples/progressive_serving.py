"""End-to-end PICE serving driver (the paper's workflow, real compute).

Trains the tiny cloud + edge models on the synthetic redundancy corpus (a
few hundred steps), then serves a request stream through the full
progressive-inference pipeline and reports throughput / latency / quality
against the corpus ground truth.

Run:  PYTHONPATH=src python examples/progressive_serving.py \
          [--requests 10] [--train-steps 200]
"""
import argparse

from repro.launch.serve import main as serve_main
import sys


if __name__ == "__main__":
    # launch/serve.py implements the full driver; this example is its
    # documented entry point with friendlier defaults.
    if "--train-steps" not in " ".join(sys.argv):
        sys.argv += ["--train-steps", "200"]
    if "--requests" not in " ".join(sys.argv):
        sys.argv += ["--requests", "10"]
    serve_main()
