"""Training driver: train a ~100M-parameter reduced model for a few hundred
steps on the synthetic corpus (deliverable (b) end-to-end trainer).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 300
      PYTHONPATH=src python examples/train_lm.py --arch mixtral-8x7b --steps 50
"""
import argparse

import jax

from repro.configs import registry
from repro.data import corpus as corpus_lib
from repro.data.pipeline import PackedDataset
from repro.models.config import ModelConfig
from repro.training import optimizer as opt_lib
from repro.training.checkpoint import save
from repro.training.train_loop import init_train_state, train

# ~100M-param dense config (d=768, 12L) — big enough to be a real model,
# small enough for a few hundred CPU steps.
LM_100M = ModelConfig(
    name="repro-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=256, max_seq_len=1024,
    qk_norm=True, remat=False, source="repro demo config")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="assigned arch id (reduced variant); default 100M dense")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt", default="artifacts/ckpt_lm")
    args = ap.parse_args()

    cfg = (registry.get_config(args.arch).reduced(remat=False)
           if args.arch else LM_100M)
    n = cfg.param_count() / 1e6
    print(f"config {cfg.name}: ~{n:.0f}M params, family={cfg.family}")
    text = corpus_lib.lm_text(4000, seed=0)
    ds = PackedDataset(text, args.seq_len, args.batch, seed=0)
    state = init_train_state(cfg, seed=0)
    opt_cfg = opt_lib.AdamWConfig(lr=6e-4, warmup_steps=30,
                                  total_steps=args.steps)
    state = train(cfg, state, iter(ds), opt_cfg, args.steps, log_every=20)
    path = save(args.ckpt, state.step, state.params)
    print(f"checkpoint saved: {path}")


if __name__ == "__main__":
    main()
