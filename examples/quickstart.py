"""Quickstart: the PICE public API in ~60 lines.

1. Build a tiny cloud LLM + two edge SLMs (pure JAX, runs on CPU).
2. Profile them offline (f(l) latency models, cost coefficient c).
3. Serve a query through the progressive-inference pipeline:
   cloud sketch -> scheduler -> parallel edge expansion -> Eq.(3) ensemble.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.pice_cloud_edge import TINY_CLOUD, TINY_EDGE_A, TINY_EDGE_B
from repro.core.profiler import cost_coefficient, profile_engine
from repro.core.progressive import PICEConfig, PICEPipeline
from repro.core.scheduler import EdgeModelInfo
from repro.models import transformer
from repro.serving.engine import InferenceEngine
from repro.serving.requests import Request


def main():
    # --- 1. models & engines -------------------------------------------------
    engines = {}
    for cfg, seed in ((TINY_CLOUD, 0), (TINY_EDGE_A, 1), (TINY_EDGE_B, 2)):
        params = transformer.init_params(cfg, jax.random.PRNGKey(seed))
        engines[cfg.name] = InferenceEngine(cfg, params, max_batch=8,
                                            max_len=512, name=cfg.name)
        n = sum(x.size for x in jax.tree.leaves(params))
        print(f"built {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
              f"({n/1e6:.1f}M params)")

    # --- 2. offline profiling (paper §III Profiler) --------------------------
    lm_cloud = profile_engine(engines["tiny-cloud"], lengths=(8, 16, 32))
    infos = []
    for name, cap in (("tiny-edge-a", 0.7), ("tiny-edge-b", 0.55)):
        lm = profile_engine(engines[name], lengths=(8, 16, 32))
        print(f"{name}: rate={lm.rate:.1f} tok/s, "
              f"c={cost_coefficient(lm_cloud, lm):.2f}")
        infos.append(EdgeModelInfo(name=name, latency=lm, capability=cap))

    # --- 3. progressive inference --------------------------------------------
    pipe = PICEPipeline(
        cloud_engine=engines["tiny-cloud"],
        edge_engines={n: engines[n] for n in ("tiny-edge-a", "tiny-edge-b")},
        cloud_latency=lm_cloud, edge_infos=infos,
        cfg=PICEConfig(ensemble_size=2))

    resp = pipe.handle(Request(
        query="explain how the system stores tokens works",
        category="generic"))
    print(f"\nmode={resp.mode}  cloud_tokens={resp.cloud_tokens}  "
          f"edge_tokens={resp.edge_tokens}  latency={resp.latency_s:.2f}s")
    print(f"response: {resp.text[:120]!r}")
    print("\n(untrained weights -> gibberish text; see "
          "examples/progressive_serving.py for the trained end-to-end demo)")


if __name__ == "__main__":
    main()
