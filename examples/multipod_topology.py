import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod topology tour: how PICE maps onto the production mesh.

Builds the 2x16x16 mesh (512 placeholder devices), shows the cloud/edge pod
split, and prints the actual parameter/cache shardings chosen for one
architecture — the same shardings the dry-run compiles with.

Run:  PYTHONPATH=src python examples/multipod_topology.py [--arch qwen3-8b]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.registry import SHAPES, input_specs
from repro.launch import shardings as sh
from repro.launch.mesh import make_production_mesh
from repro.models import transformer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=True)
    print(f"production mesh: {dict(mesh.shape)} over {mesh.devices.size} chips")
    print("  pod 0 -> PICE cloud engine (the big LLM, TP over `model`, "
          "DP over `data`)")
    print("  pod 1 -> PICE edge fleet (SLM replicas across `data` x `model` "
          "subgroups)\n")

    cfg = registry.get_config(args.arch)
    params_shape = jax.eval_shape(
        lambda k: transformer.init_params(cfg, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    psh = sh.param_shardings(cfg, mesh, params_shape)

    print(f"== {args.arch}: parameter shardings (first 12 leaves) ==")
    flat, _ = jax.tree_util.tree_flatten_with_path(psh)
    shapes, _ = jax.tree_util.tree_flatten_with_path(params_shape)
    for (path, s), (_, shp) in list(zip(flat, shapes))[:12]:
        name = jax.tree_util.keystr(path)
        print(f"  {name:55s} {str(shp.shape):24s} -> {s.spec}")

    shape = SHAPES["decode_32k"]
    specs = input_specs(cfg, shape)
    csh = sh.cache_shardings(mesh, specs["cache"], kv_policy="seq_model")
    print(f"\n== decode_32k cache shardings (seq_model policy, §Perf) ==")
    k_sh = csh["segments"][0]["k"]
    print(f"  k/v pages: {specs['cache']['segments'][0]['k'].shape} "
          f"-> {k_sh.spec}")
    print(f"  lengths:   {specs['cache']['lengths'].shape} "
          f"-> {csh['lengths'].spec}")
    n = cfg.param_count() / 1e9
    print(f"\n{args.arch}: {n:.1f}B params; per-chip share on this mesh "
          f"~{n * 4 / 16:.2f} GB f32 (TP16)")


if __name__ == "__main__":
    main()
